"""AOT pipeline: lower every model variant's computations to HLO text.

Python runs ONCE at build time (``make artifacts``); the Rust coordinator
loads the emitted ``artifacts/*.hlo.txt`` through PJRT and never calls back
into Python.

Interchange format is **HLO text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowering goes stablehlo ->
XlaComputation (``return_tuple=True``) -> ``as_hlo_text``.

Artifact signatures (mirrored by ``rust/src/runtime/session.rs``):

  <variant>/init        [seed]                  -> (p_0 .. p_k)
  <variant>/train_step  [p_0..p_k, x, y, lr]    -> (p_0 .. p_k, loss)
  <variant>/predict     [p_0..p_k, x]           -> (logits,)
  <variant>/prune       [p_0..p_k, keep_frac]   -> (p_0 .. p_k)

Alongside the HLO files a ``manifest.txt`` (tiny line format parsed by
``rust/src/runtime/artifact.rs``) and a human-facing ``manifest.json`` are
written.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _tensor_line(kind: str, name: str, dims) -> str:
    dims = list(dims)
    d = "-" if not dims else "x".join(str(int(x)) for x in dims)
    return f"{kind} {name} f32 {d}"


def lower_variant(spec: M.VariantSpec, out_dir: str):
    """Lower the four artifacts of one variant; returns manifest entries."""
    scalar = _spec(())
    params0 = jax.eval_shape(
        functools.partial(M.init_params, spec), jnp.float32(0)
    )
    p_specs = [_spec(p.shape) for p in params0]
    x_spec = _spec((spec.batch, spec.features))
    y_spec = _spec((spec.batch,))
    k = len(p_specs)

    entries = []

    def emit(kind: str, fn, in_specs, in_names, out_shapes, out_names, extra_meta=None):
        name = f"{spec.name}/{kind}"
        fname = f"{spec.name}_{kind}.hlo.txt"
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        lines = [f"artifact {name}", f"file {fname}"]
        lines += [_tensor_line("input", n, s.shape) for n, s in zip(in_names, in_specs)]
        lines += [_tensor_line("output", n, s) for n, s in zip(out_names, out_shapes)]
        meta = {
            "proxy_for": spec.proxy_for.replace(" ", "_"),
            "param_count": M.param_count(spec),
            "flops_per_example": M.flops_per_example(spec),
            "classes": spec.classes,
            "batch": spec.batch,
            "features": spec.features,
        }
        meta.update(extra_meta or {})
        lines += [f"meta {k2} {v}" for k2, v in meta.items()]
        lines.append("end")
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s",
              flush=True)
        entries.append((name, fname, lines, meta,
                        [(n, list(map(int, s.shape))) for n, s in zip(in_names, in_specs)],
                        [(n, list(map(int, s))) for n, s in zip(out_names, out_shapes)]))

    p_names = [f"p{i}" for i in range(k)]
    p_shapes = [p.shape for p in p_specs]

    emit(
        "init",
        lambda seed: tuple(M.init_params(spec, seed)),
        [scalar],
        ["seed"],
        p_shapes,
        p_names,
    )
    emit(
        "train_step",
        lambda *a: M.train_step(spec, list(a[:k]), a[k], a[k + 1], a[k + 2]),
        [*p_specs, x_spec, y_spec, scalar],
        [*p_names, "x", "y", "lr"],
        [*p_shapes, ()],
        [*p_names, "loss"],
    )
    emit(
        "predict",
        lambda *a: (M.predict(spec, list(a[:k]), a[k]),),
        [*p_specs, x_spec],
        [*p_names, "x"],
        [(spec.batch, spec.classes)],
        ["logits"],
    )
    emit(
        "prune",
        lambda *a: M.prune_step(spec, list(a[:k]), a[k]),
        [*p_specs, scalar],
        [*p_names, "keep_frac"],
        p_shapes,
        p_names,
        extra_meta={
            "prunable_params": sum(
                int(jnp.prod(jnp.array(p.shape)))
                for p in params0
                if len(p.shape) == 2 and int(jnp.prod(jnp.array(p.shape))) >= 1024
            )
        },
    )
    return entries


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--variants",
        default=",".join(M.VARIANTS),
        help="comma-separated variant names (default: all)",
    )
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    wanted = [v for v in args.variants.split(",") if v]
    for v in wanted:
        if v not in M.VARIANTS:
            print(f"unknown variant '{v}'; have {list(M.VARIANTS)}", file=sys.stderr)
            return 1

    all_entries = []
    for v in wanted:
        print(f"lowering {v} ...", flush=True)
        all_entries += lower_variant(M.VARIANTS[v], out_dir)

    manifest_lines = ["# generated by python/compile/aot.py — do not edit"]
    for _name, _fname, lines, _meta, _ins, _outs in all_entries:
        manifest_lines += lines + [""]
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(
            {
                name: {
                    "file": fname,
                    "meta": meta,
                    "inputs": ins,
                    "outputs": outs,
                }
                for name, fname, _lines, meta, ins, outs in all_entries
            },
            f,
            indent=2,
        )
    print(f"wrote {len(all_entries)} artifacts + manifest to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
