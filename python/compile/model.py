"""Layer-2 JAX model: edge-model variants, loss, train step, prune step.

The paper trains ResNet-34 / VGG-16 / DenseNet-121 / MobileNetV2 on CIFAR-10,
CIFAR-100 and SVHN on a Jetson Orin Nano. That testbed is not available
here, so each backbone is substituted by an MLP proxy whose parameter count
preserves the paper's *ordering and ratios* (ResNet-34 > VGG-16 >
DenseNet-121 > MobileNetV2) at ~1/13 scale, plus one small CNN variant that
exercises the conv path. DESIGN.md §Substitutions records the mapping; the
systems behaviour the paper measures (retrained-sample counts, memory
footprints, energy ∝ samples) depends on relative model sizes and sample
counts, which the proxies preserve.

Every dense layer goes through the Layer-1 Pallas kernel
(``kernels.dense``), so the AOT-lowered HLO contains the kernel body.
Gradients flow through the kernel's ``custom_vjp``.

Conventions (shared with ``rust/src/runtime/session.rs``):
  * ``x`` is ``[batch, 3072]`` f32 (32x32x3 flattened, CIFAR/SVHN-shaped);
  * ``y`` is ``[batch]`` f32 class indices; ``y < 0`` marks a padded row
    that must not contribute to loss or gradients;
  * parameters are a flat list ``[w1, b1, w2, b2, ...]`` (conv variants
    prepend rank-4 conv kernels);
  * optimizer is plain SGD (the paper uses Adam; optimizer state would
    double every checkpoint stored on the device — see DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import kernels

IMG_FEATURES = 32 * 32 * 3  # 3072; CIFAR-10 / CIFAR-100 / SVHN all share it.


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """Static description of one AOT model variant."""

    name: str
    #: Paper backbone this variant proxies (documentation only).
    proxy_for: str
    #: Hidden layer widths; input is IMG_FEATURES, output is ``classes``.
    hidden: Tuple[int, ...]
    classes: int
    batch: int
    #: Conv stem: list of (out_channels, stride). Empty = pure MLP.
    conv: Tuple[Tuple[int, int], ...] = ()

    @property
    def features(self) -> int:
        return IMG_FEATURES


# Parameter-count ordering mirrors Table 2 of the paper:
#   ResNet-34 23.6M > VGG-16 15.0M > DenseNet-121 7.1M > MobileNetV2 2.2M
# at roughly 1/13 scale (see DESIGN.md §Substitutions).
VARIANTS: Dict[str, VariantSpec] = {
    v.name: v
    for v in [
        VariantSpec("resnet34_c10", "ResNet-34/CIFAR-10", (512, 256, 128), 10, 64),
        VariantSpec("resnet34_c100", "ResNet-34/CIFAR-100", (512, 256, 128), 100, 64),
        VariantSpec("vgg16_c10", "VGG-16/CIFAR-10", (384, 128), 10, 64),
        VariantSpec("vgg16_c100", "VGG-16/CIFAR-100", (384, 128), 100, 64),
        VariantSpec("densenet121_c100", "DenseNet-121/CIFAR-100", (192, 96), 100, 64),
        VariantSpec("mobilenetv2_c10", "MobileNetV2/CIFAR-10", (96,), 10, 64),
        VariantSpec(
            "cnn_c10", "conv-stem demo (e2e example)", (128,), 10, 32,
            conv=((16, 2), (32, 2)),
        ),
    ]
}


def layer_dims(spec: VariantSpec) -> List[Tuple[int, int]]:
    """(fan_in, fan_out) of each dense layer, conv stem included upstream."""
    if spec.conv:
        side = 32
        ch = 3
        for out_ch, stride in spec.conv:
            side //= stride
            ch = out_ch
        first = side * side * ch
    else:
        first = spec.features
    widths = [first, *spec.hidden, spec.classes]
    return list(zip(widths[:-1], widths[1:]))


def init_params(spec: VariantSpec, seed: jax.Array) -> List[jax.Array]:
    """He-normal initialization from an f32 seed scalar (AOT-friendly)."""
    key = jax.random.PRNGKey(seed.astype(jnp.int32))
    params: List[jax.Array] = []
    if spec.conv:
        ch = 3
        for out_ch, _stride in spec.conv:
            key, sub = jax.random.split(key)
            fan_in = 3 * 3 * ch
            k = jax.random.normal(sub, (3, 3, ch, out_ch), jnp.float32)
            params.append(k * jnp.sqrt(2.0 / fan_in))
            ch = out_ch
    for fan_in, fan_out in layer_dims(spec):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        params.append(w * jnp.sqrt(2.0 / fan_in))
        params.append(jnp.zeros((fan_out,), jnp.float32))
    return params


def _conv_stem(spec: VariantSpec, params: Sequence[jax.Array], x: jax.Array):
    """Apply the conv stem (plain XLA convs; dense layers use Pallas)."""
    n_conv = len(spec.conv)
    h = x.reshape(-1, 32, 32, 3)
    for i, (_out_ch, stride) in enumerate(spec.conv):
        h = jax.lax.conv_general_dilated(
            h,
            params[i],
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = jnp.maximum(h, 0.0)
    return h.reshape(h.shape[0], -1), n_conv


def predict(spec: VariantSpec, params: Sequence[jax.Array], x: jax.Array):
    """Logits ``[batch, classes]``; every dense layer is the Pallas kernel."""
    if spec.conv:
        h, n_conv = _conv_stem(spec, params, x)
    else:
        h, n_conv = x, 0
    dense_params = params[n_conv:]
    n_layers = len(dense_params) // 2
    for l in range(n_layers):
        w, b = dense_params[2 * l], dense_params[2 * l + 1]
        act = "relu" if l + 1 < n_layers else "none"
        h = kernels.dense(h, w, b, act)
    return h


def masked_cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Mean softmax CE over rows with ``y >= 0``; padded rows contribute 0."""
    classes = logits.shape[-1]
    valid = y >= 0.0
    labels = jnp.clip(y, 0.0, classes - 1.0).astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
    return jnp.sum(nll) / denom


def loss_fn(spec: VariantSpec, params, x, y):
    return masked_cross_entropy(predict(spec, params, x), y)


def train_step(spec: VariantSpec, params, x, y, lr):
    """One SGD step; returns (new_params..., loss)."""
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(spec, p, x, y)
    )(list(params))
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return (*new_params, loss)


def prunable(p: jax.Array) -> bool:
    """RCMP prunes the dense weight matrices (rank-2, non-trivial size)."""
    return p.ndim == 2 and p.size >= 1024


def prune_step(spec: VariantSpec, params, keep_frac):
    """Magnitude-prune each prunable tensor via the Pallas mask kernel.

    Uses the bisection threshold (`magnitude_prune_fast`): XLA-CPU's sort
    made the sort-based variant ~17x slower (EXPERIMENTS.md §Perf-L2).
    """
    return tuple(
        kernels.magnitude_prune_fast(p, keep_frac) if prunable(p) else p
        for p in params
    )


def param_count(spec: VariantSpec) -> int:
    n = 0
    if spec.conv:
        ch = 3
        for out_ch, _ in spec.conv:
            n += 3 * 3 * ch * out_ch
            ch = out_ch
    for fan_in, fan_out in layer_dims(spec):
        n += fan_in * fan_out + fan_out
    return n


def flops_per_example(spec: VariantSpec) -> int:
    """fwd+bwd FLOPs per example ~= 3 * 2 * sum(w_elems) for the MLP stack."""
    dense = sum(fi * fo for fi, fo in layer_dims(spec))
    conv = 0
    if spec.conv:
        side, ch = 32, 3
        for out_ch, stride in spec.conv:
            side //= stride
            conv += side * side * 3 * 3 * ch * out_ch
            ch = out_ch
    return 6 * (dense + conv)
