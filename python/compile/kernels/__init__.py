"""Layer-1 Pallas kernels (build-time only; never imported at runtime)."""

from .dense import dense, matmul, relu_mask  # noqa: F401
from .prune import (  # noqa: F401
    apply_threshold,
    fast_threshold,
    magnitude_prune,
    magnitude_prune_fast,
)
