"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has a reference implementation here with
the same semantics, written with nothing but ``jax.numpy``. The pytest suite
(``python/tests/test_kernel.py``) sweeps shapes and dtypes with hypothesis
and asserts ``allclose`` between kernel and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x, w, b=None, activation="none"):
    """act(x @ w + b) in plain jnp."""
    y = jnp.dot(x, w)
    if b is not None:
        y = y + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    return y


def ref_dense_vjp(x, w, b, g, activation="relu"):
    """Reference gradients of the fused dense layer via jax.vjp."""

    def f(x, w, b):
        return ref_matmul(x, w, b, activation)

    _, vjp = jax.vjp(f, x, w, b)
    return vjp(g)


def ref_magnitude_prune(w, keep_frac):
    """Keep the keep_frac largest-|w| entries, zero the rest (ties keep)."""
    flat = jnp.abs(w.reshape(-1))
    n = flat.shape[0]
    srt = jnp.sort(flat)
    drop = jnp.clip((1.0 - keep_frac) * n, 0, n)
    idx = jnp.clip(jnp.floor(drop).astype(jnp.int32), 0, n - 1)
    thr = jnp.where(drop >= n, jnp.inf, srt[idx])
    thr = jnp.where(keep_frac >= 1.0, -jnp.inf, thr)
    return jnp.where(jnp.abs(w) >= thr, w, 0.0)
