"""Layer-1 Pallas kernel: magnitude pruning (RCMP / OMP compute path).

The paper's RCMP compresses each trained sub-model by magnitude pruning
(identify smallest-|w| entries, remove them, fine-tune). The *identification*
step — a global quantile over |w| — is a tiny reduction done in plain jnp;
the *masking* sweep over the full weight tensor is the bandwidth-bound part
and is written as a row-tiled Pallas kernel so the whole prune step lowers
into one HLO artifact.

On a real TPU the mask sweep is a pure VMEM-streaming kernel (no MXU); the
tile size is chosen to keep one (bm, n) block resident per grid step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dense import _tile


def _mask_kernel(w_ref, thr_ref, o_ref):
    """Zero entries with |w| below the threshold scalar."""
    thr = thr_ref[0]
    w = w_ref[...]
    o_ref[...] = jnp.where(jnp.abs(w) >= thr, w, 0.0)


def apply_threshold(w: jax.Array, thr: jax.Array) -> jax.Array:
    """Pallas sweep: ``w * (|w| >= thr)`` for a rank-2 weight tensor."""
    m, n = w.shape
    bm = _tile(m, 128)
    return pl.pallas_call(
        _mask_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            # Threshold scalar broadcast to every grid step.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(w, thr.reshape(1))


def fast_threshold(w: jax.Array, keep_frac: jax.Array) -> jax.Array:
    """Magnitude threshold via bisection — no sort.

    XLA-CPU's sort is single-threaded and comparator-based (~170 ms for a
    300k tensor); 20 bisection rounds of fused compare+count reductions find
    the same threshold to ~1e-6 of the magnitude range in ~3 ms (see
    EXPERIMENTS.md §Perf-L2). The returned threshold is *consistent* (every
    kept magnitude >= every dropped one) with achieved keep fraction within
    1/2^20 of the request.
    """
    flat = jnp.abs(w.reshape(-1))
    n = flat.shape[0]
    target = keep_frac * n  # want count(|w| >= thr) ~= target

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((flat >= mid).astype(jnp.float32))
        too_many = cnt > target
        return (jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid))

    lo, hi = jax.lax.fori_loop(
        0, 20, body, (jnp.float32(0.0), jnp.max(flat) + 1e-6)
    )
    thr = 0.5 * (lo + hi)
    thr = jnp.where(keep_frac >= 1.0, jnp.float32(-jnp.inf), thr)
    thr = jnp.where(keep_frac <= 0.0, jnp.float32(jnp.inf), thr)
    return thr


def magnitude_prune_fast(w: jax.Array, keep_frac: jax.Array) -> jax.Array:
    """Production prune: bisection threshold + the Pallas mask sweep."""
    return apply_threshold(w, fast_threshold(w, keep_frac))


def magnitude_prune(w: jax.Array, keep_frac: jax.Array) -> jax.Array:
    """Keep the ``keep_frac`` largest-magnitude entries of ``w``, zero the rest.

    ``keep_frac`` is a traced f32 scalar in [0, 1] so a single AOT artifact
    serves every pruning rate the shard controller requests. The threshold is
    the (1 - keep_frac) quantile of |w|; ties keep the larger count (i.e.
    actual sparsity can be marginally below the request), matching the
    pure-jnp oracle in ``ref.py``.
    """
    flat = jnp.abs(w.reshape(-1))
    n = flat.shape[0]
    srt = jnp.sort(flat)  # ascending
    # Index of the first kept element; keep_frac=1 -> idx 0, 0 -> idx n.
    drop = jnp.clip((1.0 - keep_frac) * n, 0, n)
    idx = jnp.clip(jnp.floor(drop).astype(jnp.int32), 0, n - 1)
    thr = jnp.where(drop >= n, jnp.inf, srt[idx])
    # keep_frac == 1.0 exactly -> keep everything (threshold below min).
    thr = jnp.where(keep_frac >= 1.0, -jnp.inf, thr)
    return apply_threshold(w, thr)
