"""Layer-1 Pallas kernels: fused dense layers for the edge models.

The paper trains its edge models (ResNet-34 / VGG-16 / MobileNetV2 proxies)
on an NVIDIA Jetson Orin Nano; the compute hot-spot of the per-shard
(re)training loop is the dense matmul stack. Here that hot-spot is written as
Pallas kernels so it lowers into the same HLO artifact as the surrounding JAX
graph (see DESIGN.md §Hardware-Adaptation for the CUDA->TPU rethink: tiles
are sized for VMEM residency and the MXU 128x128 systolic array rather than
CUDA threadblocks/shared memory).

Kernels:
  * ``matmul``/``dense`` — fused ``act(x @ w + b)`` forward, tiled
    ``(bm, bn, bk)`` with K as the sequential innermost grid axis and the
    bias+activation epilogue fused into the final K step.
  * backward — ``dx = g @ w.T``, ``dw = x.T @ g``, ``db = sum(g)`` plus a
    Pallas relu-mask kernel, wired via ``jax.custom_vjp`` so ``jax.grad`` in
    Layer 2 differentiates straight through the Pallas call.

All kernels run with ``interpret=True`` (this image's PJRT is CPU-only; real
TPU lowering emits a Mosaic custom-call the CPU plugin cannot execute).
Correctness is pinned against the pure-jnp oracle in ``ref.py`` by
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Activation = Literal["relu", "none"]

# TPU-minded tile ceilings: the MXU is a 128x128 systolic array and VMEM is
# ~16 MiB/core. A (128, 128) f32 output tile plus (128, 512) lhs and
# (512, 128) rhs tiles is ~576 KiB — comfortably triple-bufferable.
_BM, _BN, _BK = 128, 128, 512


def _tile(dim: int, ceiling: int) -> int:
    """Largest divisor of ``dim`` that is <= ceiling.

    AOT shapes are static, so exact divisors are picked instead of padding;
    for the edge-model shapes (3072/1024/256/128/64, classes 10/100) this
    always finds a healthy tile.
    """
    if dim <= ceiling:
        return dim
    for cand in range(ceiling, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def matmul(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
           activation: Activation = "none") -> jax.Array:
    """Tiled Pallas ``act(x @ w + b)``; the building block for ``dense``.

    Grid = (M/bm, N/bn, K/bk); the output tile is revisited across the K
    axis and acts as the accumulator (f32). The epilogue (bias + activation)
    runs fused on the last K step — the Pallas analogue of a CUDA
    mainloop + epilogue split.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _tile(m, _BM), _tile(n, _BN), _tile(k, _BK)
    nk = k // bk

    def kernel(*refs):
        if b is not None:
            x_ref, w_ref, b_ref, o_ref = refs
        else:
            (x_ref, w_ref, o_ref), b_ref = refs, None
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

        @pl.when(kk == nk - 1)
        def _epilogue():
            out = o_ref[...]
            if b_ref is not None:
                out = out + b_ref[...]
            if activation == "relu":
                out = jnp.maximum(out, 0.0)
            o_ref[...] = out

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if b is not None:
        assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
        # Rank-2 bias so the block layout matches the output tile.
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args.append(b.reshape(1, n))

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(*args)


def _relu_mask_kernel(g_ref, y_ref, o_ref):
    """dL/d(pre-activation) = g * 1[y > 0] for the relu epilogue."""
    o_ref[...] = jnp.where(y_ref[...] > 0.0, g_ref[...], 0.0)


def relu_mask(g: jax.Array, y: jax.Array) -> jax.Array:
    """Elementwise backward mask as a Pallas kernel (row-tiled)."""
    m, n = g.shape
    bm = _tile(m, _BM)
    return pl.pallas_call(
        _relu_mask_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(g, y)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array,
          activation: Activation = "relu") -> jax.Array:
    """Fused dense layer ``act(x @ w + b)`` with Pallas forward and backward."""
    return matmul(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    y = matmul(x, w, b, activation)
    return y, (x, w, y)


def _dense_bwd(activation, res, g):
    x, w, y = res
    if activation == "relu":
        g = relu_mask(g, y)
    # dx = g @ w.T ; dw = x.T @ g ; db = sum_rows(g) — Pallas matmuls.
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
