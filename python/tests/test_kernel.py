"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (and the activation/bias space); every case
asserts allclose against ``ref.py``. This is the core correctness signal
for everything the Rust coordinator later executes through PJRT.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, magnitude_prune, matmul, relu_mask
from compile.kernels.ref import ref_dense_vjp, ref_magnitude_prune, ref_matmul

# Dimensions exercise tile boundaries: below, at, and above the (128, 512)
# ceilings, plus awkward primes.
DIMS_M = [1, 3, 17, 64, 128, 130]
DIMS_N = [1, 10, 96, 100, 128, 130]
DIMS_K = [1, 32, 100, 512, 515]


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from(DIMS_M),
    n=st.sampled_from(DIMS_N),
    k=st.sampled_from(DIMS_K),
    bias=st.booleans(),
    act=st.sampled_from(["none", "relu"]),
)
def test_matmul_matches_ref(m, n, k, bias, act):
    x = rand(m * 1000 + k, (m, k))
    w = rand(n * 7 + k, (k, n))
    b = rand(n, (n,)) if bias else None
    got = matmul(x, w, b, act)
    want = ref_matmul(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([2, 64, 128]),
    n=st.sampled_from([10, 96, 128]),
    k=st.sampled_from([32, 512]),
    act=st.sampled_from(["none", "relu"]),
)
def test_dense_gradients_match_ref(m, n, k, act):
    x = rand(1 + m, (m, k))
    w = rand(2 + n, (k, n))
    b = rand(3 + k, (n,))
    g = rand(4 + m + n, (m, n))

    def loss(x, w, b):
        return jnp.sum(dense(x, w, b, act) * g)

    dx, dw, db = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    rx, rw, rb = ref_dense_vjp(x, w, b, g, act)
    np.testing.assert_allclose(dx, rx, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(dw, rw, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(db, rb, rtol=3e-4, atol=3e-4)


def test_relu_mask_blocks_negative_preactivations():
    g = jnp.ones((4, 8), jnp.float32)
    y = jnp.array([[-1.0, 2.0] * 4] * 4, jnp.float32)
    out = relu_mask(g, y)
    assert float(out[0, 0]) == 0.0
    assert float(out[0, 1]) == 1.0


@settings(max_examples=30, deadline=None)
@given(
    m=st.sampled_from([8, 64, 127]),
    n=st.sampled_from([16, 100, 128]),
    keep=st.floats(min_value=0.0, max_value=1.0),
)
def test_magnitude_prune_matches_ref(m, n, keep):
    w = rand(m * n, (m, n))
    got = magnitude_prune(w, jnp.float32(keep))
    want = ref_magnitude_prune(w, jnp.float32(keep))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("keep", [0.0, 0.1, 0.3, 0.5, 0.9, 1.0])
def test_prune_sparsity_tracks_keep(keep):
    w = rand(99, (64, 128))
    out = np.asarray(magnitude_prune(w, jnp.float32(keep)))
    frac_kept = (out != 0).mean()
    assert abs(frac_kept - keep) < 0.02, (keep, frac_kept)


def test_prune_keeps_largest_magnitudes():
    w = jnp.array([[1.0, -5.0, 0.1, 3.0]], jnp.float32)
    out = np.asarray(magnitude_prune(w, jnp.float32(0.5)))
    assert out[0, 1] == -5.0 and out[0, 3] == 3.0
    assert out[0, 0] == 0.0 and out[0, 2] == 0.0


def test_prune_idempotent():
    w = rand(7, (32, 64))
    once = magnitude_prune(w, jnp.float32(0.4))
    twice = magnitude_prune(once, jnp.float32(0.4))
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_grad_through_pruned_dense_is_finite():
    # RCMP fine-tunes after pruning: gradients through sparse weights must
    # stay finite.
    x = rand(1, (8, 64))
    w = magnitude_prune(rand(2, (64, 32)), jnp.float32(0.3))
    b = jnp.zeros((32,), jnp.float32)

    def loss(w):
        return jnp.sum(dense(x, w, b, "relu"))

    g = jax.grad(loss)(w)
    assert np.isfinite(np.asarray(g)).all()


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 64, 127]),
    n=st.sampled_from([16, 100, 128]),
    keep=st.floats(min_value=0.05, max_value=0.95),
)
def test_fast_prune_is_threshold_consistent(m, n, keep):
    """The bisection prune keeps exactly a top-magnitude set of the right size."""
    from compile.kernels import magnitude_prune_fast

    w = rand(m * n + 1, (m, n))
    out = np.asarray(magnitude_prune_fast(w, jnp.float32(keep)))
    aw = np.abs(np.asarray(w))
    kept = out != 0
    if kept.any() and (~kept).any():
        assert aw[kept].min() >= aw[~kept].max() - 1e-7
    achieved = kept.mean()
    assert abs(achieved - keep) < 5e-3, (keep, achieved)


def test_fast_prune_matches_exact_on_distinct_magnitudes():
    from compile.kernels import magnitude_prune_fast

    w = jnp.arange(1.0, 129.0, dtype=jnp.float32).reshape(8, 16) * jnp.where(
        jnp.arange(128).reshape(8, 16) % 2 == 0, 1.0, -1.0
    )
    exact = np.asarray(ref_magnitude_prune(w, jnp.float32(0.5)))
    fast = np.asarray(magnitude_prune_fast(w, jnp.float32(0.5)))
    np.testing.assert_array_equal(exact, fast)
