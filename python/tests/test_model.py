"""Layer-2 correctness: model variants, masked loss, train step, pruning."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def spec():
    return M.VARIANTS["mobilenetv2_c10"]


def make_batch(spec, n, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (spec.batch, spec.features), jnp.float32)
    y = jnp.where(
        jnp.arange(spec.batch) < n,
        jax.random.randint(ky, (spec.batch,), 0, spec.classes).astype(jnp.float32),
        -1.0,
    )
    return x, y


def test_variant_catalog_is_consistent():
    # Proxy parameter ordering mirrors Table 2 of the paper.
    count = lambda name: M.param_count(M.VARIANTS[name])
    assert count("resnet34_c10") > count("vgg16_c10")
    assert count("vgg16_c10") > count("densenet121_c100")
    assert count("densenet121_c100") > count("mobilenetv2_c10")
    for spec in M.VARIANTS.values():
        params = M.init_params(spec, jnp.float32(0))
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == M.param_count(spec), spec.name
        assert M.flops_per_example(spec) > 0


def test_init_is_seed_deterministic(spec):
    a = M.init_params(spec, jnp.float32(5))
    b = M.init_params(spec, jnp.float32(5))
    c = M.init_params(spec, jnp.float32(6))
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    assert any(
        not np.array_equal(np.asarray(pa), np.asarray(pc)) for pa, pc in zip(a, c)
    )


def test_predict_shapes_all_variants():
    for spec in M.VARIANTS.values():
        params = M.init_params(spec, jnp.float32(1))
        x = jnp.zeros((spec.batch, spec.features), jnp.float32)
        logits = M.predict(spec, params, x)
        assert logits.shape == (spec.batch, spec.classes), spec.name


def test_masked_loss_ignores_padding(spec):
    params = M.init_params(spec, jnp.float32(2))
    x, y = make_batch(spec, spec.batch // 2, seed=1)
    # Zero out padded rows' features: loss must not change.
    mask = (y >= 0)[:, None]
    x_zeroed = jnp.where(mask, x, 0.0)
    l1 = M.loss_fn(spec, params, x, y)
    l2 = M.loss_fn(spec, params, x_zeroed, y)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    # Gradients likewise.
    g1 = jax.grad(lambda p: M.loss_fn(spec, p, x, y))(params)
    g2 = jax.grad(lambda p: M.loss_fn(spec, p, x_zeroed, y))(params)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_all_padded_batch_gives_zero_loss(spec):
    params = M.init_params(spec, jnp.float32(3))
    x = jnp.zeros((spec.batch, spec.features), jnp.float32)
    y = -jnp.ones((spec.batch,), jnp.float32)
    assert float(M.loss_fn(spec, params, x, y)) == 0.0


def test_train_step_reduces_loss(spec):
    params = list(M.init_params(spec, jnp.float32(4)))
    x, y = make_batch(spec, spec.batch, seed=2)
    first = float(M.loss_fn(spec, params, x, y))
    for _ in range(15):
        out = M.train_step(spec, params, x, y, jnp.float32(0.05))
        params = list(out[:-1])
    last = float(out[-1])
    assert last < first * 0.7, (first, last)


def test_prune_step_only_touches_prunable(spec):
    params = M.init_params(spec, jnp.float32(5))
    pruned = M.prune_step(spec, params, jnp.float32(0.3))
    for p, q in zip(params, pruned):
        if M.prunable(p):
            frac = float((np.asarray(q) != 0).mean())
            assert abs(frac - 0.3) < 0.02
        else:
            np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_conv_variant_trains():
    spec = M.VARIANTS["cnn_c10"]
    params = list(M.init_params(spec, jnp.float32(6)))
    x, y = make_batch(spec, spec.batch, seed=3)
    first = float(M.loss_fn(spec, params, x, y))
    for _ in range(10):
        out = M.train_step(spec, params, x, y, jnp.float32(0.05))
        params = list(out[:-1])
    assert float(out[-1]) < first, "conv variant failed to learn"
