//! L3 perf microbenches: the coordinator's hot paths.
//!
//! These feed EXPERIMENTS.md §Perf — victim selection, partitioning,
//! lineage bookkeeping, checkpoint-store operations, and the end-to-end
//! cost-mode round/request loop.

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::partition::{Partitioner, Ucdp, Uniform};
use cause::replacement::{FiboR, ReplacementPolicy};
use cause::unlearning::{BatchPlanner, BatchPolicy, UnlearningService};
use cause::util::bench::{black_box, Bench};

/// Run the burst workload through the service under one batch policy;
/// returns (total RSN, requests served).
fn run_burst(
    cfg: &ExperimentConfig,
    pop: &EdgePopulation,
    trace: &RequestTrace,
    policy: BatchPolicy,
) -> (u64, usize) {
    let engine = SystemVariant::Cause.build_cost(cfg).unwrap();
    let mut svc = UnlearningService::new(engine).with_planner(BatchPlanner::new(policy, 0));
    let mut served = 0;
    for t in 1..=cfg.rounds {
        svc.ingest_round(pop).unwrap();
        for req in trace.at(t) {
            svc.submit(req.clone());
        }
        served += svc.drain_batched().unwrap();
    }
    (svc.engine().metrics.total_rsn(), served)
}

fn main() {
    let mut b = Bench::new("coordinator-hot-paths");

    // FiboR victim selection (called once per checkpoint store when full).
    b.iter("fibor_victim_x10k", 50, || {
        let mut f = FiboR::new();
        let mut acc = 0usize;
        for _ in 0..10_000 {
            acc = acc.wrapping_add(f.victim(64).unwrap());
        }
        black_box(acc)
    });

    // Partitioner assignment over one paper-scale round.
    let cfg = ExperimentConfig::default();
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.clone(),
        users: 100,
        rounds: 10,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.7,
        seed: 1,
    });
    b.iter("ucdp_assign_full_trace", 50, || {
        let mut p = Ucdp::new(4, 7);
        let mut n = 0;
        for r in 1..=10 {
            n += p.assign(pop.blocks_at(r), 4).len();
        }
        black_box(n)
    });
    b.iter("uniform_assign_full_trace", 50, || {
        let mut p = Uniform::new(4);
        let mut n = 0;
        for r in 1..=10 {
            n += p.assign(pop.blocks_at(r), 4).len();
        }
        black_box(n)
    });

    // End-to-end cost-mode runs (the engine loop the sweeps hammer).
    for (label, v) in [
        ("engine_cause_paper_default", SystemVariant::Cause),
        ("engine_sisa_paper_default", SystemVariant::Sisa),
        ("engine_arcane_paper_default", SystemVariant::Arcane),
    ] {
        b.iter(label, 10, || {
            let cfg = ExperimentConfig::default();
            let pop = cause::experiments::common::population(&cfg);
            let trace = RequestTrace::generate(
                &pop,
                &TraceConfig::paper_default(cfg.seed ^ 0x7ace).with_prob(cfg.unlearn_prob),
            );
            let mut engine = v.build_cost(&cfg).unwrap();
            engine.run_trace(&pop, &trace).unwrap();
            black_box(engine.metrics.total_rsn())
        });
    }

    // Batched unlearning: the shared seeded same-round burst over few
    // lineages (experiments::common::burst_workload — the same workload
    // tests/batched_unlearning.rs asserts the strict inequality on). The
    // coalescing win: one retrain per lineage per window instead of one
    // per request.
    let (burst_cfg, burst_pop, burst_trace) = cause::experiments::common::burst_workload();
    let (fcfs_rsn, fcfs_served) =
        run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Fcfs);
    let (coal_rsn, coal_served) =
        run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Coalesce);
    println!(
        "batched unlearning burst ({} requests / {} shards): \
         FCFS RSN {} vs Coalesce RSN {} ({:.2}x fewer samples replayed)",
        fcfs_served,
        burst_cfg.shards,
        fcfs_rsn,
        coal_rsn,
        fcfs_rsn as f64 / coal_rsn.max(1) as f64
    );
    assert_eq!(fcfs_served, coal_served);
    b.iter("service_burst_fcfs", 10, || {
        black_box(run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Fcfs))
    });
    b.iter("service_burst_coalesce", 10, || {
        black_box(run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Coalesce))
    });

    // Population + trace generation (dominates sweep setup cost).
    b.iter("population_generate_50k", 10, || {
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: cfg.dataset.clone(),
            users: 100,
            rounds: 10,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed: 2,
        });
        black_box(pop.total_samples())
    });

    b.report();
}
