//! L3 perf microbenches: the coordinator's hot paths.
//!
//! These feed EXPERIMENTS.md §Perf — victim selection, partitioning,
//! lineage bookkeeping, checkpoint-store operations, and the end-to-end
//! cost-mode round/request loop.

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::partition::{Partitioner, Ucdp, Uniform};
use cause::replacement::{FiboR, ReplacementPolicy};
use cause::unlearning::{BatchPlanner, BatchPolicy, UnlearningService};
use cause::util::bench::{black_box, Bench};
use cause::util::Json;

/// One point of the SLO sweep: service-level latency vs coalescing win.
struct SloPoint {
    label: String,
    slo: Option<u64>,
    requests: u64,
    rsn: u64,
    lineages_retrained: u64,
    retrains_coalesced: u64,
    queue_p50: f64,
    queue_p99: f64,
    slo_violations: u64,
}

impl SloPoint {
    fn retrains_per_request(&self) -> f64 {
        self.lineages_retrained as f64 / self.requests.max(1) as f64
    }

    fn to_json(&self) -> Json {
        Json::obj()
            .set("label", self.label.as_str())
            .set(
                "slo",
                self.slo.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
            )
            .set("requests", self.requests)
            .set("rsn", self.rsn)
            .set("lineages_retrained", self.lineages_retrained)
            .set("retrains_coalesced", self.retrains_coalesced)
            .set("retrains_per_request", self.retrains_per_request())
            .set("queue_p50", self.queue_p50)
            .set("queue_p99", self.queue_p99)
            .set("slo_violations", self.slo_violations)
    }
}

/// Drive the burst workload with one-tick request inter-arrivals: each
/// request is submitted, the service drains (a deadline policy holds the
/// window while every queued request still has SLO slack), and the clock
/// advances one tick. Stragglers are flushed at end of run.
fn run_slo_point(
    cfg: &ExperimentConfig,
    pop: &EdgePopulation,
    trace: &RequestTrace,
    label: &str,
    policy: BatchPolicy,
) -> SloPoint {
    let engine = SystemVariant::Cause.build_cost(cfg).unwrap();
    let mut svc = UnlearningService::new(engine).with_planner(BatchPlanner::new(policy, 0));
    for t in 1..=cfg.rounds {
        // The service is polled at every tick the clock reaches (each
        // advance and each round ingest), so a deadline window can close
        // exactly at its SLO bound, never past it.
        svc.ingest_round(pop).unwrap();
        svc.drain_batched().unwrap();
        for req in trace.at(t) {
            svc.submit(req.clone());
            svc.drain_batched().unwrap();
            svc.advance(1);
            svc.drain_batched().unwrap();
        }
    }
    svc.flush_batched().unwrap();
    assert_eq!(svc.pending(), 0, "{label}: queue must drain");
    let m = &svc.engine().metrics;
    let delays = m.queue_delay_summary();
    SloPoint {
        label: label.to_string(),
        slo: policy.slo(),
        requests: m.total_requests(),
        rsn: m.total_rsn(),
        lineages_retrained: m.lineages_retrained,
        retrains_coalesced: m.retrains_coalesced,
        queue_p50: delays.p50,
        queue_p99: delays.p99,
        slo_violations: m.slo_violations(),
    }
}

/// Run the burst workload through the service under one batch policy;
/// returns (total RSN, requests served).
fn run_burst(
    cfg: &ExperimentConfig,
    pop: &EdgePopulation,
    trace: &RequestTrace,
    policy: BatchPolicy,
) -> (u64, usize) {
    let engine = SystemVariant::Cause.build_cost(cfg).unwrap();
    let mut svc = UnlearningService::new(engine).with_planner(BatchPlanner::new(policy, 0));
    let mut served = 0;
    for t in 1..=cfg.rounds {
        svc.ingest_round(pop).unwrap();
        for req in trace.at(t) {
            svc.submit(req.clone());
        }
        served += svc.drain_batched().unwrap();
    }
    (svc.engine().metrics.total_rsn(), served)
}

fn main() {
    let mut b = Bench::new("coordinator-hot-paths");

    // FiboR victim selection (called once per checkpoint store when full).
    b.iter("fibor_victim_x10k", 50, || {
        let mut f = FiboR::new();
        let mut acc = 0usize;
        for _ in 0..10_000 {
            acc = acc.wrapping_add(f.victim(64).unwrap());
        }
        black_box(acc)
    });

    // Partitioner assignment over one paper-scale round.
    let cfg = ExperimentConfig::default();
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.clone(),
        users: 100,
        rounds: 10,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.7,
        seed: 1,
    });
    b.iter("ucdp_assign_full_trace", 50, || {
        let mut p = Ucdp::new(4, 7);
        let mut n = 0;
        for r in 1..=10 {
            n += p.assign(pop.blocks_at(r), 4).len();
        }
        black_box(n)
    });
    b.iter("uniform_assign_full_trace", 50, || {
        let mut p = Uniform::new(4);
        let mut n = 0;
        for r in 1..=10 {
            n += p.assign(pop.blocks_at(r), 4).len();
        }
        black_box(n)
    });

    // End-to-end cost-mode runs (the engine loop the sweeps hammer).
    for (label, v) in [
        ("engine_cause_paper_default", SystemVariant::Cause),
        ("engine_sisa_paper_default", SystemVariant::Sisa),
        ("engine_arcane_paper_default", SystemVariant::Arcane),
    ] {
        b.iter(label, 10, || {
            let cfg = ExperimentConfig::default();
            let pop = cause::experiments::common::population(&cfg);
            let trace = RequestTrace::generate(
                &pop,
                &TraceConfig::paper_default(cfg.seed ^ 0x7ace).with_prob(cfg.unlearn_prob),
            );
            let mut engine = v.build_cost(&cfg).unwrap();
            engine.run_trace(&pop, &trace).unwrap();
            black_box(engine.metrics.total_rsn())
        });
    }

    // Batched unlearning: the shared seeded same-round burst over few
    // lineages (experiments::common::burst_workload — the same workload
    // tests/batched_unlearning.rs asserts the strict inequality on). The
    // coalescing win: one retrain per lineage per window instead of one
    // per request.
    let (burst_cfg, burst_pop, burst_trace) = cause::experiments::common::burst_workload();
    let (fcfs_rsn, fcfs_served) =
        run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Fcfs);
    let (coal_rsn, coal_served) =
        run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Coalesce);
    println!(
        "batched unlearning burst ({} requests / {} shards): \
         FCFS RSN {} vs Coalesce RSN {} ({:.2}x fewer samples replayed)",
        fcfs_served,
        burst_cfg.shards,
        fcfs_rsn,
        coal_rsn,
        fcfs_rsn as f64 / coal_rsn.max(1) as f64
    );
    assert_eq!(fcfs_served, coal_served);
    b.iter("service_burst_fcfs", 10, || {
        black_box(run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Fcfs))
    });
    b.iter("service_burst_coalesce", 10, || {
        black_box(run_burst(&burst_cfg, &burst_pop, &burst_trace, BatchPolicy::Coalesce))
    });

    // Deadline SLO sweep: per-request latency (queueing delay, ticks) vs
    // the coalescing win, on the same burst workload with one-tick
    // inter-arrivals. FCFS is the slo=0 degenerate point; growing the SLO
    // trades bounded queueing delay for strictly fewer lineage retrains
    // per request.
    let fcfs_point =
        run_slo_point(&burst_cfg, &burst_pop, &burst_trace, "fcfs", BatchPolicy::Fcfs);
    let mut sweep = vec![fcfs_point];
    for slo in [0u64, 1, 2, 4, 8] {
        let label = format!("deadline_slo{slo}");
        sweep.push(run_slo_point(
            &burst_cfg,
            &burst_pop,
            &burst_trace,
            &label,
            BatchPolicy::Deadline { slo_ticks: slo },
        ));
    }
    println!("\nSLO sweep (burst workload, 1 req/tick):");
    println!(
        "  {:<16} {:>9} {:>10} {:>10} {:>12} {:>9} {:>9} {:>6}",
        "policy", "requests", "retrains", "coalesced", "retrain/req", "p50", "p99", "viol"
    );
    for p in &sweep {
        println!(
            "  {:<16} {:>9} {:>10} {:>10} {:>12.3} {:>9.1} {:>9.1} {:>6}",
            p.label,
            p.requests,
            p.lineages_retrained,
            p.retrains_coalesced,
            p.retrains_per_request(),
            p.queue_p50,
            p.queue_p99,
            p.slo_violations
        );
    }
    let fcfs = &sweep[0];
    for p in &sweep[1..] {
        let slo = p.slo.expect("sweep points are deadline policies");
        assert_eq!(p.requests, fcfs.requests, "{}: all requests served", p.label);
        assert_eq!(p.slo_violations, 0, "{}: deadline policy met its SLO", p.label);
        assert!(
            p.queue_p99 <= slo as f64,
            "{}: p99 queueing delay {} exceeds SLO {slo}",
            p.label,
            p.queue_p99
        );
        assert!(
            p.lineages_retrained <= fcfs.lineages_retrained,
            "{}: deadline must never retrain more than FCFS",
            p.label
        );
    }
    // slo=0 IS the FCFS service model (equal point of the frontier)...
    assert_eq!(sweep[1].lineages_retrained, fcfs.lineages_retrained);
    assert_eq!(sweep[1].rsn, fcfs.rsn);
    assert_eq!(sweep[1].queue_p99, fcfs.queue_p99);
    // ...and any real slack strictly dominates FCFS on retrains/request.
    let widest = sweep.last().expect("sweep is non-empty");
    assert!(
        widest.lineages_retrained < fcfs.lineages_retrained,
        "slo={} must coalesce strictly below FCFS ({} vs {})",
        widest.slo.unwrap_or(0),
        widest.lineages_retrained,
        fcfs.lineages_retrained
    );
    assert!(widest.retrains_coalesced > 0);

    // Population + trace generation (dominates sweep setup cost).
    b.iter("population_generate_50k", 10, || {
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: cfg.dataset.clone(),
            users: 100,
            rounds: 10,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed: 2,
        });
        black_box(pop.total_samples())
    });

    b.report();

    // Machine-readable summary for the CI bench-regression gate
    // (`bench_gate` compares it against the committed BENCH_baseline.json:
    // coalescing must not drop, p99 queueing delay must not grow > 20%).
    // Only deterministic workload counters go in — never wall-clock times.
    let gate_point = sweep
        .iter()
        .find(|p| p.label == "deadline_slo4")
        .expect("sweep contains the slo=4 gate point");
    let summary = Json::obj()
        .set("bench", "coordinator")
        .set(
            "burst",
            Json::obj()
                .set("requests", fcfs_served)
                .set("fcfs_rsn", fcfs_rsn)
                .set("coalesce_rsn", coal_rsn),
        )
        .set("slo_sweep", Json::Arr(sweep.iter().map(|p| p.to_json()).collect()))
        .set(
            "gate",
            Json::obj()
                .set("retrains_coalesced", gate_point.retrains_coalesced)
                .set("p99_queue_delay", gate_point.queue_p99),
        );
    // Cargo runs bench binaries with cwd = the package root (rust/), but
    // CI's upload and gate steps read the file from the workspace root —
    // anchor the default there instead of relying on the cwd.
    let out_path = std::env::var("CAUSE_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_coordinator.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");
}
