//! bench_scale — planner-throughput macro-bench.
//!
//! CAUSE's planner (plan → price → execute) is on the hot path once
//! batching coalesces requests: every deadline window is priced by the
//! chain resolver once per admission retry. This bench grows a large
//! state (hundreds of rounds, eviction-heavy store, bursty coalesced
//! windows) and measures:
//!
//! 1. **Probe microsection** — `Engine::plan_lineage_rsn` (index-backed:
//!    store coverage index + lineage prefix sums, allocation-free) against
//!    the compiled-in naive-scan oracle `Engine::resolve_plan_naive`
//!    (O(slots) store scans + materialized replay vectors — the pre-index
//!    planner). Asserts byte-identical pricing and a ≥ 5x speedup.
//! 2. **End-to-end requests/sec** — the full plan→price→execute loop over
//!    the bursty workload, priced indexed vs naive (PRICINGS_PER_WINDOW
//!    models the admission retries a held deadline window pays). Asserts
//!    identical execution receipts and an indexed throughput gain.
//!
//! Writes `BENCH_scale.json`; `gate.probe_speedup` (a same-machine ratio,
//! so it is stable across runner hardware unlike absolute wall-clock) is
//! checked by `bench_gate` against the committed `BENCH_baseline.json`.

use std::time::Instant;

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::coordinator::Engine;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::unlearning::BatchPlan;
use cause::util::bench::{black_box, Bench};
use cause::util::Json;

/// Admission retries a held window is priced through (deadline policies
/// re-price on every drain poll while the window holds; battery splits add
/// more). Applied to both pricing paths in the end-to-end drive.
const PRICINGS_PER_WINDOW: usize = 8;

fn fast() -> bool {
    std::env::var("CAUSE_BENCH_FAST").is_ok()
}

/// Hundreds of rounds, 8 lineages, a store small enough to evict
/// constantly, and a request trace heavy enough that every round's window
/// coalesces several requests. `age_decay` is turned up so requests keep
/// reaching old time slots: under an evicting store the checkpoint below
/// an old poisoned segment is usually gone, so chains replay long segment
/// ranges — the regime where scan-based pricing materializes thousands of
/// placements per probe and the indices matter.
fn workload() -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    let rounds: u32 = if fast() { 120 } else { 240 };
    let cfg = ExperimentConfig {
        users: 160,
        rounds,
        shards: 8,
        unlearn_prob: 0.5,
        ..Default::default()
    }
    .with_memory_gb(1.0); // ~30 slots for 8 lineages x `rounds` checkpoints
    let pop = EdgePopulation::generate(PopulationConfig {
        // Large sample pool so repeatedly-hit blocks never fully deplete
        // (depleted blocks would thin the late-round bursts out).
        spec: cfg.dataset.scaled(400_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.7,
        seed: 0x5ca1e,
    });
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig {
            unlearn_prob: cfg.unlearn_prob,
            block_incl_prob: 0.9,
            age_decay: 0.9,
            frac_range: (0.1, 0.5),
            seed: 0x5ca1e ^ 0x7ace,
        },
    );
    (cfg, pop, trace)
}

/// Evolve an engine through the whole trace, serving requests FCFS up to
/// `rounds - holdout`, then merge the held-out bursts into coalesced
/// window plans. Collection removes their samples, so pricing the
/// returned plans afterwards is read-only and repeatable.
fn build_probe_state(
    cfg: &ExperimentConfig,
    pop: &EdgePopulation,
    trace: &RequestTrace,
    holdout: u32,
) -> (Engine, Vec<BatchPlan>) {
    let mut engine = SystemVariant::Cause.build_cost(cfg).unwrap();
    let serve_through = cfg.rounds - holdout;
    for t in 1..=cfg.rounds {
        engine.run_round(pop).unwrap();
        if t <= serve_through {
            for req in trace.at(t) {
                engine.process_request(req).unwrap();
            }
        }
    }
    let held: Vec<_> = (serve_through + 1..=cfg.rounds)
        .flat_map(|t| trace.at(t).iter().cloned())
        .collect();
    let plans: Vec<BatchPlan> = held
        .chunks(16)
        .map(|w| BatchPlan::collect(&mut engine, w))
        .filter(|p| !p.is_empty())
        .collect();
    (engine, plans)
}

/// The bursty coalesced-window service loop: per round, merge the round's
/// burst into one plan, price it PRICINGS_PER_WINDOW times (indexed or
/// naive), execute. Returns (secs, requests served, total RSN).
fn e2e_drive(
    cfg: &ExperimentConfig,
    pop: &EdgePopulation,
    trace: &RequestTrace,
    naive_pricing: bool,
) -> (f64, u64, u64) {
    let mut engine = SystemVariant::Cause.build_cost(cfg).unwrap();
    let t0 = Instant::now();
    for t in 1..=cfg.rounds {
        engine.run_round(pop).unwrap();
        let reqs = trace.at(t);
        if reqs.is_empty() {
            continue;
        }
        let plan = BatchPlan::collect(&mut engine, reqs);
        for _ in 0..PRICINGS_PER_WINDOW {
            let priced: u64 = if naive_pricing {
                engine.resolve_plan_naive(&plan).lineage_rsn.iter().sum()
            } else {
                engine.plan_lineage_rsn(&plan).iter().sum()
            };
            black_box(priced);
        }
        let outcome = engine.execute_plan(&plan).unwrap();
        engine.metrics.record_requests(reqs.len() as u64, outcome.rsn);
    }
    let secs = t0.elapsed().as_secs_f64();
    (secs, engine.metrics.total_requests(), engine.metrics.total_rsn())
}

fn main() {
    let mut b = Bench::new("planner-scale");
    let (cfg, pop, trace) = workload();

    // --- Probe microsection: indexed vs the naive-scan oracle ----------
    let holdout = cfg.rounds / 6;
    let (engine, plans) = build_probe_state(&cfg, &pop, &trace, holdout);
    assert!(plans.len() >= 4, "workload produced too few probe windows");
    let probes_per_pass = plans.len();

    // Differential check first (outside the timed loops): the indexed
    // probe must price every window exactly like the scan-based planner.
    for plan in &plans {
        let indexed = engine.plan_lineage_rsn(plan);
        let naive = engine.resolve_plan_naive(plan);
        assert_eq!(indexed, naive.lineage_rsn, "indexed probe diverged from scan oracle");
    }

    let idx_reps = if fast() { 30 } else { 300 };
    let naive_reps = if fast() { 3 } else { 15 };
    let mut checksum = 0u64;
    let mut idx_samples = Vec::with_capacity(idx_reps);
    for _ in 0..idx_reps {
        let t0 = Instant::now();
        let mut sum = 0u64;
        for plan in &plans {
            sum += engine.plan_lineage_rsn(plan).iter().sum::<u64>();
        }
        idx_samples.push(t0.elapsed().as_secs_f64());
        checksum = checksum.wrapping_add(black_box(sum));
    }
    let mut naive_samples = Vec::with_capacity(naive_reps);
    for _ in 0..naive_reps {
        let t0 = Instant::now();
        let mut sum = 0u64;
        for plan in &plans {
            sum += engine.resolve_plan_naive(plan).lineage_rsn.iter().sum::<u64>();
        }
        naive_samples.push(t0.elapsed().as_secs_f64());
        checksum = checksum.wrapping_add(black_box(sum));
    }
    black_box(checksum);
    b.record("probe_pass_indexed", &idx_samples);
    b.record("probe_pass_naive", &naive_samples);

    // Best-of-reps: the min pass is the least scheduler-noise-polluted
    // measurement on both sides, keeping the gated ratio stable.
    let idx_best = idx_samples.iter().fold(f64::INFINITY, |acc, &s| acc.min(s));
    let naive_best = naive_samples.iter().fold(f64::INFINITY, |acc, &s| acc.min(s));
    let idx_probe_secs = idx_best / probes_per_pass as f64;
    let naive_probe_secs = naive_best / probes_per_pass as f64;
    let speedup = naive_probe_secs / idx_probe_secs;
    println!(
        "plan-probe: indexed {:.0} ns vs naive {:.0} ns per merged-window probe \
         ({speedup:.1}x over {probes_per_pass} windows)",
        idx_probe_secs * 1e9,
        naive_probe_secs * 1e9,
    );

    // --- End-to-end: bursty coalesced windows, priced both ways --------
    // Best-of-2 interleaved drives per side: the min is the least
    // noise-polluted run, so the throughput comparison below is robust on
    // shared CI runners (a single unrepeated wall-clock sample is not).
    let (idx_secs_a, idx_requests, idx_rsn) = e2e_drive(&cfg, &pop, &trace, false);
    let (naive_secs_a, naive_requests, naive_rsn) = e2e_drive(&cfg, &pop, &trace, true);
    let (idx_secs_b, _, idx_rsn_b) = e2e_drive(&cfg, &pop, &trace, false);
    let (naive_secs_b, _, naive_rsn_b) = e2e_drive(&cfg, &pop, &trace, true);
    assert_eq!(idx_requests, naive_requests, "both drives serve the same trace");
    assert_eq!(idx_rsn, naive_rsn, "pricing path must not change execution receipts");
    assert_eq!(idx_rsn, idx_rsn_b, "drives are deterministic");
    assert_eq!(naive_rsn, naive_rsn_b, "drives are deterministic");
    let idx_secs = idx_secs_a.min(idx_secs_b);
    let naive_secs = naive_secs_a.min(naive_secs_b);
    b.record("e2e_indexed", &[idx_secs_a, idx_secs_b]);
    b.record("e2e_naive_pricing", &[naive_secs_a, naive_secs_b]);
    let idx_rps = idx_requests as f64 / idx_secs;
    let naive_rps = naive_requests as f64 / naive_secs;
    println!(
        "end-to-end ({idx_requests} requests, {} windows/round pricing x{PRICINGS_PER_WINDOW}): \
         indexed {idx_rps:.0} req/s vs naive-priced {naive_rps:.0} req/s ({:.2}x)",
        cfg.rounds,
        idx_rps / naive_rps,
    );

    b.report();

    // Machine-readable summary. `gate.probe_speedup` is a same-machine
    // ratio (indexed vs naive on identical state), so the regression gate
    // stays stable across runner hardware; absolute ns and req/s are
    // informational only.
    let summary = Json::obj()
        .set("bench", "scale")
        .set(
            "workload",
            Json::obj()
                .set("rounds", cfg.rounds as u64)
                .set("users", cfg.users)
                .set("shards", cfg.shards)
                .set("store_slots", engine.store().capacity())
                .set("probe_windows", probes_per_pass),
        )
        .set(
            "probe",
            Json::obj()
                .set("indexed_ns", idx_probe_secs * 1e9)
                .set("naive_ns", naive_probe_secs * 1e9)
                .set("speedup", speedup),
        )
        .set(
            "e2e",
            Json::obj()
                .set("requests", idx_requests)
                .set("indexed_rps", idx_rps)
                .set("naive_rps", naive_rps)
                .set("gain", idx_rps / naive_rps)
                .set("pricings_per_window", PRICINGS_PER_WINDOW),
        )
        .set("gate", Json::obj().set("probe_speedup", speedup));
    let out_path = std::env::var("CAUSE_BENCH_SCALE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scale.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");

    // Acceptance gates (after the report/JSON so failures are diagnosable).
    assert!(
        speedup >= 5.0,
        "indexed probe must beat the naive scan oracle by >=5x, got {speedup:.2}x"
    );
    assert!(
        idx_rps > naive_rps,
        "indexed pricing must raise end-to-end throughput \
         ({idx_rps:.0} vs {naive_rps:.0} req/s)"
    );
}
