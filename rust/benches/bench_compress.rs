//! bench_compress — checkpoint codec + byte-budget store macro-bench.
//!
//! Two sections:
//!
//! 1. **Codec microsection** — encode/decode throughput and compression
//!    ratio of `TensorCodec` on ~1 MB parameter sets magnitude-masked at
//!    keep ∈ {1.0, 0.3, 0.05} (the paper's dense / δ=70% / δ=95% points),
//!    plus a delta-encoding point against a lightly-perturbed parent.
//!    Round-trips are asserted bit-exact (`PartialEq`) before timing.
//!    `gate.ratio` (keep=0.3 compression ratio, a deterministic function
//!    of the seeded tensors) and `gate.decode_mbps` are checked by
//!    `bench_gate` against the committed `BENCH_baseline.json`.
//! 2. **Byte-budget workload** — the same C_m driven through a full
//!    engine lifecycle twice with the tensor-carrying `HostTrainer` at
//!    keep=0.3: once slot-metered (slots provisioned for the codec's
//!    dense fallback — the paper's N_mem normalization), once
//!    byte-metered (admission reasons in true encoded bytes). Asserts the
//!    byte meter keeps ≥2x the checkpoints resident and converts them
//!    into strictly lower RSN.
//!
//! Writes `BENCH_compress.json` for CI upload and the regression gate.

use std::time::Instant;

use cause::config::ExperimentConfig;
use cause::coordinator::engine::EvalPolicy;
use cause::coordinator::system::SystemVariant;
use cause::coordinator::Engine;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::memory::StoreMeter;
use cause::prng::Rng;
use cause::runtime::codec::{CodecMode, TensorCodec};
use cause::runtime::HostTensor;
use cause::training::host::dense_upper_bound;
use cause::training::{HostTrainer, HostTrainerConfig};
use cause::util::bench::{black_box, Bench};
use cause::util::Json;

fn fast() -> bool {
    std::env::var("CAUSE_BENCH_FAST").is_ok()
}

/// ~1 MB of seeded random parameters, magnitude-masked to `keep`.
fn synth_params(seed: u64, keep: f64) -> Vec<HostTensor> {
    let mut rng = Rng::new(seed);
    let mut params = vec![
        HostTensor::from_fn(&[512, 512], |_| rng.f32() * 2.0 - 1.0),
        HostTensor::from_fn(&[512], |_| rng.f32() * 2.0 - 1.0),
    ];
    for t in &mut params {
        t.apply_mask(keep);
    }
    params
}

/// Time one codec point: (compression ratio, encode MB/s, decode MB/s).
fn codec_point(b: &mut Bench, label: &str, keep: f64, reps: usize) -> (f64, f64, f64) {
    let codec = TensorCodec::new(CodecMode::Sparse);
    let params = synth_params(0xc0de ^ keep.to_bits(), keep);
    let enc = codec.encode(&params, None);
    assert_eq!(enc.decode(), params, "codec round-trip broke at keep={keep}");
    let dense_mb = enc.dense_size_bytes() as f64 / (1 << 20) as f64;
    let ratio = enc.dense_size_bytes() as f64 / enc.size_bytes() as f64;

    let mut enc_samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(codec.encode(&params, None));
        enc_samples.push(t0.elapsed().as_secs_f64());
    }
    let mut dec_samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(enc.decode());
        dec_samples.push(t0.elapsed().as_secs_f64());
    }
    b.record(&format!("encode_{label}"), &enc_samples);
    b.record(&format!("decode_{label}"), &dec_samples);
    let best = |s: &[f64]| s.iter().fold(f64::INFINITY, |a, &x| a.min(x));
    (ratio, dense_mb / best(&enc_samples), dense_mb / best(&dec_samples))
}

/// Drive one engine lifecycle with the host-tensor backend; returns
/// (resident checkpoints, total RSN, stored bytes, seconds, requests).
fn drive(
    meter: StoreMeter,
    budget: u64,
    cfg: &ExperimentConfig,
    pop: &EdgePopulation,
    trace: &RequestTrace,
) -> (usize, u64, u64, f64, u64) {
    let mut cfg = cfg.clone();
    cfg.store_meter = meter;
    cfg.memory_bytes = budget;
    let trainer = HostTrainer::new(
        HostTrainerConfig {
            shapes: vec![vec![96, 96], vec![96]],
            seed: 19,
            update_frac: 0.2,
        },
        cfg.shards,
        SystemVariant::Cause.schedule(&cfg),
    );
    let mut engine: Engine = SystemVariant::Cause
        .build_with_trainer(&cfg, Box::new(trainer), EvalPolicy::Never)
        .expect("engine");
    let t0 = Instant::now();
    engine.run_trace(pop, trace).expect("trace run");
    let secs = t0.elapsed().as_secs_f64();
    (
        engine.store().occupied(),
        engine.metrics.total_rsn(),
        engine.store().stored_bytes(),
        secs,
        engine.metrics.total_requests(),
    )
}

fn main() {
    let mut b = Bench::new("compress");
    let reps = if fast() { 5 } else { 40 };

    // --- 1. Codec microsection -----------------------------------------
    let (ratio_dense, enc_dense, dec_dense) = codec_point(&mut b, "keep100", 1.0, reps);
    let (ratio_30, enc_30, dec_30) = codec_point(&mut b, "keep30", 0.3, reps);
    let (ratio_05, enc_05, dec_05) = codec_point(&mut b, "keep5", 0.05, reps);
    println!(
        "codec ratios: keep=1.0 {ratio_dense:.2}x | keep=0.3 {ratio_30:.2}x | \
         keep=0.05 {ratio_05:.2}x (sparse bitmask+values, dense fallback)"
    );
    println!(
        "codec throughput at keep=0.3: encode {enc_30:.0} MB/s, decode {dec_30:.0} MB/s"
    );

    // Delta point: a parent payload perturbed in 5% of entries.
    let delta_codec = TensorCodec::new(CodecMode::Delta);
    let parent_params = synth_params(0xde17a, 0.3);
    let parent = std::sync::Arc::new(delta_codec.encode(&parent_params, None));
    let mut child = parent_params.clone();
    let mut rng = Rng::new(0xde17a ^ 1);
    for t in &mut child {
        let n = t.len();
        for _ in 0..n / 20 {
            let i = rng.below(n as u64) as usize;
            t.data[i] += 0.5;
        }
    }
    let delta_enc = delta_codec.encode(&child, Some(&parent));
    assert_eq!(delta_enc.decode(), child, "delta round-trip broke");
    let ratio_delta = delta_enc.dense_size_bytes() as f64 / delta_enc.size_bytes() as f64;
    println!(
        "delta vs 5%-perturbed parent: {ratio_delta:.2}x (is_delta: {})",
        delta_enc.is_delta()
    );

    // --- 2. Byte-budget vs slot-mode workload at keep=0.3 --------------
    let rounds: u32 = if fast() { 14 } else { 24 };
    let cfg = ExperimentConfig {
        users: 40,
        rounds,
        shards: 4,
        unlearn_prob: 0.6,
        prune_keep: 0.3,
        seed: 0xbeef,
        ..Default::default()
    };
    let shapes = vec![vec![96, 96], vec![96]];
    // C_m = 6 dense-slot checkpoints: the slot meter provisions for the
    // codec's dense fallback; the byte meter packs true encoded sizes.
    let budget = 6 * dense_upper_bound(&shapes);
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.scaled(60_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: cfg.seed,
    });
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig {
            unlearn_prob: cfg.unlearn_prob,
            block_incl_prob: 0.9,
            age_decay: 0.7,
            frac_range: (0.1, 0.5),
            seed: cfg.seed ^ 0x7ace,
        },
    );

    let (slot_ckpts, slot_rsn, slot_bytes, slot_secs, slot_reqs) =
        drive(StoreMeter::Slots, budget, &cfg, &pop, &trace);
    let (byte_ckpts, byte_rsn, byte_bytes, byte_secs, byte_reqs) =
        drive(StoreMeter::Bytes, budget, &cfg, &pop, &trace);
    b.record("e2e_slot_meter", &[slot_secs]);
    b.record("e2e_byte_meter", &[byte_secs]);
    assert_eq!(slot_reqs, byte_reqs, "both meters serve the same trace");
    let ckpt_gain = byte_ckpts as f64 / slot_ckpts.max(1) as f64;
    let rsn_cut = 1.0 - byte_rsn as f64 / slot_rsn.max(1) as f64;
    println!(
        "byte-budget workload (C_m = {budget} B, keep=0.3, {slot_reqs} requests): \
         checkpoints {slot_ckpts} -> {byte_ckpts} ({ckpt_gain:.2}x), \
         RSN {slot_rsn} -> {byte_rsn} (-{:.1}%), \
         stored bytes {slot_bytes} -> {byte_bytes}",
        rsn_cut * 100.0
    );

    b.report();

    // Machine-readable summary. `gate.ratio` is a deterministic function
    // of the seeded tensors (hardware-independent); `gate.decode_mbps` is
    // wall-clock and gated only against a conservative floor.
    let point = |ratio: f64, enc: f64, dec: f64| {
        Json::obj()
            .set("ratio", ratio)
            .set("encode_mbps", enc)
            .set("decode_mbps", dec)
    };
    let summary = Json::obj()
        .set("bench", "compress")
        .set(
            "codec",
            Json::obj()
                .set("keep100", point(ratio_dense, enc_dense, dec_dense))
                .set("keep30", point(ratio_30, enc_30, dec_30))
                .set("keep5", point(ratio_05, enc_05, dec_05))
                .set("delta_ratio", ratio_delta),
        )
        .set(
            "workload",
            Json::obj()
                .set("rounds", cfg.rounds as u64)
                .set("shards", cfg.shards)
                .set("budget_bytes", budget)
                .set("requests", slot_reqs)
                .set(
                    "slot",
                    Json::obj()
                        .set("checkpoints", slot_ckpts)
                        .set("rsn", slot_rsn)
                        .set("stored_bytes", slot_bytes),
                )
                .set(
                    "byte",
                    Json::obj()
                        .set("checkpoints", byte_ckpts)
                        .set("rsn", byte_rsn)
                        .set("stored_bytes", byte_bytes),
                )
                .set("checkpoint_gain", ckpt_gain)
                .set("rsn_cut", rsn_cut),
        )
        .set(
            "gate",
            Json::obj().set("ratio", ratio_30).set("decode_mbps", dec_30),
        );
    let out_path = std::env::var("CAUSE_BENCH_COMPRESS_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_compress.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");

    // Acceptance gates (after the report/JSON so failures are diagnosable).
    assert!(
        ratio_30 >= 2.0,
        "keep=0.3 must compress >=2x, got {ratio_30:.2}x"
    );
    assert!(
        ratio_05 > ratio_30 && ratio_30 > ratio_dense,
        "compression must grow with sparsity: {ratio_dense:.2} / {ratio_30:.2} / {ratio_05:.2}"
    );
    assert!(
        (0.95..=1.0).contains(&(1.0 / ratio_dense)),
        "dense fallback must stay within header overhead of 1.0x, got {ratio_dense:.3}x"
    );
    assert!(
        byte_ckpts >= 2 * slot_ckpts,
        "byte meter must keep >=2x checkpoints resident: {byte_ckpts} vs {slot_ckpts}"
    );
    assert!(
        byte_rsn < slot_rsn,
        "byte meter must cut RSN: {byte_rsn} vs {slot_rsn}"
    );
    assert!(byte_bytes <= budget, "byte meter overran C_m");
    assert!(dec_30 > 0.0 && enc_30 > 0.0);
}
