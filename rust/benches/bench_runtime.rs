//! Runtime perf microbenches: the PJRT hot path (L1+L2 through L3's eyes).
//!
//! Measures per-op latency of train_step / predict / prune executions and
//! the host<->literal transfer overhead. Requires `make artifacts`; exits
//! cleanly when they are missing.

use std::rc::Rc;

use cause::runtime::{Runtime, TrainSession};
use cause::util::bench::{black_box, Bench};

fn main() {
    let dir = cause::experiments::common::artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        println!("bench_runtime: SKIPPED (no artifacts — run `make artifacts`)");
        return;
    }
    let rt = Rc::new(Runtime::new(&dir).expect("runtime"));
    let mut b = Bench::new("pjrt-runtime");

    for variant in ["mobilenetv2_c10", "vgg16_c10", "resnet34_c10", "cnn_c10"] {
        if rt.manifest().get(&format!("{variant}/train_step")).is_err() {
            continue;
        }
        let mut sess = TrainSession::init(rt.clone(), variant, 3).expect("init");
        let bs = sess.batch_size();
        let fd = sess.feature_dim();
        let xs = vec![0.1f32; bs * fd];
        let ys: Vec<f32> = (0..bs).map(|i| (i % 10) as f32).collect();

        b.iter(&format!("{variant}/train_step"), 30, || {
            black_box(sess.step(&xs, &ys, 0.05).unwrap())
        });
        b.iter(&format!("{variant}/predict"), 30, || {
            black_box(sess.logits(&xs, bs).unwrap().len())
        });
        b.iter(&format!("{variant}/prune"), 15, || {
            sess.prune(0.3).unwrap();
        });
    }

    let stats = rt.stats();
    println!(
        "cumulative: {} executions | execute {:.2}s | transfer {:.2}s \
         ({:.1}% of hot path) | {} compiles ({:.2}s)",
        stats.executions,
        stats.execute_secs,
        stats.transfer_secs,
        100.0 * stats.transfer_secs / (stats.execute_secs + stats.transfer_secs).max(1e-9),
        stats.compiles,
        stats.compile_secs
    );
    b.report();
}
