//! Bench target regenerating the paper artifact 'table2' (see DESIGN.md
//! per-experiment index). Timing wraps the full experiment; the tables are
//! printed so `cargo bench` reproduces the paper's rows.
//!
//! Scale: smoke by default (CI-friendly); set CAUSE_SCALE=full for the
//! paper-shaped run.

use cause::experiments::{self, Scale};
use cause::util::bench::Bench;

fn main() {
    let scale = match std::env::var("CAUSE_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Smoke,
    };
    let mut b = Bench::new("table2");
    let mut tables = Vec::new();
    b.iter("table2", 2, || {
        tables = experiments::run("table2", scale).expect("experiment table2");
    });
    for t in &tables {
        println!("{}", t.render());
    }
    experiments::report("table2", &tables).expect("report");
    b.report();
}
