//! bench_obs — tracing overhead ceiling for the observability layer.
//!
//! Runs the same open-loop scenario with spans off and on, strictly
//! interleaved (off, on, off, on, ...) so CPU-frequency drift and cache
//! warmth hit both arms equally, and compares the min-of-N wall-clock of
//! each arm. `gate.overhead_pct` is the relative cost of tracing,
//! clamped at zero (a negative delta is timer noise, not a speedup).
//!
//! The served-request counts of the two arms are asserted equal first:
//! if tracing ever changes what the system *does* rather than how fast
//! it does it, that is a correctness bug this bench refuses to time.
//!
//! Like `merge_overhead`, the pinned ceiling in `BENCH_baseline.json`
//! (5.0%) is wall-clock-shaped and is never auto-tightened by
//! `bench_gate` — it is a regression tripwire, not a ratchet. Writes
//! `BENCH_obs.json` (override with `CAUSE_BENCH_OBS_JSON`);
//! `CAUSE_BENCH_FAST` shrinks ticks and repetitions for PR smoke runs.

use std::time::Instant;

use cause::load::{corpus, run_open_loop, OpenLoopCfg};
use cause::util::Json;

fn fast() -> bool {
    std::env::var("CAUSE_BENCH_FAST").is_ok()
}

fn main() {
    let base = OpenLoopCfg {
        offered_per_tick: 2.0,
        ticks: if fast() { 32 } else { 96 },
        tail_ticks: if fast() { 192 } else { 256 },
        seed: 0x0b50,
        obs: false,
    };
    let traced = OpenLoopCfg { obs: true, ..base };
    let reps = if fast() { 5 } else { 9 };

    let corpus_v = corpus();
    let sc = &corpus_v[0];

    // Warm both arms once (page cache, allocator, branch predictors)
    // and pin the A/B correctness check on the warmup pair.
    let off = run_open_loop(sc.as_ref(), &base).expect("warmup untraced run");
    let on = run_open_loop(sc.as_ref(), &traced).expect("warmup traced run");
    assert_eq!(
        off.served, on.served,
        "tracing changed the served count — it must be observation-only"
    );
    let spans = on
        .trace
        .as_ref()
        .and_then(|t| t.at(&["traceEvents"]))
        .and_then(Json::as_arr)
        .map(|a| a.len() as u64)
        .unwrap_or(0);
    assert!(spans > 0, "traced run recorded no events; nothing to measure");

    let mut min_off = f64::INFINITY;
    let mut min_on = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        run_open_loop(sc.as_ref(), &base).expect("untraced run");
        min_off = min_off.min(t.elapsed().as_secs_f64());
        let t = Instant::now();
        run_open_loop(sc.as_ref(), &traced).expect("traced run");
        min_on = min_on.min(t.elapsed().as_secs_f64());
    }
    let overhead_pct = ((min_on / min_off - 1.0) * 100.0).max(0.0);

    println!(
        "{:>20}: untraced {:.4}s, traced {:.4}s over {reps} reps -> overhead {:.2}% \
         ({} trace events, {} served)",
        sc.name(),
        min_off,
        min_on,
        overhead_pct,
        spans,
        off.served
    );

    let summary = Json::obj()
        .set("bench", "obs")
        .set(
            "workload",
            Json::obj()
                .set("scenario", sc.name())
                .set("offered_per_tick", base.offered_per_tick)
                .set("ticks", base.ticks)
                .set("tail_ticks", base.tail_ticks)
                .set("seed", base.seed)
                .set("reps", reps as u64)
                .set("fast", fast()),
        )
        .set(
            "results",
            Json::obj()
                .set("min_untraced_secs", min_off)
                .set("min_traced_secs", min_on)
                .set("trace_events", spans)
                .set("served", off.served),
        )
        .set("gate", Json::obj().set("overhead_pct", overhead_pct));

    let out_path = std::env::var("CAUSE_BENCH_OBS_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");
}
