//! bench_load — open-loop throughput-at-SLO across the scenario corpus.
//!
//! For every scenario in `cause::load::scenarios::corpus()`, sweep the
//! offered arrival rate (requests per service tick) and record, per
//! rate, the full log-bucketed queueing-delay histogram plus served /
//! unserved counters. A rate *passes* when every submitted request was
//! served, nothing stayed parked in battery carryover, and p99 queueing
//! delay met the scenario's SLO; `<scenario>_rps_at_slo` is the highest
//! passing rate — the measured sustainable deletion throughput of the
//! energy-bounded device, in requests per logical tick.
//!
//! Unlike the other benches' wall-clock sections, every gated number
//! here is a deterministic function of the seed: logical ticks, seeded
//! arrivals, energy accounting. That means CI can ratchet the
//! `load.<scenario>_rps_at_slo` floors exactly like `retrains_coalesced`
//! (no tolerance needed), and the scenario-determinism tests can
//! byte-compare the same reports this bench writes. Determinism is
//! *per mode*, though: `CAUSE_BENCH_FAST` changes the ticks and the
//! swept rate grid, so fast-mode and full-mode gate counters are not
//! comparable. The summary therefore carries a top-level `"mode"`
//! field (`"fast"`/`"full"`) and `bench_gate` refuses to compare a
//! load artifact against floors pinned in the other mode. The
//! committed floors in `BENCH_baseline.json` sit at the lowest swept
//! rate (0.5), which both modes sweep and every scenario's harvest
//! envelope covers by construction — tighten them only from the merged
//! baseline document `bench_gate` prints on a green run in the
//! baseline's pinned mode (CI measures in fast mode).
//! `gate.p999_over_p50` is a histogram-sanity ceiling:
//! the (+1-shifted) tail ratio at each scenario's best passing rate
//! must stay bounded, or the histogram (or the scheduler's tail
//! behavior) has regressed.
//!
//! Writes `BENCH_load.json` (override the path with
//! `CAUSE_BENCH_LOAD_JSON`); `CAUSE_BENCH_FAST` shrinks ticks and the
//! rate list for PR smoke runs without changing any scenario's shape.

use std::time::Instant;

use cause::load::{corpus, run_open_loop, sweep, OpenLoopCfg};
use cause::obs::budget;
use cause::util::Json;

fn fast() -> bool {
    std::env::var("CAUSE_BENCH_FAST").is_ok()
}

fn main() {
    // The lowest rate stays 0.5 in both modes — it is the committed
    // floor, so even smoke runs must measure it.
    let rates: Vec<f64> = if fast() {
        vec![0.5, 2.0, 8.0]
    } else {
        vec![0.5, 1.0, 2.0, 4.0, 8.0]
    };
    let base = OpenLoopCfg {
        offered_per_tick: 0.0, // set per sweep point
        ticks: if fast() { 32 } else { 96 },
        tail_ticks: if fast() { 192 } else { 256 },
        seed: 0x10ad,
        obs: false, // gated sweep runs untraced; the trace demo below opts in
    };

    let mut scenarios_json = Json::obj();
    let mut gate = Json::obj();
    let mut floors = Vec::new();
    let mut tail_ratio = 0.0f64;
    let t0 = Instant::now();

    for sc in corpus() {
        let t1 = Instant::now();
        let (rps_at_slo, reports) = sweep(sc.as_ref(), &rates, &base)
            .unwrap_or_else(|e| panic!("{} sweep failed: {e:#}", sc.name()));
        let secs = t1.elapsed().as_secs_f64();

        // Histogram-sanity ratio at the best passing rate (the rate the
        // floor certifies), worst across the corpus.
        if let Some(best) = reports.iter().rev().find(|r| r.slo_ok) {
            tail_ratio = tail_ratio.max(best.p999_over_p50());
        }

        let best = reports.iter().rev().find(|r| r.slo_ok);
        println!(
            "{:>20}: rps_at_slo {:>4} | best rate served {} reqs, p50/p99/p999 = \
             {}/{}/{} ticks, {} violations | sweep {:.2}s",
            sc.name(),
            rps_at_slo,
            best.map(|r| r.served).unwrap_or(0),
            best.map(|r| r.p50()).unwrap_or(0),
            best.map(|r| r.p99()).unwrap_or(0),
            best.map(|r| r.p999()).unwrap_or(0),
            best.map(|r| r.violations).unwrap_or(0),
            secs
        );

        let rows: Vec<Json> = reports.iter().map(|r| r.to_json()).collect();
        scenarios_json = scenarios_json.set(
            sc.name(),
            Json::obj()
                .set("description", sc.description())
                .set("knobs", sc.knobs())
                .set("rps_at_slo", rps_at_slo)
                .set("sweep", Json::Arr(rows))
                .set("sweep_secs", secs), // informational; never gated
        );
        gate = gate.set(&format!("{}_rps_at_slo", sc.name()), rps_at_slo);
        floors.push((sc.name(), rps_at_slo));
    }
    gate = gate.set("p999_over_p50", tail_ratio);

    let summary = Json::obj()
        .set("bench", "load")
        .set("mode", if fast() { "fast" } else { "full" })
        .set(
            "workload",
            Json::obj()
                .set("rates", rates.clone())
                .set("ticks", base.ticks)
                .set("tail_ticks", base.tail_ticks)
                .set("seed", base.seed)
                .set("fast", fast())
                .set("total_secs", t0.elapsed().as_secs_f64()),
        )
        .set("scenarios", scenarios_json)
        .set("gate", gate);

    let out_path = std::env::var("CAUSE_BENCH_LOAD_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_load.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");

    // Traced demo run (informational, never gated): the first corpus
    // scenario at the committed floor rate with spans on. Writes the
    // Chrome trace next to the summary and prints the per-phase
    // tick-budget table plus the registry's durability counters —
    // re-parsed from the export itself, so the artifact is proven
    // loadable before CI uploads it.
    let corpus_v = corpus();
    let sc = &corpus_v[0];
    let traced = OpenLoopCfg { offered_per_tick: rates[0], obs: true, ..base };
    let report = run_open_loop(sc.as_ref(), &traced)
        .unwrap_or_else(|e| panic!("{} traced run failed: {e:#}", sc.name()));
    let trace = report.trace.as_ref().expect("obs run carries a trace");
    let trace_path = std::path::Path::new(&out_path)
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .join("BENCH_load_trace.json");
    std::fs::write(&trace_path, trace.to_pretty())
        .unwrap_or_else(|e| panic!("writing {}: {e}", trace_path.display()));
    let (spans, markers) =
        budget::spans_from_chrome(trace).expect("own trace export re-parses");
    let b = budget::compute(&spans);
    println!("\ntraced {} run -> {}", sc.name(), trace_path.display());
    print!("{}", budget::render(&b, &markers));
    println!("telemetry: {}", report.telemetry);
    assert!(
        b.root_us == 0 || b.attributed_us * 100 >= b.root_us * 95,
        "tick budget attributes only {} of {} in-span us to named spans",
        b.attributed_us,
        b.root_us
    );

    // Sanity asserts (after the JSON so failures are diagnosable). The
    // real floors live in BENCH_baseline.json via bench_gate; these only
    // catch a bench that stopped measuring anything.
    for (name, rps) in &floors {
        assert!(
            *rps >= rates[0],
            "{name}: even the lowest swept rate {} missed its SLO (rps_at_slo {rps})",
            rates[0]
        );
    }
    assert!(tail_ratio > 0.0, "no passing run produced a tail ratio");
}
