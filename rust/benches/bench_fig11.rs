//! Bench target regenerating the paper artifact 'fig11' (see DESIGN.md
//! per-experiment index). Timing wraps the full experiment; the tables are
//! printed so `cargo bench` reproduces the paper's rows.
//!
//! Scale: smoke by default (CI-friendly); set CAUSE_SCALE=full for the
//! paper-shaped run.

use cause::experiments::{self, Scale};
use cause::util::bench::Bench;

fn main() {
    let scale = match std::env::var("CAUSE_SCALE").as_deref() {
        Ok("full") => Scale::Full,
        _ => Scale::Smoke,
    };
    let mut b = Bench::new("fig11");
    let mut tables = Vec::new();
    b.iter("fig11", 2, || {
        tables = experiments::run("fig11", scale).expect("experiment fig11");
    });
    for t in &tables {
        println!("{}", t.render());
    }
    experiments::report("fig11", &tables).expect("report");
    b.report();
}
