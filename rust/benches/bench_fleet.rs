//! bench_fleet — sharded fleet-service throughput: the same skewed-user
//! unlearning burst driven through `SystemVariant::build_fleet` at 1, 2,
//! and 4 shard workers.
//!
//! Three sections:
//!
//! 1. **Burst throughput** — a lognormal-skewed population (a few heavy
//!    users dominate the data volume) with a dense unlearning trace is
//!    ingested, submitted, and drained per round; requests route through
//!    the UCDP-backed front-end and retrain on per-shard workers. Each
//!    worker count runs `reps` times and the best wall-clock is kept;
//!    served-request counts must be identical across reps and across
//!    worker counts (the router conserves requests — every submit lands
//!    on exactly one shard and is drained).
//! 2. **Scaling** — `gate.scaling_2w` is requests/s at 2 workers over
//!    requests/s at 1 worker *on the same machine in the same process* (a
//!    ratio, like `scale.probe_speedup`, so it is far more stable across
//!    runner hardware than an absolute rate — but it still depends on the
//!    runner having ≥2 usable cores). 4-worker scaling is reported
//!    informationally (CI runners may not have 4 free cores).
//! 3. **Merge cost** — `gate.merge_overhead` is the wall-clock of one
//!    merged fleet report (aggregated `metrics()` + routing-wrapped
//!    `state_receipt()`) at 2 workers, as a fraction of one full 2-worker
//!    run. Receipt merging must stay cheap relative to the work it
//!    summarizes; a ceiling gate in `bench_gate` catches a merge path
//!    that starts re-doing per-shard work.
//!
//! Writes `BENCH_fleet.json` for CI upload and the regression gate. The
//! committed floors in `BENCH_baseline.json` (scaling_2w ≥ 1.5, merge
//! overhead ≤ 0.5) were pinned without a local toolchain run; tighten
//! them from CI artifacts via the merged baseline document `bench_gate`
//! prints on green runs.

use std::time::Instant;

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::catalog::CIFAR10;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::util::bench::black_box;
use cause::util::Json;

fn fast() -> bool {
    std::env::var("CAUSE_BENCH_FAST").is_ok()
}

fn cfg(workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        users: if fast() { 32 } else { 96 },
        rounds: if fast() { 4 } else { 8 },
        shards: 4,
        // Dense unlearning burst: the retrain path (plan → price → admit →
        // execute) dominates wall-clock, and it splits across workers by
        // request, which is exactly what the fleet is supposed to scale.
        unlearn_prob: 0.9,
        fleet_workers: workers,
        ..Default::default()
    }
}

fn inputs(cfg: &ExperimentConfig) -> (EdgePopulation, RequestTrace) {
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: CIFAR10.scaled(12_000),
        users: cfg.users,
        rounds: cfg.rounds,
        // Heavy skew: a handful of users carry most samples, so routing
        // balance (not just request count) is exercised.
        size_sigma: 1.2,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: 1077,
    });
    let trace =
        RequestTrace::generate(&pop, &TraceConfig::paper_default(53).with_prob(cfg.unlearn_prob));
    (pop, trace)
}

/// One full fleet run: returns (served requests, wall seconds).
fn run_once(cfg: &ExperimentConfig, pop: &EdgePopulation, trace: &RequestTrace) -> (usize, f64) {
    let mut fleet = SystemVariant::Cause.build_fleet(cfg).expect("fleet");
    let t0 = Instant::now();
    let mut served = 0;
    for t in 1..=cfg.rounds {
        fleet.ingest_round(pop).expect("ingest");
        for req in trace.at(t) {
            fleet.submit(req.clone());
        }
        fleet.advance(1);
        served += fleet.drain_batched().expect("drain");
    }
    served += fleet.flush_batched().expect("flush");
    (served, t0.elapsed().as_secs_f64())
}

/// Best-of-`reps` wall-clock for one worker count; asserts the served
/// count is deterministic across reps.
fn bench_workers(
    workers: usize,
    pop: &EdgePopulation,
    trace: &RequestTrace,
    reps: usize,
) -> (usize, f64) {
    let cfg = cfg(workers);
    let mut best = f64::INFINITY;
    let mut served = None;
    for _ in 0..reps {
        let (s, secs) = run_once(&cfg, pop, trace);
        assert_eq!(*served.get_or_insert(s), s, "served count must be deterministic");
        best = best.min(secs);
    }
    (served.unwrap_or(0), best)
}

fn main() {
    let reps = if fast() { 2 } else { 3 };
    let base_cfg = cfg(1);
    let (pop, trace) = inputs(&base_cfg);

    // 1. Burst throughput at 1 / 2 / 4 workers.
    let (served_1w, secs_1w) = bench_workers(1, &pop, &trace, reps);
    let (served_2w, secs_2w) = bench_workers(2, &pop, &trace, reps);
    let (served_4w, secs_4w) = bench_workers(4, &pop, &trace, reps);
    let rps = |served: usize, secs: f64| served as f64 / secs.max(1e-9);
    let (rps_1w, rps_2w, rps_4w) =
        (rps(served_1w, secs_1w), rps(served_2w, secs_2w), rps(served_4w, secs_4w));
    let scaling_2w = rps_2w / rps_1w.max(1e-9);
    let scaling_4w = rps_4w / rps_1w.max(1e-9);
    println!(
        "burst: {} requests | 1w {:.3}s ({:.0} req/s), 2w {:.3}s ({:.0} req/s, {:.2}x), \
         4w {:.3}s ({:.0} req/s, {:.2}x)",
        served_1w, secs_1w, rps_1w, secs_2w, rps_2w, scaling_2w, secs_4w, rps_4w, scaling_4w
    );

    // 2. Merge cost at 2 workers: one aggregated metrics + routed receipt
    // per call, amortized over a few calls, as a fraction of a full run.
    let cfg_2w = cfg(2);
    let mut fleet = SystemVariant::Cause.build_fleet(&cfg_2w).expect("fleet for merge");
    for t in 1..=cfg_2w.rounds {
        fleet.ingest_round(&pop).expect("ingest");
        for req in trace.at(t) {
            fleet.submit(req.clone());
        }
        fleet.advance(1);
        fleet.drain_batched().expect("drain");
    }
    fleet.flush_batched().expect("flush");
    let merge_reps = 5;
    let t0 = Instant::now();
    for _ in 0..merge_reps {
        black_box(fleet.metrics().expect("metrics merge"));
        black_box(fleet.state_receipt().expect("receipt merge"));
    }
    let merge_secs = t0.elapsed().as_secs_f64() / merge_reps as f64;
    let merge_overhead = merge_secs / secs_2w.max(1e-9);
    println!(
        "merge: {:.4}s per merged report at 2 workers ({:.3} of one run)",
        merge_secs, merge_overhead
    );

    let summary = Json::obj()
        .set("bench", "fleet")
        .set(
            "workload",
            Json::obj()
                .set("users", base_cfg.users)
                .set("rounds", base_cfg.rounds as u64)
                .set("requests", served_1w)
                .set("reps", reps),
        )
        .set(
            "fleet",
            Json::obj()
                .set("secs_1w", secs_1w)
                .set("secs_2w", secs_2w)
                .set("secs_4w", secs_4w)
                .set("rps_1w", rps_1w)
                .set("rps_2w", rps_2w)
                .set("rps_4w", rps_4w)
                .set("scaling_4w", scaling_4w)
                .set("merge_secs", merge_secs),
        )
        .set(
            "gate",
            Json::obj().set("scaling_2w", scaling_2w).set("merge_overhead", merge_overhead),
        );
    let out_path = std::env::var("CAUSE_BENCH_FLEET_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fleet.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");

    // Sanity asserts (after the JSON so failures are diagnosable). The
    // real scaling/merge floors live in BENCH_baseline.json and are
    // enforced by bench_gate; these only catch a broken bench.
    assert!(served_1w > 0, "burst produced no served requests");
    assert_eq!(served_2w, served_1w, "2-worker fleet must conserve requests");
    assert_eq!(served_4w, served_1w, "4-worker fleet must conserve requests");
    assert!(
        scaling_2w > 0.5,
        "2-worker fleet slower than half the single-worker rate ({scaling_2w:.2}x)"
    );
    assert!(
        merge_overhead < 1.0,
        "merging a fleet report cost more than a full run ({merge_overhead:.2})"
    );
}
