//! bench_persist — durability macro-bench: write-ahead log append
//! throughput, crash-recovery replay rate, and compaction ratio.
//!
//! Three sections:
//!
//! 1. **Live overhead** — a deterministic battery-gated workload run with
//!    `durability = off` vs `log` vs `log+spill`; asserts the journaled
//!    runs stay receipt-identical to the in-memory run (observation-only
//!    journaling) and reports the wall-clock overhead.
//! 2. **Log micro-rates** — re-appending the recorded run's frames to a
//!    fresh log measures framing+fs append MB/s; recovering a fresh
//!    service from the recorded log measures recovery events/s. Both are
//!    wall-clock and gated only against conservative floors
//!    (`gate.append_mbps`, `gate.recovery_events_per_s`).
//! 3. **Fsync modes** — the same frames through a real disk log with one
//!    fsync barrier per append (`gate.append_mbps_fsync`, the worst-case
//!    durable write floor), and the live workload under group commit:
//!    `gate.group_commit_amortization` is events appended per barrier
//!    issued — the factor the batched window amortizes durability by.
//! 4. **Compaction** — snapshot+truncate on the full log: reports the
//!    bytes the compacted generation (snapshot + empty tail) occupies vs
//!    the raw log (`compaction.ratio`) and that a reopen after compaction
//!    replays zero events.
//! 5. **Replica-side compaction** — the live workload shipped to an
//!    in-process peer replica with a small auto-compaction cadence:
//!    every compaction ships a `ShipReset` snapshot delta, so the peer
//!    holds the source's live generation instead of accreting the full
//!    event history. `gate.replica_compaction_ratio` is full-history
//!    bytes over final replica bytes — it falls to <= 1 if replicas ever
//!    go back to accreting history unboundedly.
//!
//! Writes `BENCH_persist.json` for CI upload and the regression gate.

use std::time::Instant;

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::catalog::CIFAR10;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::persist::frame::{scan_frames, LOG_MAGIC};
use cause::persist::{
    DiskFs, Durability, DurabilityMode, EventLog, FsyncPolicy, MemFs, ReplicaStore,
};
use cause::sim::device::AI_CUBESAT;
use cause::sim::Battery;
use cause::util::bench::black_box;
use cause::util::Json;
use cause::UnlearningService;

fn fast() -> bool {
    std::env::var("CAUSE_BENCH_FAST").is_ok()
}

fn cfg() -> ExperimentConfig {
    ExperimentConfig {
        users: if fast() { 16 } else { 40 },
        rounds: if fast() { 4 } else { 8 },
        shards: 4,
        unlearn_prob: 0.4,
        ..Default::default()
    }
}

fn inputs(cfg: &ExperimentConfig) -> (EdgePopulation, RequestTrace) {
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: CIFAR10.scaled(12_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.7,
        seed: 77,
    });
    let trace =
        RequestTrace::generate(&pop, &TraceConfig::paper_default(31).with_prob(cfg.unlearn_prob));
    (pop, trace)
}

fn build(cfg: &ExperimentConfig) -> UnlearningService {
    let engine = SystemVariant::Cause.build_cost(cfg).expect("engine");
    let mut battery = Battery::new(&AI_CUBESAT);
    // Partial charge so the battery-admission path (and possibly deferral/
    // carryover events) is exercised by the journaled workload.
    battery.charge_j = battery.capacity_j * 0.4;
    UnlearningService::new(engine).with_battery(battery)
}

/// Drive the workload to completion; returns wall seconds.
fn run(svc: &mut UnlearningService, pop: &EdgePopulation, trace: &RequestTrace) -> f64 {
    let t0 = Instant::now();
    let rounds = svc.engine().cfg.rounds;
    for t in 1..=rounds {
        svc.ingest_round(pop).expect("ingest");
        for req in trace.at(t) {
            svc.submit(req.clone());
        }
        svc.advance(1);
        svc.drain_batched().expect("drain");
        svc.harvest(5_000.0);
        svc.drain_batched().expect("drain carryover");
    }
    svc.flush_batched().expect("flush");
    t0.elapsed().as_secs_f64()
}

fn main() {
    let cfg = cfg();
    let (pop, trace) = inputs(&cfg);

    // 1. Live overhead + receipt equivalence.
    let mut baseline = build(&cfg);
    let off_secs = run(&mut baseline, &pop, &trace);
    let off_receipt = baseline.state_receipt();

    let fs_log = MemFs::new();
    let mut logged = build(&cfg);
    logged
        .attach_durability(Durability::mem(DurabilityMode::Log, fs_log.clone(), 0))
        .expect("attach log");
    let log_secs = run(&mut logged, &pop, &trace);
    assert_eq!(logged.state_receipt(), off_receipt, "log must be observation-only");
    assert!(logged.durability_error().is_none());
    let events = logged.journal_events();

    let fs_spill = MemFs::new();
    let mut spilled = build(&cfg);
    spilled
        .attach_durability(Durability::mem(DurabilityMode::LogSpill, fs_spill.clone(), 0))
        .expect("attach spill");
    let spill_secs = run(&mut spilled, &pop, &trace);
    assert_eq!(spilled.state_receipt(), off_receipt, "spill must be observation-only");
    drop(logged);
    drop(spilled);

    let wal = fs_log.file("wal-0.log").expect("log written");
    let (frames, _) = scan_frames(&wal, LOG_MAGIC);
    assert_eq!(frames.len() as u64, events);
    let log_bytes = wal.len() as u64;
    println!(
        "live workload: {} events, {} log bytes | off {:.3}s, log {:.3}s, \
         log+spill {:.3}s",
        events, log_bytes, off_secs, log_secs, spill_secs
    );

    // 2a. Append throughput: re-frame the recorded payloads into a fresh
    // in-memory log (framing + CRC + fs append, no service work).
    let reps = if fast() { 2 } else { 8 };
    let mut appended_bytes = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let opened = EventLog::open(Box::new(MemFs::new())).expect("fresh log");
        let mut log = opened.log;
        for f in &frames {
            log.append_payload(f).expect("append");
        }
        appended_bytes += log.log_bytes();
        black_box(log.next_seq());
    }
    let append_mbps = appended_bytes as f64 / 1e6 / t0.elapsed().as_secs_f64();

    // 2b. Recovery rate: rebuild a fresh service from the recorded log.
    let recover_once = || {
        let mut svc = build(&cfg);
        let report = svc
            .attach_durability(Durability::mem(DurabilityMode::Log, fs_log.fork(), 0))
            .expect("recover");
        assert_eq!(report.events_replayed, events);
        assert_eq!(svc.state_receipt(), off_receipt, "recovery must be exact");
        svc
    };
    let t0 = Instant::now();
    let mut replayed = 0u64;
    for _ in 0..reps {
        black_box(recover_once());
        replayed += events;
    }
    let recovery_eps = replayed as f64 / t0.elapsed().as_secs_f64();
    println!(
        "log rates: append {:.1} MB/s, recovery {:.0} events/s ({} events x {} reps)",
        append_mbps, recovery_eps, events, reps
    );

    // 3a. Fsync append floor: the recorded frames through a real disk
    // log with a barrier per append (`FsyncPolicy::Always`) — the
    // worst-case durable write path. Bounded to a frame prefix so the
    // section stays a few thousand barriers even on slow disks.
    let fsync_frames = &frames[..frames.len().min(512)];
    let dir = std::env::temp_dir().join(format!("cause_bench_fsync_{}", std::process::id()));
    let mut fsync_bytes = 0u64;
    let t0 = Instant::now();
    {
        std::fs::create_dir_all(&dir).expect("fsync bench dir");
        let fs = DiskFs::new(&dir).expect("disk fs");
        let opened = EventLog::open(Box::new(fs)).expect("fresh disk log");
        let mut log = opened.log;
        log.set_fsync(FsyncPolicy::Always);
        for f in fsync_frames {
            log.append_payload(f).expect("append+fsync");
        }
        fsync_bytes += log.log_bytes();
        let (appended, fsyncs) = log.fsync_stats();
        assert_eq!(appended, fsyncs, "Always = one barrier per append");
        black_box(log.next_seq());
    }
    let append_mbps_fsync = fsync_bytes as f64 / 1e6 / t0.elapsed().as_secs_f64();
    let _ = std::fs::remove_dir_all(&dir);

    // 3b. Group-commit amortization: the live workload again, now with
    // one barrier per sealed commit scope (window drain / round ingest)
    // instead of one per event. The ratio is the factor batching divides
    // durability cost by — receipt-identical to the unsynced run.
    let fs_gc = MemFs::new();
    let mut gc = build(&cfg);
    gc.attach_durability(
        Durability::mem(DurabilityMode::Log, fs_gc.clone(), 0)
            .with_fsync(FsyncPolicy::GroupCommit),
    )
    .expect("attach group-commit");
    let gc_secs = run(&mut gc, &pop, &trace);
    assert_eq!(gc.state_receipt(), off_receipt, "group commit must be observation-only");
    assert!(gc.durability_error().is_none());
    let (gc_appended, gc_fsyncs) = gc.journal_fsync_stats().expect("fsync stats");
    assert_eq!(gc_appended, events, "same workload, same event count");
    assert!(gc_fsyncs > 0, "commit scopes must seal");
    let amortization = gc_appended as f64 / gc_fsyncs as f64;
    drop(gc);
    println!(
        "fsync: always {:.2} MB/s ({} barriers) | group commit {} events / {} barriers \
         = {:.1}x amortized ({:.3}s)",
        append_mbps_fsync,
        fsync_frames.len(),
        gc_appended,
        gc_fsyncs,
        amortization,
        gc_secs
    );

    // 4. Compaction: snapshot + truncate, then prove a reopen needs no
    // tail replay and the state still matches.
    let pre_bytes: u64 = fs_log.sizes().iter().map(|(_, s)| s).sum();
    let fs_c = fs_log.fork();
    let mut svc = build(&cfg);
    svc.attach_durability(Durability::mem(DurabilityMode::Log, fs_c.clone(), 0))
        .expect("recover for compaction");
    svc.compact_now().expect("compact");
    let post_bytes: u64 = fs_c.sizes().iter().map(|(_, s)| s).sum();
    let compaction_ratio = pre_bytes as f64 / post_bytes.max(1) as f64;
    drop(svc);
    let mut reopened = build(&cfg);
    let report = reopened
        .attach_durability(Durability::mem(DurabilityMode::Log, fs_c, 0))
        .expect("reopen");
    assert!(report.snapshot_loaded);
    assert_eq!(report.events_replayed, 0, "compaction materialized everything");
    assert_eq!(reopened.state_receipt(), off_receipt);
    println!(
        "compaction: {} -> {} bytes ({:.2}x) | reopen replayed 0 events",
        pre_bytes, post_bytes, compaction_ratio
    );

    // 5. Replica-side compaction: the same workload journaled with a
    // small auto-compaction cadence while shipping to an in-process
    // peer. The peer's replica must track the source's live generation
    // (snapshot + tail), not the full history the run appended.
    let store = ReplicaStore::new();
    let fs_ship = MemFs::new();
    let mut shipped = build(&cfg);
    shipped
        .attach_durability(
            Durability::mem(DurabilityMode::Log, fs_ship.clone(), 64)
                .with_fsync(FsyncPolicy::GroupCommit),
        )
        .expect("attach for shipping");
    shipped.enable_shipping(0, Box::new(store.clone()), 8).expect("enable shipping");
    let ship_secs = run(&mut shipped, &pop, &trace);
    shipped.sync_journal().expect("final seal");
    let receipt = shipped.shipping_state().expect("shipping enabled");
    assert!(receipt.failed.is_none());
    assert_eq!(receipt.pending, 0, "a clean transport drains at every seal");
    assert_eq!(
        shipped.state_receipt(),
        off_receipt,
        "shipping + auto-compaction must be observation-only"
    );
    let live_bytes = shipped.journal_stats().expect("journal stats").live_bytes();
    drop(shipped);
    let replica = store.replica(0).expect("replica shipped");
    let replica_bytes = replica.bytes().max(1);
    assert!(
        replica.bytes() <= 2 * live_bytes.max(1),
        "replica must stay bounded by the source's live generation: \
         {} replica bytes vs {} live",
        replica.bytes(),
        live_bytes
    );
    // `log_bytes` is the same workload's full unbounded history
    // (section 1 journaled it with auto-compaction off).
    let replica_compaction_ratio = log_bytes as f64 / replica_bytes as f64;
    println!(
        "replica compaction: {} history bytes -> {} replica bytes \
         ({:.2}x bounded, {:.3}s)",
        log_bytes, replica_bytes, replica_compaction_ratio, ship_secs
    );

    let summary = Json::obj()
        .set("bench", "persist")
        .set(
            "workload",
            Json::obj()
                .set("rounds", cfg.rounds as u64)
                .set("users", cfg.users)
                .set("events", events)
                .set("log_bytes", log_bytes)
                .set("off_secs", off_secs)
                .set("log_secs", log_secs)
                .set("spill_secs", spill_secs)
                .set("group_commit_secs", gc_secs),
        )
        .set(
            "compaction",
            Json::obj()
                .set("pre_bytes", pre_bytes)
                .set("post_bytes", post_bytes)
                .set("ratio", compaction_ratio),
        )
        .set(
            "gate",
            Json::obj()
                .set("append_mbps", append_mbps)
                .set("append_mbps_fsync", append_mbps_fsync)
                .set("group_commit_amortization", amortization)
                .set("recovery_events_per_s", recovery_eps)
                .set("replica_compaction_ratio", replica_compaction_ratio),
        );
    let out_path = std::env::var("CAUSE_BENCH_PERSIST_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_persist.json").to_string()
    });
    std::fs::write(&out_path, summary.to_pretty() + "\n")
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");

    // Acceptance gates (after the JSON so failures are diagnosable).
    assert!(events > 0, "workload logged no events");
    assert!(
        compaction_ratio > 1.0,
        "compaction must shrink a non-trivial log ({compaction_ratio:.2}x)"
    );
    assert!(
        amortization >= 2.0,
        "group commit must amortize barriers across the window ({amortization:.2}x)"
    );
    assert!(
        replica_compaction_ratio > 1.0,
        "replica-side compaction must bound the peer below the full history \
         ({replica_compaction_ratio:.2}x)"
    );
}
