//! User-Centered Data Partition (UCDP) — paper Algorithm 1.
//!
//! Shards are keyed by data *origin* (the user): all of a user's data lands
//! in the same shard lineage, so a user's unlearning request touches exactly
//! one sub-model. Assignment balances shards by "data size per user" around
//! the mean contribution θ̄, greedily (the paper's knapsack-style step).
//!
//! Streaming semantics (the paper partitions per round; lineages persist):
//! * a user already assigned keeps their shard — locality is the point;
//! * new users are seeded round-robin onto the S_t shards if fewer users
//!   than shards exist, otherwise greedily onto the shard minimizing
//!   |size/user − θ̄| after insertion (Algorithm 1 lines 6–11);
//! * when the shard controller shrinks `s_t`, users of frozen shards are
//!   re-assigned among the active shards for *future* data (their past
//!   contributions stay covered by the frozen lineage's sub-model).

use std::collections::BTreeMap;

use crate::data::dataset::{DataBlock, UserId};
use crate::partition::{Partitioner, Placement, ShardId};
use crate::prng::Rng;

/// UCDP state: the persistent user → shard map plus shard statistics.
pub struct Ucdp {
    assignment: BTreeMap<UserId, ShardId>,
    /// Cumulative samples per shard (for the balance heuristic).
    shard_size: Vec<u64>,
    /// Users per shard.
    shard_users: Vec<u64>,
    rng: Rng,
}

impl Ucdp {
    pub fn new(max_shards: usize, seed: u64) -> Self {
        Self {
            assignment: BTreeMap::new(),
            shard_size: vec![0; max_shards],
            shard_users: vec![0; max_shards],
            rng: Rng::new(seed),
        }
    }

    /// The shard currently assigned to `user`, if any.
    pub fn shard_of(&self, user: UserId) -> Option<ShardId> {
        self.assignment.get(&user).copied()
    }

    /// Mean data size per user over users seen so far (θ̄ in Algorithm 1).
    fn theta_bar(&self) -> f64 {
        let users: u64 = self.shard_users.iter().sum();
        if users == 0 {
            return 0.0;
        }
        let size: u64 = self.shard_size.iter().sum();
        size as f64 / users as f64
    }

    /// Algorithm 1's greedy step: the shard (among 0..s_t) where adding
    /// `size` keeps size-per-user closest to θ̄ (from below, ⌊·⌋₊).
    fn best_shard(&self, size: u64, s_t: usize) -> ShardId {
        let theta = self.theta_bar();
        let mut best = 0;
        // ⌊x − θ̄⌋₊ in the paper: deviation clamped at zero from below —
        // prefer shards that stay under the mean; tie-break on total size.
        // Lexicographic (over, size): the size tie-break stays u64-exact.
        // (The old `over * 1e6 + size as f64` collapsed sizes past 2^53
        // into one f64 value, making the tie-break arbitrary at scale.)
        let mut best_key: Option<(f64, u64)> = None;
        for s in 0..s_t {
            let per_user =
                (self.shard_size[s] + size) as f64 / (self.shard_users[s] + 1) as f64;
            let over = (per_user - theta).max(0.0);
            let key = (over, self.shard_size[s]);
            let better = match best_key {
                None => true,
                Some(bk) => key.0 < bk.0 || (key.0 == bk.0 && key.1 < bk.1),
            };
            if better {
                best_key = Some(key);
                best = s;
            }
        }
        best
    }

    /// Sticky routing step for the fleet front-end. An already-seen user
    /// keeps their home shard even when it is frozen (>= s_t): the shard
    /// holding their past data must keep serving their unlearning
    /// requests (the locality invariant), so — unlike
    /// [`Ucdp::assign`](Partitioner::assign)'s re-homing of frozen
    /// shards' users for *future* data — routing never moves anyone.
    /// Only the cumulative size statistic advances. A new user is placed
    /// among the active shards by the same greedy step as Algorithm 1.
    pub fn route(&mut self, user: UserId, size: u64, s_t: usize) -> ShardId {
        if let Some(&s) = self.assignment.get(&user) {
            self.shard_size[s] += size;
            return s;
        }
        let s = self.best_shard(size, s_t);
        self.assignment.insert(user, s);
        self.shard_users[s] += 1;
        self.shard_size[s] += size;
        s
    }

    /// Re-home users of frozen shards (>= s_t) among the active shards.
    fn rehome_frozen(&mut self, s_t: usize) {
        let moved: Vec<UserId> = self
            .assignment
            .iter()
            .filter(|(_, s)| **s >= s_t)
            .map(|(u, _)| *u)
            .collect();
        for u in moved {
            let best = self.best_shard(0, s_t);
            self.assignment.insert(u, best);
            self.shard_users[best] += 1;
        }
    }
}

impl Partitioner for Ucdp {
    fn name(&self) -> &'static str {
        "ucdp"
    }

    fn assign(&mut self, blocks: &[DataBlock], s_t: usize) -> Vec<Placement> {
        assert!(s_t >= 1 && s_t <= self.shard_size.len());
        self.rehome_frozen(s_t);

        // Gather this round's per-user totals (a user can have 1 block/round
        // from the generator, but the algorithm shouldn't rely on that).
        let mut per_user: BTreeMap<UserId, u64> = BTreeMap::new();
        for b in blocks {
            *per_user.entry(b.user).or_default() += b.samples;
        }

        // Returning users: their new data lands on their shard *before* new
        // users are balanced, so the greedy step sees current loads.
        for (u, size) in &per_user {
            if let Some(&shard) = self.assignment.get(u) {
                self.shard_size[shard] += size;
            }
        }

        // New users this round, ordered by size (largest first gives the
        // greedy step its best shot at balance — LPT scheduling).
        let mut new_users: Vec<(UserId, u64)> = per_user
            .iter()
            .filter(|(u, _)| !self.assignment.contains_key(u))
            .map(|(u, s)| (*u, *s))
            .collect();
        new_users.sort_by_key(|(_, s)| std::cmp::Reverse(*s));

        // Algorithm 1 line 1/13: fewer (new) users than shards → one shard
        // each, seeded randomly among the emptiest shards.
        let empty_shards: Vec<ShardId> =
            (0..s_t).filter(|s| self.shard_users[*s] == 0).collect();
        let mut seed_iter = {
            let mut v = empty_shards;
            // Random seeding per Algorithm 1 line 3 ("select S users randomly").
            self.rng.shuffle(&mut v);
            v.into_iter()
        };
        for (u, size) in new_users {
            let shard = match seed_iter.next() {
                Some(s) => s,
                None => self.best_shard(size, s_t),
            };
            self.assignment.insert(u, shard);
            self.shard_users[shard] += 1;
            self.shard_size[shard] += size;
        }

        // Emit placements through the persistent map.
        let mut out = Vec::with_capacity(blocks.len());
        for b in blocks {
            let shard = self.assignment[&b.user];
            out.push(Placement { block: b.id, shard, samples: b.samples });
        }
        out
    }

    /// Layout: `[S, shard_size×S, shard_users×S, U, (user, shard)×U, rng×4]`.
    fn persist_state(&self) -> Vec<u64> {
        let s = self.shard_size.len();
        let mut out = Vec::with_capacity(2 + 2 * s + 2 * self.assignment.len() + 4);
        out.push(s as u64);
        out.extend(self.shard_size.iter().copied());
        out.extend(self.shard_users.iter().copied());
        out.push(self.assignment.len() as u64);
        for (u, shard) in &self.assignment {
            out.push(u.0 as u64);
            out.push(*shard as u64);
        }
        out.extend(self.rng.state());
        out
    }

    fn restore_state(&mut self, state: &[u64]) {
        let mut it = state.iter().copied();
        let Some(shards) = it.next() else { return };
        if shards as usize != self.shard_size.len() {
            return; // built with a different shard count — keep fresh state
        }
        for v in self.shard_size.iter_mut() {
            *v = it.next().unwrap_or(0);
        }
        for v in self.shard_users.iter_mut() {
            *v = it.next().unwrap_or(0);
        }
        let users = it.next().unwrap_or(0);
        self.assignment.clear();
        for _ in 0..users {
            let (Some(u), Some(s)) = (it.next(), it.next()) else { return };
            self.assignment.insert(UserId(u as u32), s as usize);
        }
        let rng: Vec<u64> = it.collect();
        if let [a, b, c, d] = rng[..] {
            self.rng = Rng::from_state([a, b, c, d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::{EdgePopulation, PopulationConfig};
    use crate::partition::{coverage_ok, shard_loads};
    use crate::testkit::forall;

    fn pop(seed: u64, users: usize) -> EdgePopulation {
        EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(20_000),
            users,
            rounds: 6,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed,
        })
    }

    /// Persist mid-run, restore into a fresh partitioner, and both must
    /// place the remaining rounds identically (crash-recovery property).
    #[test]
    fn persist_state_continues_assignments() {
        let p = pop(5, 40);
        let mut live = Ucdp::new(4, 11);
        for r in 1..=3 {
            live.assign(p.blocks_at(r), 4);
        }
        let saved = live.persist_state();
        let mut recovered = Ucdp::new(4, 11);
        recovered.restore_state(&saved);
        for r in 4..=6 {
            assert_eq!(
                live.assign(p.blocks_at(r), 4),
                recovered.assign(p.blocks_at(r), 4),
                "placements diverged at round {r}"
            );
        }
        // Restoring the empty vec keeps fresh state usable.
        let mut fresh = Ucdp::new(4, 11);
        fresh.restore_state(&[]);
        coverage_ok(p.blocks_at(1), &fresh.assign(p.blocks_at(1), 4), 4).unwrap();
    }

    #[test]
    fn covers_all_blocks_and_keeps_user_locality() {
        let p = pop(1, 40);
        let mut ucdp = Ucdp::new(4, 7);
        let mut user_shard: std::collections::BTreeMap<_, _> = Default::default();
        for r in 1..=6 {
            let blocks = p.blocks_at(r);
            let placements = ucdp.assign(blocks, 4);
            coverage_ok(blocks, &placements, 4).unwrap();
            for pl in &placements {
                let user = p.block(pl.block).unwrap().user;
                let prev = user_shard.insert(user, pl.shard);
                if let Some(prev) = prev {
                    assert_eq!(prev, pl.shard, "user {user:?} moved shards");
                }
            }
        }
    }

    #[test]
    fn balances_shards_within_factor() {
        let p = pop(2, 100);
        let mut ucdp = Ucdp::new(4, 3);
        let mut all = Vec::new();
        for r in 1..=6 {
            all.extend(ucdp.assign(p.blocks_at(r), 4));
        }
        let loads = shard_loads(&all, 4);
        let max = *loads.iter().max().unwrap() as f64;
        // "approximately balanced" — the greedy runs on whole users whose
        // *future* contributions are unknown (log-normal sizes), so the
        // meaningful guarantee is that no shard dominates the corpus and
        // every shard is populated.
        let total: u64 = loads.iter().sum();
        assert!(max < total as f64 * 0.5, "one shard dominates: {loads:?}");
        assert!(loads.iter().all(|l| *l > 0), "empty shard: {loads:?}");
    }

    #[test]
    fn fewer_users_than_shards_get_own_shard() {
        let p = pop(3, 3);
        let mut ucdp = Ucdp::new(8, 1);
        let placements = ucdp.assign(p.blocks_at(1), 8);
        let mut shards_used: Vec<_> = placements.iter().map(|p| p.shard).collect();
        shards_used.sort_unstable();
        shards_used.dedup();
        // Each user alone in a shard.
        let users: std::collections::BTreeSet<_> =
            p.blocks_at(1).iter().map(|b| b.user).collect();
        assert_eq!(shards_used.len(), users.len());
    }

    #[test]
    fn shrinking_shards_rehomes_future_data_only() {
        let p = pop(4, 30);
        let mut ucdp = Ucdp::new(8, 5);
        let r1 = ucdp.assign(p.blocks_at(1), 8);
        let used_high: Vec<_> = r1.iter().filter(|pl| pl.shard >= 2).collect();
        assert!(!used_high.is_empty(), "seed data never hit shards >= 2");
        // Controller shrinks to 2 shards: all new placements in 0..2.
        for r in 2..=6 {
            let placements = ucdp.assign(p.blocks_at(r), 2);
            coverage_ok(p.blocks_at(r), &placements, 2).unwrap();
        }
    }

    /// Regression: with shard sizes past 2^53 the old f64 score
    /// (`over * 1e6 + size as f64`) collapsed distinct sizes into one
    /// value — (2^53) and (2^53 + 1) both convert to 9007199254740992.0 —
    /// so the tie-break silently kept the *larger* shard (first index
    /// wins a float tie). The lexicographic (over, size) key compares the
    /// size leg in u64 and must pick the genuinely smaller shard.
    #[test]
    fn best_shard_tie_break_is_integer_exact_past_2_53() {
        let mut ucdp = Ucdp::new(2, 1);
        ucdp.shard_size = vec![(1u64 << 53) + 1, 1u64 << 53];
        ucdp.shard_users = vec![1, 1];
        // Both candidates sit under θ̄ (per_user = size/2 < θ̄ ≈ size), so
        // `over` clamps to exactly 0.0 for both and the size leg decides.
        assert_eq!(ucdp.best_shard(0, 2), 1, "u64 tie-break must pick the smaller shard");
        // Sanity: the mirrored layout picks the other index.
        let mut flipped = Ucdp::new(2, 1);
        flipped.shard_size = vec![1u64 << 53, (1u64 << 53) + 1];
        flipped.shard_users = vec![1, 1];
        assert_eq!(flipped.best_shard(0, 2), 0);
    }

    /// Routing is sticky: `route` never moves an existing user, even when
    /// the shard controller has frozen their home shard (s_t shrank), and
    /// repeated routes agree with `shard_of`.
    #[test]
    fn route_is_sticky_across_shrink() {
        let mut ucdp = Ucdp::new(8, 5);
        let homes: Vec<ShardId> =
            (0..20).map(|u| ucdp.route(UserId(u), 100 + u as u64, 8)).collect();
        // Shrink to 2 active shards: existing users keep frozen homes.
        for u in 0..20 {
            assert_eq!(ucdp.route(UserId(u), 50, 2), homes[u as usize]);
            assert_eq!(ucdp.shard_of(UserId(u)), Some(homes[u as usize]));
        }
        // New users after the shrink land only on active shards.
        for u in 20..40 {
            assert!(ucdp.route(UserId(u), 100, 2) < 2);
        }
    }

    #[test]
    fn prop_full_coverage_any_shard_count() {
        let seeds: Vec<u64> = (0..6).collect();
        for seed in seeds {
            forall(
                seed,
                20,
                |rng, size| {
                    let users = rng.range(1, 2 + (30.0 * size) as usize);
                    let shards = rng.range(1, 9);
                    (seed, users, shards)
                },
                |(seed, users, shards)| {
                    let p = pop(*seed + 100, *users);
                    let mut ucdp = Ucdp::new(*shards, 11);
                    for r in 1..=6 {
                        let placements = ucdp.assign(p.blocks_at(r), *shards);
                        coverage_ok(p.blocks_at(r), &placements, *shards)?;
                    }
                    Ok(())
                },
            );
        }
    }
}
