//! Data partitioning strategies: UCDP (the paper's), uniform (SISA) and
//! class-based (ARCANE).
//!
//! A partitioner assigns each arriving [`DataBlock`] to one or more shard
//! *lineages* (a block may split across shards only under the class-based
//! scheme, where a mixed-class block scatters by label). Assignments are
//! sticky: a partitioner sees each round's new blocks once and its internal
//! state (e.g. UCDP's user → shard map) persists across rounds.

pub mod class_based;
pub mod ucdp;
pub mod uniform;

use crate::data::dataset::{BlockId, DataBlock};

pub use class_based::ClassBased;
pub use ucdp::Ucdp;
pub use uniform::Uniform;

/// A shard lineage index (0-based; lineage `s` persists across rounds).
pub type ShardId = usize;

/// One placement: `samples` of `block` assigned to `shard`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub block: BlockId,
    pub shard: ShardId,
    pub samples: u64,
}

/// A data-partition strategy.
pub trait Partitioner: Send {
    fn name(&self) -> &'static str;

    /// Assign this round's new blocks to shards `0..s_t`.
    ///
    /// Every block's samples must be fully placed (the sum of placements
    /// per block equals `block.samples`) — exact unlearning requires full
    /// coverage. `s_t` may shrink between rounds (shard controller); it
    /// never exceeds the initial shard count.
    fn assign(&mut self, blocks: &[DataBlock], s_t: usize) -> Vec<Placement>;

    /// Internal state as raw words, for durability snapshots (UCDP's
    /// user → shard map, the uniform partitioner's RNG stream). Stateless
    /// partitioners return an empty vec. Restoring the saved words into a
    /// freshly built partitioner must make future `assign` calls place
    /// exactly as the pre-crash instance would have.
    fn persist_state(&self) -> Vec<u64> {
        Vec::new()
    }

    /// Restore state saved by [`Partitioner::persist_state`]. Must accept
    /// the empty vec (fresh state) and its own output.
    fn restore_state(&mut self, _state: &[u64]) {}
}

/// Check the full-coverage contract (used by tests and debug assertions).
pub fn coverage_ok(blocks: &[DataBlock], placements: &[Placement], s_t: usize) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut placed: BTreeMap<BlockId, u64> = BTreeMap::new();
    for p in placements {
        if p.shard >= s_t {
            return Err(format!("placement {p:?} outside 0..{s_t}"));
        }
        if p.samples == 0 {
            return Err(format!("zero-sample placement {p:?}"));
        }
        *placed.entry(p.block).or_default() += p.samples;
    }
    for b in blocks {
        let got = placed.remove(&b.id).unwrap_or(0);
        if got != b.samples {
            return Err(format!("block {:?}: placed {got} of {} samples", b.id, b.samples));
        }
    }
    if let Some((id, _)) = placed.into_iter().next() {
        return Err(format!("placement for unknown block {id:?}"));
    }
    Ok(())
}

/// Per-shard sample totals of a placement set (balance diagnostics).
pub fn shard_loads(placements: &[Placement], s_t: usize) -> Vec<u64> {
    let mut loads = vec![0u64; s_t];
    for p in placements {
        loads[p.shard] += p.samples;
    }
    loads
}
