//! Class-based partitioning — the ARCANE baseline.
//!
//! ARCANE shards by label: classes are grouped into `s_t` contiguous groups
//! and each sub-model trains on one group ("one-class classifiers" grouped
//! when classes > shards). A mixed-class data block therefore *splits*
//! across shards, and a user's unlearning request fans out to every shard
//! holding any of their classes.

use crate::data::dataset::DataBlock;
use crate::partition::{Partitioner, Placement, ShardId};

/// Class-range partitioner: class c → shard `c * s_t / classes`.
pub struct ClassBased {
    classes: usize,
}

impl ClassBased {
    pub fn new(classes: usize) -> Self {
        assert!(classes >= 1);
        Self { classes }
    }

    pub fn shard_of_class(&self, class: usize, s_t: usize) -> ShardId {
        class * s_t / self.classes
    }
}

impl Partitioner for ClassBased {
    fn name(&self) -> &'static str {
        "class_based"
    }

    fn assign(&mut self, blocks: &[DataBlock], s_t: usize) -> Vec<Placement> {
        assert!(s_t >= 1);
        let mut out = Vec::new();
        for b in blocks {
            debug_assert_eq!(b.class_counts.len(), self.classes);
            // Accumulate per-shard portions of this block.
            let mut per_shard = vec![0u64; s_t];
            for (class, count) in b.class_counts.iter().enumerate() {
                per_shard[self.shard_of_class(class, s_t)] += count;
            }
            for (shard, samples) in per_shard.into_iter().enumerate() {
                if samples > 0 {
                    out.push(Placement { block: b.id, shard, samples });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog::{CIFAR10, CIFAR100};
    use crate::data::dataset::{EdgePopulation, PopulationConfig};
    use crate::partition::coverage_ok;

    fn pop(seed: u64) -> EdgePopulation {
        EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(10_000),
            users: 30,
            rounds: 4,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed,
        })
    }

    #[test]
    fn class_ranges_cover_all_shards() {
        let cb = ClassBased::new(10);
        for s_t in 1..=8 {
            let mut hit = vec![false; s_t];
            for c in 0..10 {
                let s = cb.shard_of_class(c, s_t);
                assert!(s < s_t);
                hit[s] = true;
            }
            if s_t <= 10 {
                assert!(hit.iter().all(|h| *h), "s_t={s_t} left shards empty");
            }
        }
        // 100-class case (CIFAR-100 / ARCANE grouping).
        let cb100 = ClassBased::new(CIFAR100.classes);
        assert_eq!(cb100.shard_of_class(0, 4), 0);
        assert_eq!(cb100.shard_of_class(99, 4), 3);
    }

    #[test]
    fn splits_blocks_but_preserves_totals() {
        let p = pop(1);
        let mut cb = ClassBased::new(10);
        for r in 1..=4 {
            let placements = cb.assign(p.blocks_at(r), 4);
            coverage_ok(p.blocks_at(r), &placements, 4).unwrap();
        }
    }

    #[test]
    fn mixed_class_blocks_scatter() {
        let p = pop(2);
        let mut cb = ClassBased::new(10);
        let placements = cb.assign(p.blocks_at(1), 4);
        // Some block should appear in more than one shard (non-IID but
        // multi-class users).
        let mut counts = std::collections::BTreeMap::new();
        for pl in &placements {
            *counts.entry(pl.block).or_insert(0) += 1;
        }
        assert!(counts.values().any(|c| *c > 1), "no block split across shards");
    }
}
