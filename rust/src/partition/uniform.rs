//! Uniform partitioning — the SISA baseline.
//!
//! SISA assigns training *samples* to shards uniformly at random, so every
//! arriving data block scatters across all active shards (near-equal
//! portions). This keeps shards perfectly balanced but means a user's
//! unlearning request — even for a single block — touches *every* shard
//! holding a piece of it, which is exactly the fan-out CAUSE's UCDP avoids
//! (and why SISA's RSN grows with the shard count in Figs. 14/16).

use crate::data::dataset::DataBlock;
use crate::partition::{Partitioner, Placement};
use crate::prng::Rng;

/// Sample-level uniform partitioner.
pub struct Uniform {
    rng: Rng,
}

impl Uniform {
    pub fn new(max_shards: usize) -> Self {
        // max_shards only fixes the RNG stream; assignment is per-call.
        Self { rng: Rng::new(0x5150 ^ max_shards as u64) }
    }
}

impl Partitioner for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn assign(&mut self, blocks: &[DataBlock], s_t: usize) -> Vec<Placement> {
        assert!(s_t >= 1);
        let mut out = Vec::with_capacity(blocks.len() * s_t);
        for b in blocks {
            // Even split with the remainder scattered uniformly.
            let base = b.samples / s_t as u64;
            let rem = (b.samples % s_t as u64) as usize;
            let mut extra = vec![0u64; s_t];
            for _ in 0..rem {
                extra[self.rng.below(s_t as u64) as usize] += 1;
            }
            for (shard, ex) in extra.iter().enumerate() {
                let samples = base + ex;
                if samples > 0 {
                    out.push(Placement { block: b.id, shard, samples });
                }
            }
        }
        out
    }

    fn persist_state(&self) -> Vec<u64> {
        self.rng.state().to_vec()
    }

    fn restore_state(&mut self, state: &[u64]) {
        if let [a, b, c, d] = *state {
            self.rng = Rng::from_state([a, b, c, d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::{EdgePopulation, PopulationConfig};
    use crate::partition::{coverage_ok, shard_loads};

    fn pop(seed: u64) -> EdgePopulation {
        EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(20_000),
            users: 50,
            rounds: 5,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed,
        })
    }

    #[test]
    fn covers_and_balances_tightly() {
        let p = pop(1);
        let mut part = Uniform::new(4);
        let mut all = Vec::new();
        for r in 1..=5 {
            let placements = part.assign(p.blocks_at(r), 4);
            coverage_ok(p.blocks_at(r), &placements, 4).unwrap();
            all.extend(placements);
        }
        let loads = shard_loads(&all, 4);
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap().max(&1) as f64;
        assert!(max / min < 1.05, "sample-level uniform must balance: {loads:?}");
    }

    #[test]
    fn blocks_scatter_across_all_shards() {
        let p = pop(2);
        let mut part = Uniform::new(4);
        let placements = part.assign(p.blocks_at(1), 4);
        // Any reasonably-sized block must appear in all 4 shards.
        for b in p.blocks_at(1) {
            if b.samples >= 8 {
                let shards: std::collections::BTreeSet<_> = placements
                    .iter()
                    .filter(|pl| pl.block == b.id)
                    .map(|pl| pl.shard)
                    .collect();
                assert_eq!(shards.len(), 4, "block {:?} ({} samples)", b.id, b.samples);
            }
        }
    }

    #[test]
    fn persist_state_continues_scatter_stream() {
        let p = pop(4);
        let mut live = Uniform::new(4);
        live.assign(p.blocks_at(1), 4);
        let saved = live.persist_state();
        let mut recovered = Uniform::new(4);
        recovered.restore_state(&saved);
        for r in 2..=5 {
            assert_eq!(
                live.assign(p.blocks_at(r), 4),
                recovered.assign(p.blocks_at(r), 4),
                "scatter diverged at round {r}"
            );
        }
    }

    #[test]
    fn single_shard_is_identity() {
        let p = pop(3);
        let mut part = Uniform::new(1);
        let placements = part.assign(p.blocks_at(1), 1);
        assert_eq!(placements.len(), p.blocks_at(1).len());
        coverage_ok(p.blocks_at(1), &placements, 1).unwrap();
    }
}
