//! One shard worker: a thread that owns a full [`UnlearningService`]
//! (engine, model store, battery, batch planner, and — when durability is
//! on — its own write-ahead log) and drives it from a command channel.
//!
//! The engine's trainer is deliberately not `Send` (the PJRT backend is
//! `Rc`-based), so the service is **constructed inside the worker
//! thread** from a `Send` builder closure; only plain data crosses the
//! channels.
//!
//! Batched drains run the same window lifecycle as the standalone
//! service, but stage 2 (battery admission) is delegated to the fleet
//! front-end: for every priced window the worker publishes a
//! [`Reply::Quote`] (per-lineage costs + a battery snapshot) on the
//! shared event channel and blocks on its grant channel for the
//! [`Admission`] verdict, then commits. The front-end computes the
//! verdict with [`admission_decide`](crate::unlearning::service) — the
//! exact function the standalone service calls inline — which is what
//! makes a 1-worker fleet byte-identical to the unsharded service.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::data::dataset::EdgePopulation;
use crate::data::trace::UnlearnRequest;
use crate::load::LatencyHistogram;
use crate::metrics::RunMetrics;
use crate::persist::recovery::RecoveryReport;
use crate::persist::{Durability, Replica, ShipReceipt, ShipTransport};
use crate::sim::Battery;
use crate::unlearning::service::Admission;
use crate::unlearning::{BatchReport, JournalStats, UnlearningService};
use crate::util::Json;

/// Commands the fleet front-end sends a shard worker. Processed strictly
/// in order; queries are answered on the shared event channel tagged with
/// the worker's shard index.
pub(crate) enum Cmd {
    /// Ingest one training round over this shard's slice of the
    /// population (possibly empty — every worker ingests every round so
    /// engine round counters stay aligned across the fleet).
    Ingest(Box<EdgePopulation>),
    Submit(UnlearnRequest),
    Advance(u64),
    Harvest(f64),
    SetBattery(Battery),
    /// Drain batched windows (`flush` = close everything regardless of
    /// deadline slack), quoting each window to the front-end for
    /// admission. Terminates with `Served` or `Err`. `parent` links the
    /// worker's drain span to the front-end span that caused it (0 =
    /// none / tracing off).
    Drain { flush: bool, parent: u64 },
    AttachDurability(Durability),
    /// Start shipping the shard's journal over `transport` (identifying
    /// as shard `source`); the current generation is staged immediately.
    EnableShipping { source: usize, transport: Box<dyn ShipTransport>, retry_limit: u32 },
    /// Force the group-commit window closed: fsync barrier + ship.
    SyncJournal,
    /// Write a snapshot and truncate the shard's log prefix.
    Compact,
    /// Shipping receipt + the journal's next sequence number.
    ShipState,
    /// Latency histogram of every receipt so far, with exact violation
    /// count against `slo_ticks` (pass `u64::MAX` for "histogram only").
    LatencyHist { slo_ticks: u64 },
    Receipt,
    Metrics,
    BatchLog,
    Counts,
    JournalEvents,
    /// Aggregate journal counters (fsync stats, log/snapshot bytes).
    JournalStats,
    /// Snapshot of the shard's retained span records (empty when tracing
    /// is off).
    ObsSpans,
    /// The shard's named-metrics registry.
    ObsRegistry,
    /// The journal's durable state, [`Replica`]-shaped (soak-harness
    /// byte-convergence checks compare this against the peer's copy).
    JournalImage,
    Shutdown,
}

/// Worker→front-end replies, tagged `(shard, Reply)` on the shared event
/// channel.
#[derive(Debug)]
pub(crate) enum Reply {
    /// Builder succeeded; the worker is serving commands.
    Ready,
    Ingested,
    /// A priced window awaiting the front-end's admission verdict.
    Quote { costs: Option<Vec<f64>>, battery: Option<Battery> },
    /// Drain finished; total requests served.
    Served(usize),
    Receipt(Box<Json>),
    Metrics(Box<RunMetrics>),
    BatchLog(Vec<BatchReport>),
    Counts { pending: usize, carryover_requests: usize, carryover_lineages: usize },
    Attached(Box<RecoveryReport>),
    Events(u64),
    ShipEnabled,
    Synced,
    Compacted,
    /// Shipping receipt (`None` = shipping off) + journal next_seq.
    Shipping { receipt: Option<ShipReceipt>, log_seq: u64 },
    LatencyHist { hist: Box<LatencyHistogram>, violations: u64 },
    JournalStats(Option<JournalStats>),
    ObsSpans(Vec<crate::obs::SpanRec>),
    ObsRegistry(Box<crate::obs::Registry>),
    JournalImage(Box<Option<Replica>>),
    Err(String),
}

/// Front-end handle to one worker thread.
pub(crate) struct WorkerHandle {
    pub(crate) cmd: Sender<Cmd>,
    /// Admission grants for in-flight quotes (stage 2 of the window
    /// lifecycle).
    pub(crate) grant: Sender<Admission>,
    pub(crate) join: Option<JoinHandle<()>>,
}

/// Spawn shard worker `k`. The service is built inside the thread; the
/// first event is `Ready` on success or `Err` with the builder failure.
/// The factory is `Fn` (not `FnOnce`) and shared by `Arc` so failover can
/// rebuild a dead shard from the same recipe.
pub(crate) fn spawn(
    k: usize,
    build: Arc<dyn Fn() -> Result<UnlearningService> + Send + Sync>,
    events: Sender<(usize, Reply)>,
) -> WorkerHandle {
    let (cmd_tx, cmd_rx) = std::sync::mpsc::channel::<Cmd>();
    let (grant_tx, grant_rx) = std::sync::mpsc::channel::<Admission>();
    let join = std::thread::Builder::new()
        .name(format!("fleet-shard-{k}"))
        .spawn(move || run(k, build, cmd_rx, grant_rx, events))
        .expect("spawn fleet worker thread");
    WorkerHandle { cmd: cmd_tx, grant: grant_tx, join: Some(join) }
}

fn run(
    k: usize,
    build: Arc<dyn Fn() -> Result<UnlearningService> + Send + Sync>,
    cmds: Receiver<Cmd>,
    grants: Receiver<Admission>,
    events: Sender<(usize, Reply)>,
) {
    let mut svc = match build() {
        Ok(svc) => {
            let _ = events.send((k, Reply::Ready));
            svc
        }
        Err(e) => {
            let _ = events.send((k, Reply::Err(format!("{e:#}"))));
            return;
        }
    };
    while let Ok(cmd) = cmds.recv() {
        let reply = match cmd {
            Cmd::Ingest(pop) => Some(match svc.ingest_round(&pop) {
                Ok(()) => Reply::Ingested,
                Err(e) => Reply::Err(format!("{e:#}")),
            }),
            Cmd::Submit(req) => {
                svc.submit(req);
                None
            }
            Cmd::Advance(ticks) => {
                svc.advance(ticks);
                None
            }
            Cmd::Harvest(secs) => {
                svc.harvest(secs);
                None
            }
            Cmd::SetBattery(b) => {
                svc = svc.with_battery(b);
                None
            }
            Cmd::Drain { flush, parent } => {
                Some(match drain(&mut svc, flush, parent, k, &events, &grants) {
                    Ok(served) => Reply::Served(served),
                    Err(e) => Reply::Err(format!("{e:#}")),
                })
            }
            Cmd::AttachDurability(d) => Some(match svc.attach_durability(d) {
                Ok(report) => Reply::Attached(Box::new(report)),
                Err(e) => Reply::Err(format!("{e:#}")),
            }),
            Cmd::EnableShipping { source, transport, retry_limit } => {
                Some(match svc.enable_shipping(source, transport, retry_limit) {
                    Ok(()) => Reply::ShipEnabled,
                    Err(e) => Reply::Err(format!("{e:#}")),
                })
            }
            Cmd::SyncJournal => Some(match svc.sync_journal() {
                Ok(()) => Reply::Synced,
                Err(e) => Reply::Err(format!("{e:#}")),
            }),
            Cmd::Compact => Some(match svc.compact_now() {
                Ok(()) => Reply::Compacted,
                Err(e) => Reply::Err(format!("{e:#}")),
            }),
            Cmd::ShipState => Some(Reply::Shipping {
                receipt: svc.shipping_state(),
                log_seq: svc.journal_seq(),
            }),
            Cmd::LatencyHist { slo_ticks } => {
                // The histogram is maintained incrementally (and covers
                // receipts folded out of the capped vec); the exact
                // violation count still scans the retained receipts.
                let hist = svc.engine().metrics.latency_hist.clone();
                let mut violations = 0u64;
                for r in &svc.engine().metrics.latency {
                    if r.queued_ticks > slo_ticks {
                        violations += 1;
                    }
                }
                Some(Reply::LatencyHist { hist: Box::new(hist), violations })
            }
            Cmd::Receipt => Some(Reply::Receipt(Box::new(svc.state_receipt()))),
            Cmd::Metrics => Some(Reply::Metrics(Box::new(svc.engine().metrics.clone()))),
            Cmd::BatchLog => Some(Reply::BatchLog(svc.batch_log.clone())),
            Cmd::Counts => Some(Reply::Counts {
                pending: svc.pending(),
                carryover_requests: svc.carryover_requests(),
                carryover_lineages: svc.carryover_lineages(),
            }),
            Cmd::JournalEvents => Some(Reply::Events(svc.journal_events())),
            Cmd::JournalStats => Some(Reply::JournalStats(svc.journal_stats())),
            Cmd::ObsSpans => Some(Reply::ObsSpans(svc.obs_records())),
            Cmd::ObsRegistry => Some(Reply::ObsRegistry(Box::new(svc.registry()))),
            Cmd::JournalImage => Some(Reply::JournalImage(Box::new(svc.journal_image()))),
            Cmd::Shutdown => break,
        };
        if let Some(reply) = reply {
            if events.send((k, reply)).is_err() {
                break; // front-end gone
            }
        }
    }
}

/// The worker half of the batched drain: the standalone service's window
/// loop with stage 2 (admission) swapped for a quote/grant exchange.
fn drain(
    svc: &mut UnlearningService,
    flush: bool,
    parent: u64,
    k: usize,
    events: &Sender<(usize, Reply)>,
    grants: &Receiver<Admission>,
) -> Result<usize> {
    svc.check_journal()?;
    if parent != 0 {
        svc.obs_set_parent(parent);
    }
    let now = svc.now();
    let root = crate::obs::begin_root(
        svc.tracer_mut(),
        if flush { "drain_flush" } else { "drain" },
        now,
    );
    let mut served = 0;
    loop {
        let w = svc.next_window(flush);
        if w == 0 {
            // Flush a carried-over plan even when no window opens — its
            // samples are already removed, so its poison must still be
            // replayed (and its requests counted).
            if svc.has_carryover() {
                served += exchange(svc, Vec::new(), k, events, grants)?;
            }
            break;
        }
        let window = svc.take_window(w);
        let n = exchange(svc, window, k, events, grants)?;
        served += n;
        if n == 0 && svc.has_carryover() {
            // Battery-starved: the window's plan is parked; draining
            // further windows would only park more unfunded work.
            break;
        }
    }
    // Same commit scope as the standalone drain: seal the group-commit
    // window (one fsync) and ship the sealed frames before acking.
    svc.journal_seal();
    svc.check_journal()?;
    let now = svc.now();
    crate::obs::end(svc.tracer_mut(), root, now, served as u64);
    Ok(served)
}

/// Price one window, quote it, await the grant, commit.
fn exchange(
    svc: &mut UnlearningService,
    window: Vec<UnlearnRequest>,
    k: usize,
    events: &Sender<(usize, Reply)>,
    grants: &Receiver<Admission>,
) -> Result<usize> {
    let pw = svc.price_window(window);
    let now = svc.now();
    let span = crate::obs::begin(svc.tracer_mut(), "admit", now);
    events
        .send((k, Reply::Quote { costs: pw.costs.clone(), battery: svc.battery().cloned() }))
        .map_err(|_| anyhow::anyhow!("fleet front-end hung up mid-quote"))?;
    let admission = grants
        .recv()
        .map_err(|_| anyhow::anyhow!("fleet front-end hung up awaiting grant"))?;
    let granted = matches!(admission, Admission::Granted { .. });
    let now = svc.now();
    crate::obs::end(svc.tracer_mut(), span, now, u64::from(granted));
    svc.commit_window(pw, admission)
}
