//! Sharded fleet service: N independent shard workers behind the
//! unsharded [`UnlearningService`] surface.
//!
//! [`FleetService`] promotes UCDP's user→shard map to a front-end
//! [`Router`] and runs one worker per shard, each owning a full service
//! stack — engine, model store, battery, batch planner, and (when
//! durability is on) its own write-ahead log under
//! `persist_dir/shard-<k>/`. Submits and round ingests fan out over
//! channels by the router's sticky assignment; batched drains run
//! windows per-shard but admit battery energy centrally through a
//! two-phase price-then-commit exchange; per-shard receipts merge into
//! one fleet receipt with deterministic ordering given the routing seed.
//!
//! **Keystone invariant**: `fleet_workers = 1` replays the unsharded
//! service byte-identically — same receipts, RSN, store stats, and
//! journal. Worker 0 runs the root config seed, routing is a no-op over
//! one shard, and admission verdicts come from the same
//! [`admission_decide`] the standalone service calls inline, so every
//! transition is the same function applied to the same state.
//!
//! Per-shard engine seeds derive deterministically from
//! `(routing_seed, shard)` via the crate PRNG's fork discipline
//! ([`FleetService::derive_shard_seeds`]), and surface in the fleet
//! state receipt so recovery of any shard is seed-auditable.

mod router;
mod worker;

pub use router::Router;

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::data::dataset::{EdgePopulation, UserId};
use crate::data::trace::UnlearnRequest;
use crate::load::LatencyHistogram;
use crate::metrics::RunMetrics;
use crate::partition::ShardId;
use crate::persist::recovery::RecoveryReport;
use crate::persist::ship::materialize_replica;
use crate::persist::{
    Durability, DurabilityMode, FsyncPolicy, MemFs, PersistFs, Replica, ReplicaSource,
    ReplicaStore, ShipReceipt, ShipTransport,
};
use crate::prng::Rng;
use crate::sim::Battery;
use crate::unlearning::service::admission_decide;
use crate::unlearning::{BatchReport, JournalStats, UnlearningService};
use crate::util::Json;

use worker::{Cmd, Reply, WorkerHandle};

/// Consecutive shipping faults tolerated before a shard's shipping fails
/// terminally (the journal itself is unaffected).
const SHIP_RETRY_LIMIT: u32 = 8;

/// A shared factory that rebuilds one shard's service from scratch —
/// used at spawn and again by [`FleetService::failover`].
type ShardFactory = Arc<dyn Fn() -> Result<UnlearningService> + Send + Sync>;

/// Builds the shipping transport for one shard. Rebuilt transports (at
/// failover re-enable) come from the same recipe.
type TransportFactory = Arc<dyn Fn(usize) -> Box<dyn ShipTransport> + Send + Sync>;

/// Log-shipping state the front-end keeps: where failover reads a dead
/// shard's replica from (the in-process store, or a reopened file spool
/// for out-of-process transports), the transport recipe, and the retry
/// budget — everything failover and re-enable need.
struct Shipping {
    source: Arc<dyn ReplicaSource>,
    make: TransportFactory,
    retry_limit: u32,
    /// The shared in-process store, when the default transport family is
    /// in use (tests poll watermarks through it). `None` for custom
    /// out-of-process sources.
    store: Option<ReplicaStore>,
}

/// A fleet of shard workers behind the unsharded service surface.
pub struct FleetService {
    router: Router,
    workers: Vec<WorkerHandle>,
    /// Per-shard service factories, retained so failover can rebuild a
    /// dead shard's worker from the same recipe.
    factories: Vec<ShardFactory>,
    events: Receiver<(usize, Reply)>,
    /// Kept (not just cloned into workers) so failover can hand the
    /// replacement worker the same event channel.
    event_tx: Sender<(usize, Reply)>,
    seeds: Vec<u64>,
    /// Fleet-level round counter (mirrors each worker's ingest count).
    round: u32,
    /// Per-shard liveness; a dead shard parks commands until failover.
    alive: Vec<bool>,
    /// Fire-and-forget commands addressed to a dead shard, delivered in
    /// arrival order once failover rebuilds it.
    parked: Vec<Vec<Cmd>>,
    /// Battery template ([`FleetService::with_battery`]), re-armed on the
    /// replacement worker at failover.
    battery: Option<Battery>,
    /// Per-shard durability spec captured at attach time; failover
    /// re-attaches the replacement with the same mode/fsync/cadence over
    /// the materialized replica (the dead shard's local disk is lost).
    dura_spec: Vec<Option<(DurabilityMode, FsyncPolicy, u64)>>,
    shipping: Option<Shipping>,
    /// Front-end span tracer ([`FleetService::enable_obs`]); its lane is
    /// distinct from every shard's, and its drain spans parent the
    /// worker-side drain roots across the channel boundary.
    tracer: Option<crate::obs::Tracer>,
    /// Front-end mirror of the lockstep shard clocks (ticks), used to
    /// stamp front-end spans and markers.
    now_tick: u64,
}

/// Tracer shard key for the fleet front-end: exports to its own lane,
/// never colliding with a real shard index.
const FRONT_END_SHARD: u32 = u32::MAX;

impl FleetService {
    /// Derive the per-shard engine seeds from the routing seed. Shard 0
    /// keeps the root seed itself — that is what makes a 1-worker fleet
    /// byte-identical to an unsharded service built from the same config
    /// — and every later shard gets an independent stream from the crate
    /// PRNG's fork discipline (root stream advanced once per shard, so
    /// the derivation is order-independent of fleet operations).
    pub fn derive_shard_seeds(routing_seed: u64, workers: usize) -> Vec<u64> {
        let mut root = Rng::new(routing_seed);
        (0..workers)
            .map(|k| if k == 0 { routing_seed } else { root.fork(k as u64).next_u64() })
            .collect()
    }

    /// Spawn one worker per builder. Each closure runs *inside* its
    /// worker thread (the engine's trainer is not `Send`), and must
    /// construct the shard's full service — engine, planner, battery —
    /// but **not** durability, which is attached per-shard afterwards.
    /// Builders are `Fn` (rerunnable): failover rebuilds a dead shard's
    /// worker from the same recipe. `routing_seed` seeds the router's
    /// UCDP table and anchors [`FleetService::shard_seeds`].
    pub fn new(
        builders: Vec<Box<dyn Fn() -> Result<UnlearningService> + Send + Sync>>,
        routing_seed: u64,
    ) -> Result<FleetService> {
        if builders.is_empty() {
            bail!("fleet needs at least one worker");
        }
        let n = builders.len();
        let factories: Vec<ShardFactory> = builders.into_iter().map(ShardFactory::from).collect();
        let (event_tx, event_rx) = std::sync::mpsc::channel::<(usize, Reply)>();
        let workers: Vec<WorkerHandle> = factories
            .iter()
            .enumerate()
            .map(|(k, build)| worker::spawn(k, build.clone(), event_tx.clone()))
            .collect();
        let fleet = FleetService {
            router: Router::new(n, routing_seed),
            workers,
            factories,
            events: event_rx,
            event_tx,
            seeds: FleetService::derive_shard_seeds(routing_seed, n),
            round: 0,
            alive: vec![true; n],
            parked: (0..n).map(|_| Vec::new()).collect(),
            battery: None,
            dura_spec: vec![None; n],
            shipping: None,
            tracer: None,
            now_tick: 0,
        };
        // One Ready (or builder Err) per worker; first failure wins in
        // shard order. Drop shuts the healthy workers down.
        let ready = fleet.collect(|reply| match reply {
            Reply::Ready => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        for (k, r) in ready.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} failed to build: {e}"));
            }
        }
        Ok(fleet)
    }

    /// Collect exactly one terminal reply per worker, answering
    /// [`Reply::Quote`]s with centrally computed admission verdicts as
    /// they arrive. `classify` returns `Ok(v)` for a terminal reply or
    /// `Err(reply)` for an unexpected one. Results land in shard order.
    fn collect<T>(&self, mut classify: impl FnMut(Reply) -> Result<T, Reply>) -> Result<Vec<T>> {
        let n = self.workers.len();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut remaining = n;
        while remaining > 0 {
            let (k, reply) = self
                .events
                .recv()
                .map_err(|_| anyhow!("fleet worker hung up"))?;
            match reply {
                Reply::Quote { costs, battery } => {
                    let verdict = admission_decide(costs.as_deref(), battery.as_ref());
                    self.workers[k]
                        .grant
                        .send(verdict)
                        .map_err(|_| anyhow!("fleet worker {k} hung up awaiting grant"))?;
                }
                other => match classify(other) {
                    Ok(v) => {
                        debug_assert!(out[k].is_none(), "one terminal reply per worker");
                        out[k] = Some(v);
                        remaining -= 1;
                    }
                    Err(unexpected) => {
                        bail!("unexpected reply from fleet worker {k}: {unexpected:?}")
                    }
                },
            }
        }
        Ok(out.into_iter().map(|v| v.expect("all workers replied")).collect())
    }

    fn send(&self, k: usize, cmd: Cmd) {
        self.workers[k].cmd.send(cmd).expect("fleet worker hung up");
    }

    /// Fire-and-forget dispatch: a dead shard parks the command (in
    /// arrival order) until failover rebuilds it.
    fn dispatch(&mut self, k: usize, cmd: Cmd) {
        if self.alive[k] {
            self.send(k, cmd);
        } else {
            self.parked[k].push(cmd);
        }
    }

    /// Fallible fleet operations refuse to run while any shard is dead —
    /// a partial answer over a sharded obligation set would be a silent
    /// lie. Recover the shard with [`FleetService::failover`] first.
    fn ensure_all_alive(&self) -> Result<()> {
        match self.alive.iter().position(|a| !a) {
            None => Ok(()),
            Some(k) => Err(anyhow!("fleet worker {k} is dead; recover it with failover({k})")),
        }
    }

    /// Route and enqueue a request on its user's home shard (FCFS within
    /// the shard, arrival stamped on the shard's service clock — which
    /// all workers advance in lockstep). A dead home shard parks the
    /// request; failover delivers it after recovery, so acceptance
    /// ordering survives the shard's death.
    pub fn submit(&mut self, req: UnlearnRequest) {
        let k = self.router.route(req.user, req.total_samples());
        self.dispatch(k, Cmd::Submit(req));
    }

    /// Run one training round: route the round's blocks by user, hand
    /// each worker its shard's slice of the population, and ingest on
    /// every worker (possibly an empty slice — round counters advance in
    /// lockstep fleet-wide).
    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        self.ensure_all_alive()?;
        self.round += 1;
        // Mirror the workers' clocks: each shard's ingest advances its
        // service clock by one tick.
        self.now_tick = self.now_tick.saturating_add(1);
        let span = crate::obs::begin_root(&mut self.tracer, "fleet_ingest", self.now_tick);
        for b in pop.blocks_at(self.round) {
            self.router.route(b.user, b.samples);
        }
        let n = self.workers.len();
        for k in 0..n {
            let slice = if n == 1 {
                pop.clone()
            } else {
                pop.filter_users(|u| self.router.lookup(u) == Some(k))
            };
            self.send(k, Cmd::Ingest(Box::new(slice)));
        }
        let acks = self.collect(|reply| match reply {
            Reply::Ingested => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        crate::obs::end(&mut self.tracer, span, self.now_tick, u64::from(self.round));
        for (k, r) in acks.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} ingest failed: {e}"));
            }
        }
        Ok(())
    }

    /// Advance every shard's service clock (fleet clocks move in
    /// lockstep; a dead shard's ticks are parked and replayed in order at
    /// failover, so its recovered clock catches up exactly).
    pub fn advance(&mut self, ticks: u64) {
        self.now_tick = self.now_tick.saturating_add(ticks);
        for k in 0..self.workers.len() {
            self.dispatch(k, Cmd::Advance(ticks));
        }
    }

    /// Advance harvest time on every shard's battery.
    pub fn harvest(&mut self, secs: f64) {
        for k in 0..self.workers.len() {
            self.dispatch(k, Cmd::Harvest(secs));
        }
    }

    /// Give every shard its own battery (clones of `battery` — each
    /// worker draws from its own charge; admission stays centralized).
    /// The template is retained so failover re-arms the replacement
    /// worker before recovery replays its log.
    pub fn with_battery(mut self, battery: Battery) -> Self {
        self.battery = Some(battery.clone());
        for k in 0..self.workers.len() {
            self.dispatch(k, Cmd::SetBattery(battery.clone()));
        }
        self
    }

    /// Drain batched windows on every shard, admitting each priced
    /// window centrally (two-phase price-then-commit). Returns the total
    /// requests served across the fleet; on shard errors, the first in
    /// shard order (after every shard has settled, so no replies are
    /// left in flight).
    pub fn drain_batched(&mut self) -> Result<usize> {
        self.drain(false)
    }

    /// Drain everything queued regardless of deadline slack (end of run
    /// / device shutdown), fleet-wide.
    pub fn flush_batched(&mut self) -> Result<usize> {
        self.drain(true)
    }

    fn drain(&mut self, flush: bool) -> Result<usize> {
        self.ensure_all_alive()?;
        let root = crate::obs::begin_root(
            &mut self.tracer,
            if flush { "fleet_flush" } else { "fleet_drain" },
            self.now_tick,
        );
        for k in 0..self.workers.len() {
            // `root` rides to each worker so the shard-side drain span
            // parents to this front-end span across the channel boundary
            // (0 = tracing off).
            self.send(k, Cmd::Drain { flush, parent: root });
        }
        let results = self.collect(|reply| match reply {
            Reply::Served(n) => Ok(Ok(n)),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        let mut served = 0;
        for (k, r) in results.into_iter().enumerate() {
            match r {
                Ok(n) => served += n,
                Err(e) => {
                    crate::obs::end(&mut self.tracer, root, self.now_tick, served as u64);
                    return Err(anyhow!("fleet worker {k} drain failed: {e}"));
                }
            }
        }
        crate::obs::end(&mut self.tracer, root, self.now_tick, served as u64);
        Ok(served)
    }

    /// Attach one durability journal per shard (index = shard). Each
    /// worker recovers whatever its filesystem holds, then arms
    /// log-before-ack journaling.
    pub fn attach_durability(&mut self, ds: Vec<Durability>) -> Result<Vec<RecoveryReport>> {
        self.ensure_all_alive()?;
        if ds.len() != self.workers.len() {
            bail!(
                "fleet has {} workers but {} durability journals",
                self.workers.len(),
                ds.len()
            );
        }
        for (k, d) in ds.into_iter().enumerate() {
            // Failover re-attaches the replacement shard with the same
            // spec (over a materialized replica — the dead disk is lost).
            self.dura_spec[k] = Some((d.mode, d.fsync, d.compact_every));
            self.send(k, Cmd::AttachDurability(d));
        }
        let reports = self.collect(|reply| match reply {
            Reply::Attached(r) => Ok(Ok(*r)),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        reports
            .into_iter()
            .enumerate()
            .map(|(k, r)| r.map_err(|e| anyhow!("fleet worker {k} recovery failed: {e}")))
            .collect()
    }

    /// Attach per-shard disk journals under `dir`. A single-worker fleet
    /// uses `dir` itself — its WAL is drop-in compatible with (and can
    /// recover) an unsharded service's persist dir; a real fleet
    /// journals under `dir/shard-<k>/`.
    pub fn attach_durability_disk(
        &mut self,
        mode: DurabilityMode,
        dir: &str,
        compact_every: u64,
        fsync: FsyncPolicy,
    ) -> Result<Vec<RecoveryReport>> {
        let n = self.workers.len();
        let ds = (0..n)
            .map(|k| {
                let shard_dir = if n == 1 {
                    dir.to_string()
                } else {
                    format!("{dir}/shard-{k}")
                };
                Ok(Durability::disk(mode, shard_dir, compact_every)?.with_fsync(fsync))
            })
            .collect::<Result<Vec<Durability>>>()?;
        self.attach_durability(ds)
    }

    /// Like [`FleetService::collect`] but for exactly one worker —
    /// failover talks to the replacement shard while the rest of the
    /// fleet is quiescent (every other exchange fully collects before
    /// returning, so no stray replies can arrive here).
    fn collect_one<T>(
        &self,
        k: usize,
        mut classify: impl FnMut(Reply) -> Result<T, Reply>,
    ) -> Result<T> {
        loop {
            let (kk, reply) =
                self.events.recv().map_err(|_| anyhow!("fleet worker hung up"))?;
            if kk != k {
                bail!("unexpected reply from fleet worker {kk} while waiting on {k}");
            }
            match reply {
                Reply::Quote { costs, battery } => {
                    let verdict = admission_decide(costs.as_deref(), battery.as_ref());
                    self.workers[k]
                        .grant
                        .send(verdict)
                        .map_err(|_| anyhow!("fleet worker {k} hung up awaiting grant"))?;
                }
                other => {
                    return classify(other)
                        .map_err(|u| anyhow!("unexpected reply from fleet worker {k}: {u:?}"))
                }
            }
        }
    }

    /// Enable cross-shard log shipping over the default in-process
    /// transport: each shard streams its sealed WAL frames into a shared
    /// [`ReplicaStore`] — shard `k`'s replica is conceptually hosted by
    /// peer `(k + 1) % n` — so [`FleetService::failover`] can rebuild a
    /// dead shard with zero acknowledged obligations lost. Requires
    /// durability to be attached first.
    pub fn enable_log_shipping(&mut self) -> Result<ReplicaStore> {
        self.enable_log_shipping_with(|_, store| Box::new(store))
    }

    /// Like [`FleetService::enable_log_shipping`] but with a custom
    /// transport per shard (fault-injection wrappers, etc.); `make` also
    /// rebuilds the transport when failover re-enables shipping on a
    /// recovered shard. Returns the shared replica store for inspection.
    pub fn enable_log_shipping_with(
        &mut self,
        make: impl Fn(usize, ReplicaStore) -> Box<dyn ShipTransport> + Send + Sync + 'static,
    ) -> Result<ReplicaStore> {
        let store = ReplicaStore::new();
        let st = store.clone();
        self.enable_shipping_inner(
            Arc::new(store.clone()),
            Arc::new(move |k| make(k, st.clone())),
            Some(store.clone()),
        )?;
        Ok(store)
    }

    /// Ship over a fully custom transport family whose durable state
    /// lives *outside* the fleet process (e.g. [`FileSpool`] directories
    /// on disk — [`crate::persist::FileSpool`]). `source` is where
    /// failover reads a dead shard's replica back from; for an
    /// out-of-process spool it should **reopen** the spool from its
    /// backing store rather than trust any in-memory copy, so recovery
    /// exercises the same path a fresh process would.
    pub fn enable_log_shipping_custom(
        &mut self,
        source: Arc<dyn ReplicaSource>,
        make: impl Fn(usize) -> Box<dyn ShipTransport> + Send + Sync + 'static,
    ) -> Result<()> {
        self.enable_shipping_inner(source, Arc::new(make), None)
    }

    fn enable_shipping_inner(
        &mut self,
        source: Arc<dyn ReplicaSource>,
        make: TransportFactory,
        store: Option<ReplicaStore>,
    ) -> Result<()> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(
                k,
                Cmd::EnableShipping {
                    source: k,
                    transport: make(k),
                    retry_limit: SHIP_RETRY_LIMIT,
                },
            );
        }
        let acks = self.collect(|reply| match reply {
            Reply::ShipEnabled => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        for (k, r) in acks.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} failed to enable shipping: {e}"));
            }
        }
        self.shipping = Some(Shipping { source, make, retry_limit: SHIP_RETRY_LIMIT, store });
        Ok(())
    }

    /// Seal every shard's group-commit window (one fsync barrier each)
    /// and give each shipper a flush opportunity. Drive this until
    /// [`FleetService::shipping_states`] shows no pending frames to
    /// guarantee the peers hold everything acknowledged so far.
    pub fn sync_journals(&mut self) -> Result<()> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::SyncJournal);
        }
        let acks = self.collect(|reply| match reply {
            Reply::Synced => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        for (k, r) in acks.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} journal sync failed: {e}"));
            }
        }
        Ok(())
    }

    /// Per-shard shipping receipts + journal next_seq, in shard order
    /// (`None` receipt = shipping not enabled on that shard).
    pub fn shipping_states(&self) -> Result<Vec<(Option<ShipReceipt>, u64)>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::ShipState);
        }
        self.collect(|reply| match reply {
            Reply::Shipping { receipt, log_seq } => Ok((receipt, log_seq)),
            other => Err(other),
        })
    }

    /// Compact every shard's journal (snapshot + log-prefix truncation).
    pub fn compact_now(&mut self) -> Result<()> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Compact);
        }
        let acks = self.collect(|reply| match reply {
            Reply::Compacted => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        for (k, r) in acks.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} compaction failed: {e}"));
            }
        }
        Ok(())
    }

    /// Per-shard latency histograms, recorded at the workers and carried
    /// whole to the front-end (not reconstructed from raw metrics), plus
    /// each shard's exact SLO-violation count against `slo_ticks`.
    pub fn shard_latency_histograms(
        &self,
        slo_ticks: u64,
    ) -> Result<Vec<(LatencyHistogram, u64)>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::LatencyHist { slo_ticks });
        }
        self.collect(|reply| match reply {
            Reply::LatencyHist { hist, violations } => Ok((*hist, violations)),
            other => Err(other),
        })
    }

    /// The fleet's merged latency histogram (lossless bucket-wise merge
    /// of the per-shard histograms; surfaces in the fleet receipt).
    pub fn latency_histogram(&self) -> Result<LatencyHistogram> {
        let mut merged = LatencyHistogram::new();
        for (h, _) in self.shard_latency_histograms(u64::MAX)? {
            merged.merge(&h);
        }
        Ok(merged)
    }

    /// The shared replica store, when shipping is enabled over the
    /// default in-process transport family (tests poll watermarks
    /// through this). `None` for custom out-of-process sources.
    pub fn replica_store(&self) -> Option<&ReplicaStore> {
        self.shipping.as_ref().and_then(|s| s.store.as_ref())
    }

    /// Per-shard aggregate journal counters (fsync stats, log/snapshot
    /// bytes), in shard order; `None` entries have no journal attached.
    pub fn journal_stats(&self) -> Result<Vec<Option<JournalStats>>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::JournalStats);
        }
        self.collect(|reply| match reply {
            Reply::JournalStats(s) => Ok(s),
            other => Err(other),
        })
    }

    /// Each shard journal's durable state as a [`Replica`]-shaped value,
    /// in shard order. The chaos soak's byte-convergence invariant
    /// compares these against the peers' shipped replicas.
    pub fn journal_images(&self) -> Result<Vec<Option<Replica>>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::JournalImage);
        }
        self.collect(|reply| match reply {
            Reply::JournalImage(r) => Ok(*r),
            other => Err(other),
        })
    }

    /// Kill shard `k`'s worker outright — the crash model for failover
    /// testing. The worker thread is shut down and joined; its in-memory
    /// state and local journal filesystem are treated as lost (only what
    /// was shipped survives). Commands addressed to the dead shard park
    /// until [`FleetService::failover`]; fallible fleet operations error
    /// until then.
    pub fn kill_worker(&mut self, k: usize) -> Result<()> {
        if k >= self.workers.len() {
            bail!("no fleet worker {k}");
        }
        if !self.alive[k] {
            return Ok(());
        }
        let _ = self.workers[k].cmd.send(Cmd::Shutdown);
        if let Some(join) = self.workers[k].join.take() {
            let _ = join.join();
        }
        self.alive[k] = false;
        Ok(())
    }

    /// Rebuild dead shard `k` from its shipped replica: spawn a fresh
    /// worker from the shard's factory, re-arm its battery template,
    /// recover it from the materialized replica (snapshot + shipped
    /// frames through the standard recovery path), re-enable shipping,
    /// and deliver the commands that parked while the shard was dead —
    /// in arrival order, so acceptance ordering is preserved. Bumps the
    /// routing epoch (the failover is receipt-auditable). Returns the
    /// replacement's recovery report: every obligation acknowledged
    /// below the shipped watermark is back.
    pub fn failover(&mut self, k: usize) -> Result<RecoveryReport> {
        self.failover_wrapped(k, |fs| Box::new(fs))
    }

    /// [`FleetService::failover`] with the replacement shard's journal
    /// filesystem wrapped by `wrap` — the chaos harness re-wraps it in a
    /// tracked [`FailpointFs`](crate::testkit::FailpointFs) so fault
    /// injection keeps reaching shards across failovers.
    pub fn failover_wrapped(
        &mut self,
        k: usize,
        wrap: impl FnOnce(MemFs) -> Box<dyn PersistFs>,
    ) -> Result<RecoveryReport> {
        if k >= self.workers.len() {
            bail!("no fleet worker {k}");
        }
        if self.alive[k] {
            bail!("fleet worker {k} is alive; kill_worker({k}) first");
        }
        let Some((mode, fsync, compact_every)) = self.dura_spec[k] else {
            bail!("failover needs durability attached on shard {k}");
        };
        let (source, make, retry_limit) = match &self.shipping {
            Some(s) => (s.source.clone(), s.make.clone(), s.retry_limit),
            None => bail!("failover needs log shipping enabled"),
        };
        let replica = source.replica(k).unwrap_or_default();
        let fs = materialize_replica(&replica);

        // A fresh worker from the same recipe, on the same event channel
        // and shard slot.
        self.workers[k] = worker::spawn(k, self.factories[k].clone(), self.event_tx.clone());
        self.collect_one(k, |reply| match reply {
            Reply::Ready => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?
        .map_err(|e| anyhow!("failover rebuild of fleet worker {k} failed: {e}"))?;
        if let Some(b) = &self.battery {
            self.send(k, Cmd::SetBattery(b.clone()));
        }
        // Recover from the peer's copy; the report says what came back.
        self.send(
            k,
            Cmd::AttachDurability(Durability { mode, fs: wrap(fs), compact_every, fsync }),
        );
        let report = self
            .collect_one(k, |reply| match reply {
                Reply::Attached(r) => Ok(Ok(*r)),
                Reply::Err(e) => Ok(Err(e)),
                other => Err(other),
            })?
            .map_err(|e| anyhow!("failover recovery of fleet worker {k} failed: {e}"))?;
        // The replacement ships again (its prime re-converges the peer's
        // replica to the recovered generation).
        self.send(k, Cmd::EnableShipping { source: k, transport: make(k), retry_limit });
        self.collect_one(k, |reply| match reply {
            Reply::ShipEnabled => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?
        .map_err(|e| anyhow!("failover re-shipping on fleet worker {k} failed: {e}"))?;
        self.alive[k] = true;
        // Replay what arrived while the shard was dead, in order.
        for cmd in std::mem::take(&mut self.parked[k]) {
            self.send(k, cmd);
        }
        self.router.note_failover();
        Ok(report)
    }

    /// Deterministic digest of the whole fleet. A 1-worker fleet returns
    /// its only shard's receipt **verbatim** (the keystone equivalence:
    /// byte-identical to [`UnlearningService::state_receipt`]); a real
    /// fleet wraps per-shard receipts (shard order) with the routing
    /// state — seed, epoch, active range, and the derived per-shard
    /// engine seeds (hex, so full u64 precision survives JSON) for seed
    /// auditing — plus the fleet's merged latency histogram and, when log
    /// shipping is on, each shard's shipping watermark with retry
    /// diagnostics (attempts / faults / last transport error) and its
    /// journal's fsync counters.
    pub fn state_receipt(&self) -> Result<Json> {
        let mut receipts = self.shard_receipts()?;
        if receipts.len() == 1 {
            return Ok(receipts.remove(0));
        }
        let routing = Json::obj()
            .set("seed", format!("{:#018x}", self.router.seed()))
            .set("epoch", self.router.epoch())
            .set("active", self.router.active())
            .set("workers", self.router.workers())
            .set(
                "shard_seeds",
                Json::Arr(
                    self.seeds
                        .iter()
                        .map(|s| Json::Str(format!("{s:#018x}")))
                        .collect(),
                ),
            );
        let mut out = Json::obj()
            .set("routing", routing)
            .set("latency_hist", self.latency_histogram()?.to_json());
        if self.shipping.is_some() {
            let stats = self.journal_stats()?;
            let states = self
                .shipping_states()?
                .into_iter()
                .zip(stats)
                .map(|((r, log_seq), js)| {
                    // Physical journal counters ride with the (equally
                    // physical) shipping diagnostics; the logical state
                    // digest under "shards" stays history-independent.
                    let journal = js.map_or(Json::Null, |s| {
                        Json::obj()
                            .set("appended", s.appended)
                            .set("fsyncs", s.fsyncs)
                            .set("events_in_log", s.events_in_log)
                            .set("log_bytes", s.log_bytes)
                            .set("snapshot_bytes", s.snapshot_bytes)
                    });
                    let o = Json::obj().set("log_seq", log_seq).set("journal", journal);
                    match r {
                        Some(r) => o
                            .set("shipped", r.shipped_seq)
                            .set("pending", r.pending)
                            .set("attempts", r.attempts)
                            .set("faults", r.faults)
                            .set("last_error", r.last_error.map_or(Json::Null, Json::Str))
                            .set("failed", r.failed.map_or(Json::Null, Json::Str)),
                        None => o,
                    }
                })
                .collect();
            out = out.set("shipping", Json::Arr(states));
        }
        Ok(out.set("shards", Json::Arr(receipts)))
    }

    /// Per-shard state receipts in shard order.
    pub fn shard_receipts(&self) -> Result<Vec<Json>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Receipt);
        }
        let receipts = self.collect(|reply| match reply {
            Reply::Receipt(j) => Ok(*j),
            other => Err(other),
        })?;
        Ok(receipts)
    }

    /// Fleet-aggregate run metrics ([`RunMetrics::fleet_aggregate`] over
    /// the shards in shard order; the identity for one worker).
    pub fn metrics(&self) -> Result<RunMetrics> {
        Ok(RunMetrics::fleet_aggregate(&self.shard_metrics()?))
    }

    /// Per-shard run metrics in shard order.
    pub fn shard_metrics(&self) -> Result<Vec<RunMetrics>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Metrics);
        }
        self.collect(|reply| match reply {
            Reply::Metrics(m) => Ok(*m),
            other => Err(other),
        })
    }

    /// Turn on span tracing at the fleet front-end. Workers trace (or
    /// not) per their own build config — [`SystemVariant::build_fleet`]
    /// enables both sides from one `obs` knob.
    ///
    /// [`SystemVariant::build_fleet`]: crate::coordinator::system::SystemVariant::build_fleet
    pub fn enable_obs(&mut self) {
        if self.tracer.is_none() {
            self.tracer = Some(crate::obs::Tracer::new(FRONT_END_SHARD));
        }
    }

    /// Whether front-end span tracing is enabled.
    pub fn obs_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// Stamp an instant marker (scenario phase, injected fault) into the
    /// front-end trace lane. No-op when tracing is off.
    pub fn obs_marker(&mut self, name: &'static str) {
        let tick = self.now_tick;
        crate::obs::marker(&mut self.tracer, name, tick, 0);
    }

    /// Every retained span record across the fleet: the front-end lane
    /// first, then each shard's (shard order). One flat vec — the
    /// exporters lane-split by shard key.
    pub fn trace_records(&self) -> Result<Vec<crate::obs::SpanRec>> {
        self.ensure_all_alive()?;
        let mut out = self
            .tracer
            .as_ref()
            .map_or_else(Vec::new, crate::obs::Tracer::records);
        for k in 0..self.workers.len() {
            self.send(k, Cmd::ObsSpans);
        }
        let shards = self.collect(|reply| match reply {
            Reply::ObsSpans(v) => Ok(v),
            other => Err(other),
        })?;
        for v in shards {
            out.extend(v);
        }
        Ok(out)
    }

    /// The fleet's named-metrics registry. A 1-worker fleet returns its
    /// only shard's registry **verbatim** (byte-identical JSON to the
    /// unsharded [`UnlearningService::registry`]); a real fleet merges
    /// the per-shard registries in shard order (counters sum, gauges sum,
    /// labels union, histograms merge).
    pub fn registry(&self) -> Result<crate::obs::Registry> {
        let mut regs = self.shard_registries()?;
        if regs.len() == 1 {
            return Ok(regs.remove(0));
        }
        let mut out = crate::obs::Registry::new();
        for r in &regs {
            out.merge(r);
        }
        Ok(out)
    }

    /// Per-shard named-metrics registries in shard order.
    pub fn shard_registries(&self) -> Result<Vec<crate::obs::Registry>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::ObsRegistry);
        }
        self.collect(|reply| match reply {
            Reply::ObsRegistry(r) => Ok(*r),
            other => Err(other),
        })
    }

    /// Per-window receipts, concatenated in shard order.
    pub fn batch_log(&self) -> Result<Vec<BatchReport>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::BatchLog);
        }
        let logs = self.collect(|reply| match reply {
            Reply::BatchLog(l) => Ok(l),
            other => Err(other),
        })?;
        Ok(logs.into_iter().flatten().collect())
    }

    fn counts(&self) -> Result<Vec<(usize, usize, usize)>> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Counts);
        }
        self.collect(|reply| match reply {
            Reply::Counts { pending, carryover_requests, carryover_lineages } => {
                Ok((pending, carryover_requests, carryover_lineages))
            }
            other => Err(other),
        })
    }

    /// Requests still queued across the fleet.
    pub fn pending(&self) -> Result<usize> {
        Ok(self.counts()?.iter().map(|c| c.0).sum())
    }

    /// Requests parked in carryover plans across the fleet.
    pub fn carryover_requests(&self) -> Result<usize> {
        Ok(self.counts()?.iter().map(|c| c.1).sum())
    }

    /// Lineages with parked replay work across the fleet (shutdown loops
    /// poll this, exactly as for the unsharded service).
    pub fn carryover_lineages(&self) -> Result<usize> {
        Ok(self.counts()?.iter().map(|c| c.2).sum())
    }

    /// Events currently in the fleet's log tails (sum over shards).
    pub fn journal_events(&self) -> Result<u64> {
        self.ensure_all_alive()?;
        for k in 0..self.workers.len() {
            self.send(k, Cmd::JournalEvents);
        }
        let events = self.collect(|reply| match reply {
            Reply::Events(n) => Ok(n),
            other => Err(other),
        })?;
        Ok(events.iter().sum())
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Routing epoch (bumped by shard-range changes; see
    /// [`Router::set_active`]).
    pub fn epoch(&self) -> u64 {
        self.router.epoch()
    }

    /// Narrow (or re-widen) the shard range offered to new users — the
    /// routing-layer image of a shard-controller shrink. Existing users
    /// keep routing to the shard holding their past data.
    pub fn set_active_shards(&mut self, n: usize) {
        self.router.set_active(n);
    }

    pub fn active_shards(&self) -> usize {
        self.router.active()
    }

    /// The derived per-shard engine seeds (shard 0 = the routing seed).
    pub fn shard_seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// A user's home shard, if they have ever been routed.
    pub fn shard_of(&self, user: UserId) -> Option<ShardId> {
        self.router.lookup(user)
    }

    /// Rebuild the router's sticky table after a whole-fleet restart by
    /// replaying the routing touches of `pop`'s first `rounds` training
    /// rounds in ingest order. Workers recover their engines from their
    /// journals, but the front-end router is in-memory only; replaying
    /// the same touch sequence against the same routing seed lands every
    /// previously-ingested user back on their home shard.
    pub fn warm_routes(&mut self, pop: &EdgePopulation, rounds: u32) {
        for r in 1..=rounds {
            for b in pop.blocks_at(r) {
                self.router.route(b.user, b.samples);
            }
        }
    }
}

impl Drop for FleetService {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}
