//! Sharded fleet service: N independent shard workers behind the
//! unsharded [`UnlearningService`] surface.
//!
//! [`FleetService`] promotes UCDP's user→shard map to a front-end
//! [`Router`] and runs one worker per shard, each owning a full service
//! stack — engine, model store, battery, batch planner, and (when
//! durability is on) its own write-ahead log under
//! `persist_dir/shard-<k>/`. Submits and round ingests fan out over
//! channels by the router's sticky assignment; batched drains run
//! windows per-shard but admit battery energy centrally through a
//! two-phase price-then-commit exchange; per-shard receipts merge into
//! one fleet receipt with deterministic ordering given the routing seed.
//!
//! **Keystone invariant**: `fleet_workers = 1` replays the unsharded
//! service byte-identically — same receipts, RSN, store stats, and
//! journal. Worker 0 runs the root config seed, routing is a no-op over
//! one shard, and admission verdicts come from the same
//! [`admission_decide`] the standalone service calls inline, so every
//! transition is the same function applied to the same state.
//!
//! Per-shard engine seeds derive deterministically from
//! `(routing_seed, shard)` via the crate PRNG's fork discipline
//! ([`FleetService::derive_shard_seeds`]), and surface in the fleet
//! state receipt so recovery of any shard is seed-auditable.

mod router;
mod worker;

pub use router::Router;

use std::sync::mpsc::{Receiver, Sender};

use anyhow::{anyhow, bail, Result};

use crate::data::dataset::{EdgePopulation, UserId};
use crate::data::trace::UnlearnRequest;
use crate::metrics::RunMetrics;
use crate::partition::ShardId;
use crate::persist::recovery::RecoveryReport;
use crate::persist::{Durability, DurabilityMode};
use crate::prng::Rng;
use crate::sim::Battery;
use crate::unlearning::service::admission_decide;
use crate::unlearning::{BatchReport, UnlearningService};
use crate::util::Json;

use worker::{Cmd, Reply, WorkerHandle};

/// A fleet of shard workers behind the unsharded service surface.
pub struct FleetService {
    router: Router,
    workers: Vec<WorkerHandle>,
    events: Receiver<(usize, Reply)>,
    seeds: Vec<u64>,
    /// Fleet-level round counter (mirrors each worker's ingest count).
    round: u32,
}

impl FleetService {
    /// Derive the per-shard engine seeds from the routing seed. Shard 0
    /// keeps the root seed itself — that is what makes a 1-worker fleet
    /// byte-identical to an unsharded service built from the same config
    /// — and every later shard gets an independent stream from the crate
    /// PRNG's fork discipline (root stream advanced once per shard, so
    /// the derivation is order-independent of fleet operations).
    pub fn derive_shard_seeds(routing_seed: u64, workers: usize) -> Vec<u64> {
        let mut root = Rng::new(routing_seed);
        (0..workers)
            .map(|k| if k == 0 { routing_seed } else { root.fork(k as u64).next_u64() })
            .collect()
    }

    /// Spawn one worker per builder. Each closure runs *inside* its
    /// worker thread (the engine's trainer is not `Send`), and must
    /// construct the shard's full service — engine, planner, battery —
    /// but **not** durability, which is attached per-shard afterwards.
    /// `routing_seed` seeds the router's UCDP table and anchors
    /// [`FleetService::shard_seeds`].
    pub fn new(
        builders: Vec<Box<dyn FnOnce() -> Result<UnlearningService> + Send>>,
        routing_seed: u64,
    ) -> Result<FleetService> {
        if builders.is_empty() {
            bail!("fleet needs at least one worker");
        }
        let n = builders.len();
        let (event_tx, event_rx) = std::sync::mpsc::channel::<(usize, Reply)>();
        let workers: Vec<WorkerHandle> = builders
            .into_iter()
            .enumerate()
            .map(|(k, build)| worker::spawn(k, build, event_tx.clone()))
            .collect();
        drop(event_tx);
        let fleet = FleetService {
            router: Router::new(n, routing_seed),
            workers,
            events: event_rx,
            seeds: FleetService::derive_shard_seeds(routing_seed, n),
            round: 0,
        };
        // One Ready (or builder Err) per worker; first failure wins in
        // shard order. Drop shuts the healthy workers down.
        let ready = fleet.collect(|reply| match reply {
            Reply::Ready => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        for (k, r) in ready.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} failed to build: {e}"));
            }
        }
        Ok(fleet)
    }

    /// Collect exactly one terminal reply per worker, answering
    /// [`Reply::Quote`]s with centrally computed admission verdicts as
    /// they arrive. `classify` returns `Ok(v)` for a terminal reply or
    /// `Err(reply)` for an unexpected one. Results land in shard order.
    fn collect<T>(&self, mut classify: impl FnMut(Reply) -> Result<T, Reply>) -> Result<Vec<T>> {
        let n = self.workers.len();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut remaining = n;
        while remaining > 0 {
            let (k, reply) = self
                .events
                .recv()
                .map_err(|_| anyhow!("fleet worker hung up"))?;
            match reply {
                Reply::Quote { costs, battery } => {
                    let verdict = admission_decide(costs.as_deref(), battery.as_ref());
                    self.workers[k]
                        .grant
                        .send(verdict)
                        .map_err(|_| anyhow!("fleet worker {k} hung up awaiting grant"))?;
                }
                other => match classify(other) {
                    Ok(v) => {
                        debug_assert!(out[k].is_none(), "one terminal reply per worker");
                        out[k] = Some(v);
                        remaining -= 1;
                    }
                    Err(unexpected) => {
                        bail!("unexpected reply from fleet worker {k}: {unexpected:?}")
                    }
                },
            }
        }
        Ok(out.into_iter().map(|v| v.expect("all workers replied")).collect())
    }

    fn send(&self, k: usize, cmd: Cmd) {
        self.workers[k].cmd.send(cmd).expect("fleet worker hung up");
    }

    /// Route and enqueue a request on its user's home shard (FCFS within
    /// the shard, arrival stamped on the shard's service clock — which
    /// all workers advance in lockstep).
    pub fn submit(&mut self, req: UnlearnRequest) {
        let k = self.router.route(req.user, req.total_samples());
        self.send(k, Cmd::Submit(req));
    }

    /// Run one training round: route the round's blocks by user, hand
    /// each worker its shard's slice of the population, and ingest on
    /// every worker (possibly an empty slice — round counters advance in
    /// lockstep fleet-wide).
    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        self.round += 1;
        for b in pop.blocks_at(self.round) {
            self.router.route(b.user, b.samples);
        }
        let n = self.workers.len();
        for k in 0..n {
            let slice = if n == 1 {
                pop.clone()
            } else {
                pop.filter_users(|u| self.router.lookup(u) == Some(k))
            };
            self.send(k, Cmd::Ingest(Box::new(slice)));
        }
        let acks = self.collect(|reply| match reply {
            Reply::Ingested => Ok(Ok(())),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        for (k, r) in acks.into_iter().enumerate() {
            if let Err(e) = r {
                return Err(anyhow!("fleet worker {k} ingest failed: {e}"));
            }
        }
        Ok(())
    }

    /// Advance every shard's service clock (fleet clocks move in
    /// lockstep).
    pub fn advance(&mut self, ticks: u64) {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Advance(ticks));
        }
    }

    /// Advance harvest time on every shard's battery.
    pub fn harvest(&mut self, secs: f64) {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Harvest(secs));
        }
    }

    /// Give every shard its own battery (clones of `battery` — each
    /// worker draws from its own charge; admission stays centralized).
    pub fn with_battery(self, battery: Battery) -> Self {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::SetBattery(battery.clone()));
        }
        self
    }

    /// Drain batched windows on every shard, admitting each priced
    /// window centrally (two-phase price-then-commit). Returns the total
    /// requests served across the fleet; on shard errors, the first in
    /// shard order (after every shard has settled, so no replies are
    /// left in flight).
    pub fn drain_batched(&mut self) -> Result<usize> {
        self.drain(false)
    }

    /// Drain everything queued regardless of deadline slack (end of run
    /// / device shutdown), fleet-wide.
    pub fn flush_batched(&mut self) -> Result<usize> {
        self.drain(true)
    }

    fn drain(&mut self, flush: bool) -> Result<usize> {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Drain { flush });
        }
        let results = self.collect(|reply| match reply {
            Reply::Served(n) => Ok(Ok(n)),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        let mut served = 0;
        for (k, r) in results.into_iter().enumerate() {
            match r {
                Ok(n) => served += n,
                Err(e) => return Err(anyhow!("fleet worker {k} drain failed: {e}")),
            }
        }
        Ok(served)
    }

    /// Attach one durability journal per shard (index = shard). Each
    /// worker recovers whatever its filesystem holds, then arms
    /// log-before-ack journaling.
    pub fn attach_durability(&mut self, ds: Vec<Durability>) -> Result<Vec<RecoveryReport>> {
        if ds.len() != self.workers.len() {
            bail!(
                "fleet has {} workers but {} durability journals",
                self.workers.len(),
                ds.len()
            );
        }
        for (k, d) in ds.into_iter().enumerate() {
            self.send(k, Cmd::AttachDurability(d));
        }
        let reports = self.collect(|reply| match reply {
            Reply::Attached(r) => Ok(Ok(*r)),
            Reply::Err(e) => Ok(Err(e)),
            other => Err(other),
        })?;
        reports
            .into_iter()
            .enumerate()
            .map(|(k, r)| r.map_err(|e| anyhow!("fleet worker {k} recovery failed: {e}")))
            .collect()
    }

    /// Attach per-shard disk journals under `dir`. A single-worker fleet
    /// uses `dir` itself — its WAL is drop-in compatible with (and can
    /// recover) an unsharded service's persist dir; a real fleet
    /// journals under `dir/shard-<k>/`.
    pub fn attach_durability_disk(
        &mut self,
        mode: DurabilityMode,
        dir: &str,
        compact_every: u64,
    ) -> Result<Vec<RecoveryReport>> {
        let n = self.workers.len();
        let ds = (0..n)
            .map(|k| {
                let shard_dir = if n == 1 {
                    dir.to_string()
                } else {
                    format!("{dir}/shard-{k}")
                };
                Ok(Durability::disk(mode, shard_dir, compact_every)?)
            })
            .collect::<Result<Vec<Durability>>>()?;
        self.attach_durability(ds)
    }

    /// Deterministic digest of the whole fleet. A 1-worker fleet returns
    /// its only shard's receipt **verbatim** (the keystone equivalence:
    /// byte-identical to [`UnlearningService::state_receipt`]); a real
    /// fleet wraps per-shard receipts (shard order) with the routing
    /// state — seed, epoch, active range, and the derived per-shard
    /// engine seeds (hex, so full u64 precision survives JSON) for seed
    /// auditing.
    pub fn state_receipt(&self) -> Result<Json> {
        let mut receipts = self.shard_receipts()?;
        if receipts.len() == 1 {
            return Ok(receipts.remove(0));
        }
        let routing = Json::obj()
            .set("seed", format!("{:#018x}", self.router.seed()))
            .set("epoch", self.router.epoch())
            .set("active", self.router.active())
            .set("workers", self.router.workers())
            .set(
                "shard_seeds",
                Json::Arr(
                    self.seeds
                        .iter()
                        .map(|s| Json::Str(format!("{s:#018x}")))
                        .collect(),
                ),
            );
        Ok(Json::obj()
            .set("routing", routing)
            .set("shards", Json::Arr(receipts)))
    }

    /// Per-shard state receipts in shard order.
    pub fn shard_receipts(&self) -> Result<Vec<Json>> {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Receipt);
        }
        let receipts = self.collect(|reply| match reply {
            Reply::Receipt(j) => Ok(*j),
            other => Err(other),
        })?;
        Ok(receipts)
    }

    /// Fleet-aggregate run metrics ([`RunMetrics::fleet_aggregate`] over
    /// the shards in shard order; the identity for one worker).
    pub fn metrics(&self) -> Result<RunMetrics> {
        Ok(RunMetrics::fleet_aggregate(&self.shard_metrics()?))
    }

    /// Per-shard run metrics in shard order.
    pub fn shard_metrics(&self) -> Result<Vec<RunMetrics>> {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Metrics);
        }
        self.collect(|reply| match reply {
            Reply::Metrics(m) => Ok(*m),
            other => Err(other),
        })
    }

    /// Per-window receipts, concatenated in shard order.
    pub fn batch_log(&self) -> Result<Vec<BatchReport>> {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::BatchLog);
        }
        let logs = self.collect(|reply| match reply {
            Reply::BatchLog(l) => Ok(l),
            other => Err(other),
        })?;
        Ok(logs.into_iter().flatten().collect())
    }

    fn counts(&self) -> Result<Vec<(usize, usize, usize)>> {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::Counts);
        }
        self.collect(|reply| match reply {
            Reply::Counts { pending, carryover_requests, carryover_lineages } => {
                Ok((pending, carryover_requests, carryover_lineages))
            }
            other => Err(other),
        })
    }

    /// Requests still queued across the fleet.
    pub fn pending(&self) -> Result<usize> {
        Ok(self.counts()?.iter().map(|c| c.0).sum())
    }

    /// Requests parked in carryover plans across the fleet.
    pub fn carryover_requests(&self) -> Result<usize> {
        Ok(self.counts()?.iter().map(|c| c.1).sum())
    }

    /// Lineages with parked replay work across the fleet (shutdown loops
    /// poll this, exactly as for the unsharded service).
    pub fn carryover_lineages(&self) -> Result<usize> {
        Ok(self.counts()?.iter().map(|c| c.2).sum())
    }

    /// Events currently in the fleet's log tails (sum over shards).
    pub fn journal_events(&self) -> Result<u64> {
        for k in 0..self.workers.len() {
            self.send(k, Cmd::JournalEvents);
        }
        let events = self.collect(|reply| match reply {
            Reply::Events(n) => Ok(n),
            other => Err(other),
        })?;
        Ok(events.iter().sum())
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Routing epoch (bumped by shard-range changes; see
    /// [`Router::set_active`]).
    pub fn epoch(&self) -> u64 {
        self.router.epoch()
    }

    /// Narrow (or re-widen) the shard range offered to new users — the
    /// routing-layer image of a shard-controller shrink. Existing users
    /// keep routing to the shard holding their past data.
    pub fn set_active_shards(&mut self, n: usize) {
        self.router.set_active(n);
    }

    pub fn active_shards(&self) -> usize {
        self.router.active()
    }

    /// The derived per-shard engine seeds (shard 0 = the routing seed).
    pub fn shard_seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// A user's home shard, if they have ever been routed.
    pub fn shard_of(&self, user: UserId) -> Option<ShardId> {
        self.router.lookup(user)
    }
}

impl Drop for FleetService {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}
