//! The fleet's routing layer: UCDP's user→shard map promoted from an
//! engine-internal partitioner detail to the front door of the sharded
//! service.
//!
//! The router owns its own [`Ucdp`] instance (seeded from the routing
//! seed, independent of each worker engine's internal partitioner) and
//! resolves every submit / round block through [`Ucdp::route`] — the
//! sticky variant of the paper's Algorithm 1 greedy: a user's first
//! appearance is placed on the θ̄-balancing shard, and every later
//! appearance returns home regardless of how the active shard count has
//! moved since. That stickiness is the fleet's locality invariant: a
//! worker holds *all* of a user's past data, so an unlearning request
//! never fans out across shards.
//!
//! Shard-controller shrink/re-home decisions surface here as **routing
//! epoch bumps**: [`Router::set_active`] narrows (or re-widens) the shard
//! range offered to *new* users and increments the epoch, while existing
//! users keep routing to their frozen home shard. Receipts carry the
//! epoch so merged fleet output is auditable against the routing history.

use crate::data::dataset::UserId;
use crate::partition::{ShardId, Ucdp};

/// User→shard routing for a fleet of `workers` shard workers.
pub struct Router {
    table: Ucdp,
    workers: usize,
    /// Shards currently offered to new users (`1..=workers`).
    active: usize,
    /// Bumped on every active-range change (shrink or re-widen).
    epoch: u64,
    seed: u64,
}

impl Router {
    pub fn new(workers: usize, seed: u64) -> Router {
        Router {
            table: Ucdp::new(workers, seed),
            workers,
            active: workers,
            epoch: 0,
            seed,
        }
    }

    /// Route `size` samples of `user` to their home shard, creating the
    /// assignment (θ̄-greedy over the active range) on first sight.
    pub fn route(&mut self, user: UserId, size: u64) -> ShardId {
        self.table.route(user, size, self.active)
    }

    /// The user's home shard, if they have ever been routed.
    pub fn lookup(&self, user: UserId) -> Option<ShardId> {
        self.table.shard_of(user)
    }

    /// Narrow (or re-widen) the shard range offered to new users; clamped
    /// to `1..=workers`. Existing users keep their frozen home shard —
    /// this is the routing-layer image of a shard-controller shrink, so a
    /// change bumps the routing epoch.
    pub fn set_active(&mut self, n: usize) {
        let n = n.clamp(1, self.workers);
        if n != self.active {
            self.active = n;
            self.epoch += 1;
        }
    }

    /// A shard died and was rebuilt from its peer's shipped log. Routing
    /// itself is unchanged — users keep their sticky home shard, and the
    /// replacement worker answers for it — but the epoch bump makes the
    /// failover auditable in every receipt that carries routing state.
    pub fn note_failover(&mut self) {
        self.epoch += 1;
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_stay_in_active_range_and_stick() {
        let mut r = Router::new(4, 7);
        let homes: Vec<ShardId> =
            (0..16).map(|u| r.route(UserId(u), 100)).collect();
        assert!(homes.iter().all(|&s| s < 4));
        assert!(homes.iter().any(|&s| s > 0), "greedy should spread users");
        // Shrink: old users keep their home, new users land in range.
        assert_eq!(r.epoch(), 0);
        r.set_active(2);
        assert_eq!(r.epoch(), 1);
        for u in 0..16 {
            assert_eq!(r.route(UserId(u), 50), homes[u as usize]);
        }
        for u in 16..32 {
            assert!(r.route(UserId(u), 100) < 2);
        }
        // No-op change does not bump the epoch; a real one does.
        r.set_active(2);
        assert_eq!(r.epoch(), 1);
        r.set_active(4);
        assert_eq!(r.epoch(), 2);
    }

    #[test]
    fn set_active_clamps() {
        let mut r = Router::new(3, 1);
        r.set_active(0);
        assert_eq!(r.active(), 1);
        r.set_active(99);
        assert_eq!(r.active(), 3);
        assert_eq!(r.workers(), 3);
        let before = r.epoch();
        r.note_failover();
        assert_eq!(r.epoch(), before + 1, "failover is epoch-visible");
    }
}
