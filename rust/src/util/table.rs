//! Fixed-width table renderer for paper-style console reports.

/// A simple column-aligned text table with a title, used by every
/// experiment to print the same rows the paper reports.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    /// Convert to a JSON object (header -> column arrays).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let mut obj = Json::obj().set("title", self.title.clone());
        let mut rows = Vec::new();
        for row in &self.rows {
            let mut o = Json::obj();
            for (h, c) in self.header.iter().zip(row) {
                o = match c.parse::<f64>() {
                    Ok(v) if v.is_finite() => o.set(h, v),
                    _ => o.set(h, c.clone()),
                };
            }
            rows.push(o);
        }
        obj = obj.set("rows", Json::Arr(rows));
        obj
    }
}

/// Format a float with `digits` decimal places (helper for experiment rows).
pub fn fnum(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["sys", "rsn"]);
        t.row(vec!["CAUSE".into(), "825".into()]);
        t.row(vec!["SISA".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.lines().count() >= 4);
        // Columns aligned: "sys" padded to len("CAUSE").
        assert!(s.contains("CAUSE  825"), "{s}");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn json_conversion_types_numbers() {
        let mut t = Table::new("x", &["sys", "rsn"]);
        t.row(vec!["CAUSE".into(), "825".into()]);
        let s = t.to_json().to_string();
        assert!(s.contains("\"rsn\":825"), "{s}");
        assert!(s.contains("\"sys\":\"CAUSE\""), "{s}");
    }
}
