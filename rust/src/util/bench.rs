//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every `rust/benches/*.rs` target with `harness = false`:
//!
//! ```ignore
//! let mut b = Bench::new("fig11");
//! b.iter("cause_default", 20, || run_fig11_once());
//! b.report();
//! ```
//!
//! Measures wall time per iteration with warmup, prints mean ± std and
//! percentiles, and honors `CAUSE_BENCH_FAST=1` (used by `make test`) to
//! shrink iteration counts.

use std::time::Instant;

use super::stats::Summary;

/// One named benchmark group.
pub struct Bench {
    name: String,
    results: Vec<(String, Summary)>,
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), results: vec![] }
    }

    /// Effective iteration count after the fast-mode override.
    pub fn iters(&self, requested: usize) -> usize {
        if std::env::var("CAUSE_BENCH_FAST").is_ok() {
            requested.min(3).max(1)
        } else {
            requested.max(1)
        }
    }

    /// Time `f` for `iters` iterations (plus one warmup run).
    pub fn iter<T>(&mut self, label: &str, iters: usize, mut f: impl FnMut() -> T) {
        let iters = self.iters(iters);
        black_box(f()); // warmup (also compiles PJRT executables etc.)
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.results.push((label.to_string(), Summary::of(&samples)));
    }

    /// Record an externally-measured sample set (e.g. per-step timings).
    pub fn record(&mut self, label: &str, secs: &[f64]) {
        self.results.push((label.to_string(), Summary::of(secs)));
    }

    /// Print the report; returns it for tee-ing into files.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("bench group: {}\n", self.name));
        for (label, s) in &self.results {
            out.push_str(&format!(
                "  {:<40} {:>10.3} ms ±{:>8.3}  (n={}, p95 {:.3} ms)\n",
                label,
                s.mean * 1e3,
                s.std * 1e3,
                s.n,
                s.p95 * 1e3
            ));
        }
        print!("{out}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        std::env::set_var("CAUSE_BENCH_FAST", "1");
        let mut b = Bench::new("t");
        b.iter("noop", 5, || 1 + 1);
        let rep = b.report();
        assert!(rep.contains("noop"));
        std::env::remove_var("CAUSE_BENCH_FAST");
    }
}
