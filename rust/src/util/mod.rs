//! Infrastructure utilities built in-repo (the offline registry has no
//! serde/criterion/prettytable): a tiny JSON writer, a fixed-width table
//! renderer for the paper-style reports, summary statistics, and the
//! micro-benchmark harness used by `cargo bench`.

pub mod bench;
pub mod json;
pub mod stats;
pub mod table;

pub use json::Json;
pub use stats::Summary;
pub use table::Table;
