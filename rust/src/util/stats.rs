//! Summary statistics for benches and experiment reporting.

/// Mean / stddev / min / max / percentiles of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.6} std={:.6} min={:.6} p50={:.6} p95={:.6} p99={:.6} max={:.6}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.p99, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p50 <= s.p95);
        assert!(s.p95 <= s.p99);
        assert!(s.p99 <= s.max);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 94.0).abs() <= 1.5);
        assert!((s.p99 - 98.0).abs() <= 1.5);
    }
}
