//! Minimal JSON value + writer (results files; serde is unavailable offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Construction is builder-style; output is deterministic
/// (object keys are sorted) so result files diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a field (only valid on `Obj`; panics otherwise — builder use).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, padc) = match indent {
            Some(w) => ("\n", " ".repeat(w * (depth + 1)), " ".repeat(w * depth)),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let j = Json::obj()
            .set("name", "fig11")
            .set("rsn", vec![825u64, 1700, 7269])
            .set("ok", true)
            .set("ratio", 0.0923)
            .set("none", Json::Null);
        let s = j.to_string();
        assert!(s.contains("\"name\":\"fig11\""), "{s}");
        assert!(s.contains("[825,1700,7269]"), "{s}");
        assert!(s.contains("0.0923"), "{s}");
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_is_indented() {
        let j = Json::obj().set("a", 1u64);
        assert_eq!(j.to_pretty(), "{\n  \"a\": 1\n}");
    }
}
