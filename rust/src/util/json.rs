//! Minimal JSON value + writer + reader (results files and the CI bench
//! regression gate; serde is unavailable offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Construction is builder-style; output is deterministic
/// (object keys are sorted) so result files diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a field (only valid on `Obj`; panics otherwise — builder use).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Field lookup (objects only).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Nested field lookup: `at(&["gate", "p99"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Exact `u64` extraction. `Num` qualifies only when it is a
    /// non-negative integer at or below 2^53 — the largest magnitude an
    /// `f64` represents exactly — so a value that round-tripped through
    /// the float parser is never silently rounded. Larger integers are
    /// carried as digit strings (see `Manifest::to_json`) and parsed
    /// here without ever touching floating point.
    pub fn as_u64(&self) -> Option<u64> {
        const EXACT_MAX: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Num(x) if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 && *x <= EXACT_MAX => {
                Some(*x as u64)
            }
            Json::Str(s) if !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()) => {
                s.parse().ok()
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document (the subset this writer emits: no exotic
    /// escapes beyond `\uXXXX`, numbers via Rust's `f64` parser). Trailing
    /// non-whitespace input is an error.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing input at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, padc) = match indent {
            Some(w) => ("\n", " ".repeat(w * (depth + 1)), " ".repeat(w * depth)),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`json.to_string()` comes through this impl).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

/// Recursive-descent reader over the writer's output subset.
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if b.is_ascii_whitespace() {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("invalid number at byte {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point (strings may hold any
                    // unicode the writer passed through unescaped).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    let c = rest.chars().next().expect("peeked non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let j = Json::obj()
            .set("name", "fig11")
            .set("rsn", vec![825u64, 1700, 7269])
            .set("ok", true)
            .set("ratio", 0.0923)
            .set("none", Json::Null);
        let s = j.to_string();
        assert!(s.contains("\"name\":\"fig11\""), "{s}");
        assert!(s.contains("[825,1700,7269]"), "{s}");
        assert!(s.contains("0.0923"), "{s}");
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_is_indented() {
        let j = Json::obj().set("a", 1u64);
        assert_eq!(j.to_pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .set("name", "gate")
            .set("vals", vec![1u64, 2, 3])
            .set("neg", -2.5)
            .set("ok", true)
            .set("none", Json::Null)
            .set("nested", Json::obj().set("p99", 4.0));
        for text in [j.to_string(), j.to_pretty()] {
            let parsed = Json::parse(&text).unwrap();
            assert_eq!(parsed, j, "reparse of {text}");
        }
        assert_eq!(j.at(&["nested", "p99"]).and_then(Json::as_f64), Some(4.0));
        assert_eq!(j.get("name").and_then(Json::as_str), Some("gate"));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("vals").and_then(Json::as_arr).map(|a| a.len()), Some(3));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn as_u64_is_exact_on_both_carriers() {
        // Num carrier: exact integers up to 2^53, inclusive.
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(5.0).as_u64(), Some(5));
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), Some(1 << 53));
        // Past 2^53 the float no longer identifies one integer — refuse.
        assert_eq!(Json::Num(9_007_199_254_741_000.0).as_u64(), None);
        assert_eq!(Json::Num(5.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_u64(), None);
        // Str carrier: exact for the full u64 range, digits only.
        assert_eq!(Json::Str(u64::MAX.to_string()).as_u64(), Some(u64::MAX));
        assert_eq!(Json::Str("12345".into()).as_u64(), Some(12345));
        assert_eq!(Json::Str("".into()).as_u64(), None);
        assert_eq!(Json::Str("-3".into()).as_u64(), None);
        assert_eq!(Json::Str("1.5".into()).as_u64(), None);
        // Overflowing digit string is a parse failure, not a wrap.
        assert_eq!(Json::Str("18446744073709551616".into()).as_u64(), None);
        assert_eq!(Json::Bool(true).as_u64(), None);
    }

    #[test]
    fn parse_escapes_and_rejects_garbage() {
        assert_eq!(
            Json::parse(r#""a\"b\\c\ndA""#).unwrap(),
            Json::Str("a\"b\\c\ndA".into())
        );
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("true false").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    // -- Round-trip property tests ----------------------------------------
    //
    // The writer/parser pair now backs both the CI bench-gate baselines
    // and the durability recovery manifest, so parse ∘ serialize must be
    // the identity on everything the writer can emit — escape sequences,
    // nested arrays/objects, and number edge cases included.

    use crate::prng::Rng;
    use crate::testkit::forall;

    /// A random string exercising every escape class the writer handles:
    /// quotes, backslashes, control characters, unicode.
    fn rand_string(rng: &mut Rng, size: f64) -> String {
        let len = rng.range(0, 2 + (24.0 * size) as usize);
        (0..len)
            .map(|_| match rng.range(0, 8) {
                0 => '"',
                1 => '\\',
                2 => '\n',
                3 => '\r',
                4 => '\t',
                5 => char::from_u32(rng.range(0, 0x20) as u32).unwrap(),
                6 => char::from_u32(0x3b1 + rng.range(0, 24) as u32).unwrap(), // α..ω
                _ => char::from_u32(0x20 + rng.range(0, 0x5f) as u32).unwrap(),
            })
            .collect()
    }

    /// Numbers across the writer's two formats (integer-rendered and
    /// shortest-roundtrip float) plus signs, zero, and magnitude edges.
    fn rand_number(rng: &mut Rng) -> f64 {
        match rng.range(0, 7) {
            0 => 0.0,
            1 => rng.below(1 << 20) as f64 - (1 << 19) as f64, // small ints
            2 => 1e15 - 1.0,                                   // integer-render bound
            3 => 1e15 + 1.0,                                   // float-render bound
            4 => (rng.f64() - 0.5) * 1e-9,                     // tiny fractions
            5 => (rng.f64() - 0.5) * 1e18,                     // huge
            _ => rng.f64() * 100.0 - 50.0,
        }
    }

    fn rand_json(rng: &mut Rng, depth: usize, size: f64) -> Json {
        let leaf_bias = if depth == 0 { 4 } else { 6 };
        match rng.range(0, leaf_bias) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num(rand_number(rng)),
            3 => Json::Str(rand_string(rng, size)),
            4 => Json::Arr(
                (0..rng.range(0, 2 + (4.0 * size) as usize))
                    .map(|_| rand_json(rng, depth - 1, size))
                    .collect(),
            ),
            _ => {
                let mut obj = Json::obj();
                for _ in 0..rng.range(0, 2 + (4.0 * size) as usize) {
                    obj = obj.set(&rand_string(rng, size), rand_json(rng, depth - 1, size));
                }
                obj
            }
        }
    }

    #[test]
    fn prop_parse_serialize_parse_roundtrips() {
        forall(
            0x15095,
            200,
            |rng, size| rand_json(rng, 3, size),
            |j| {
                for text in [j.to_string(), j.to_pretty()] {
                    let once = Json::parse(&text)
                        .map_err(|e| format!("parse failed on {text:?}: {e}"))?;
                    if once != *j {
                        return Err(format!("parse(serialize(j)) != j for {text:?}"));
                    }
                    // Serialization is a fixed point after one round trip.
                    let again = Json::parse(&once.to_string())
                        .map_err(|e| format!("reparse failed: {e}"))?;
                    if again != once {
                        return Err("parse ∘ serialize is not idempotent".into());
                    }
                    if once.to_string() != j.to_string() {
                        return Err("serialization not canonical after reparse".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_number_edge_cases_roundtrip() {
        forall(
            0xed6e5,
            300,
            |rng, _| rand_number(rng),
            |x| {
                let j = Json::Num(*x);
                let parsed = Json::parse(&j.to_string())
                    .map_err(|e| format!("parse {j}: {e}"))?;
                if parsed != j {
                    return Err(format!("number {x} did not round-trip: {parsed:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn escape_and_nesting_edge_cases_roundtrip() {
        let cases = vec![
            Json::Str("".into()),
            Json::Str("\u{0}\u{1}\u{1f}".into()),
            Json::Str("\"\\\n\r\t/".into()),
            Json::Str("κόσμε ✓ 💡".into()),
            Json::Arr(vec![]),
            Json::Arr(vec![Json::Arr(vec![Json::Arr(vec![Json::Null])])]),
            Json::obj().set("", Json::obj().set("\"nested\nkey\"", vec![1u64, 2])),
            Json::Num(-0.0), // writes "0"; IEEE equality keeps the round trip
            Json::Num(f64::MIN),
            Json::Num(f64::MAX),
            Json::Num(5e-324), // smallest subnormal
        ];
        for j in &cases {
            for text in [j.to_string(), j.to_pretty()] {
                assert_eq!(&Json::parse(&text).unwrap(), j, "case {text:?}");
            }
        }
        // Non-finite numbers degrade to null by design (JSON has no NaN).
        assert_eq!(Json::parse(&Json::Num(f64::NAN).to_string()).unwrap(), Json::Null);
        assert_eq!(
            Json::parse(&Json::Num(f64::INFINITY).to_string()).unwrap(),
            Json::Null
        );
    }
}
