//! `cause` — the CAUSE coordinator CLI.
//!
//! Subcommands:
//!   repro <id>|all     regenerate a paper table/figure (see DESIGN.md index)
//!   run [key=value..]  run one system over a generated trace and report
//!   info               artifact + runtime information
//!
//! The argument parser is hand-rolled (no clap in the offline registry).

use std::process::ExitCode;

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::experiments::{self, Scale};

fn usage() -> &'static str {
    "cause — Constraint-aware Adaptive Exact Unlearning System at the network Edge

USAGE:
    cause repro <experiment>|all [--smoke]
    cause run [--system <name>] [--config <file>] [key=value ...]
    cause info

EXPERIMENTS (see DESIGN.md per-experiment index):
    fig2 table2 fig5 table3 fig10 fig11 fig12 fig13 fig14 fig15 fig16 fig17 fibor

SYSTEMS:
    cause cause-no-sc cause-u cause-c cause-rand sisa arcane omp-70 omp-95

CONFIG KEYS (key=value):
    seed users rounds epochs_per_round shards memory_gb unlearn_prob
    sc_gamma sc_p prune_keep batch_policy batch_window batch_slo model dataset
    store_mode memory_budget_bytes codec durability persist_dir compact_every
    fleet_workers obs obs_dir

BATCHING:
    batch_policy = fcfs | coalesce | deadline
    batch_slo    = latency SLO in service ticks for 'deadline' (0 ≡ fcfs,
                   'inf' ≡ coalesce-at-flush); per-request queueing-delay
                   receipts land in the metrics JSON (queue_delay_p50/p99,
                   slo_violations)

MEMORY:
    store_mode          = slots | bytes (slots = paper N_mem baseline;
                          bytes = admission/eviction in true encoded bytes)
    memory_budget_bytes = C_m in bytes; implies store_mode = bytes
    codec               = dense | sparse | delta (checkpoint payload codec,
                          tensor-carrying backends only)

DURABILITY (service-level; reboots must not void the deletion guarantee):
    durability    = off | log | log+spill
                    off       = in-memory only (byte-identical baseline)
                    log       = CRC-framed write-ahead event log; recovery
                                replays snapshot+tail to the exact pre-crash
                                accounting state (lineages, store, battery,
                                queue, carryover, metrics)
                    log+spill = log plus checkpoint payload spill — store
                                tensors recover bit-exactly
    persist_dir   = directory for MANIFEST.json / wal-*.log / snapshot-*.bin
    compact_every = events between automatic snapshot+truncate compactions
                    (0 = never; compaction bounds recovery time and log size)

FLEET (sharded service; `run` drives it when fleet_workers > 1):
    fleet_workers = N shard workers, each with its own engine, store,
                    battery, planner, and (with durability) WAL under
                    persist_dir/shard-<k>/. Users route to shards via the
                    UCDP map promoted to a routing layer: sticky (a user's
                    requests always reach the shard holding their data;
                    shard-controller shrinks only bump the routing epoch),
                    with battery admission decided centrally per priced
                    window. fleet_workers=1 replays the unsharded service
                    byte-identically (receipts, RSN, store stats, journal).

OBSERVABILITY:
    obs     = true | false   deterministic span tracing (plan→price→admit→
                             retrain→snapshot→seal→ship) + metrics registry
    obs_dir = directory for <prefix>_trace.json (Chrome trace format; load
              in chrome://tracing or Perfetto) and <prefix>_events.jsonl.
              Setting obs_dir implies obs=true. `cause run` exports the
              fleet trace when fleet_workers > 1; summarize a trace into a
              per-phase tick-budget table with the `obs` binary.
"
}

fn cmd_repro(args: &[String]) -> anyhow::Result<()> {
    let smoke = args.iter().any(|a| a == "--smoke");
    let scale = if smoke { Scale::Smoke } else { Scale::from_env() };
    let ids: Vec<&str> = match args.iter().find(|a| !a.starts_with("--")) {
        Some(id) if id == "all" => experiments::ALL.to_vec(),
        Some(id) => vec![id.as_str()],
        None => anyhow::bail!("repro needs an experiment id (or 'all')\n\n{}", usage()),
    };
    for id in ids {
        eprintln!("--- running {id} ({scale:?}) ---");
        let t0 = std::time::Instant::now();
        let tables = experiments::run(id, scale)?;
        experiments::report(id, &tables)?;
        eprintln!("--- {id} done in {:.1}s ---\n", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    let mut system = SystemVariant::Cause;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--system" => {
                let name = it.next().ok_or_else(|| anyhow::anyhow!("--system needs a name"))?;
                system = SystemVariant::by_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown system '{name}'"))?;
            }
            "--config" => {
                let path = it.next().ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
                cfg = ExperimentConfig::from_file(std::path::Path::new(path))?;
            }
            kv if kv.contains('=') => {
                let (k, v) = kv.split_once('=').unwrap();
                cfg.apply(k, v)?;
            }
            other => anyhow::bail!("unexpected argument '{other}'\n\n{}", usage()),
        }
    }
    cfg.validate()?;

    println!(
        "running {} | model={} dataset={} S={} T={} users={} C_m={:.1}GB rho_u={}",
        system.display(),
        cfg.model.name,
        cfg.dataset.name,
        cfg.shards,
        cfg.rounds,
        cfg.users,
        cfg.memory_bytes as f64 / (1u64 << 30) as f64,
        cfg.unlearn_prob
    );
    let pop = cause::experiments::common::population(&cfg);
    let trace = cause::experiments::common::trace(&cfg, &pop);
    let m = if cfg.fleet_workers > 1 {
        // Sharded service path: route each round's data and requests to
        // the shard workers, drain batched windows per round, flush at
        // the end of the trace.
        let mut fleet = system.build_fleet(&cfg)?;
        for t in 1..=cfg.rounds {
            fleet.ingest_round(&pop)?;
            for req in trace.at(t) {
                fleet.submit(req.clone());
            }
            fleet.drain_batched()?;
        }
        fleet.flush_batched()?;
        println!(
            "fleet: {} workers, routing epoch {}, shard seeds {:?}",
            fleet.workers(),
            fleet.epoch(),
            fleet
                .shard_seeds()
                .iter()
                .map(|s| format!("{s:#x}"))
                .collect::<Vec<_>>()
        );
        if cfg.obs {
            if let Some(dir) = cfg.obs_dir.as_deref() {
                let recs = fleet.trace_records()?;
                let (trace, events) = cause::obs::export::write_dir(
                    std::path::Path::new(dir),
                    "run",
                    &recs,
                )?;
                println!(
                    "trace: {} ({} spans)  events: {}",
                    trace.display(),
                    recs.len(),
                    events.display()
                );
            }
        }
        fleet.metrics()?
    } else {
        let mut engine = system.build_cost(&cfg)?;
        engine.run_trace(&pop, &trace)?;
        engine.metrics.clone()
    };
    println!("{}", m.to_json().to_pretty());
    println!(
        "total RSN {}  energy {:.0} J  requests {}  store: {} stored / {} replaced / {} rejected",
        m.total_rsn(),
        m.energy_joules,
        m.total_requests(),
        m.ckpts_stored,
        m.ckpts_replaced,
        m.ckpts_rejected
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = cause::experiments::common::artifacts_dir();
    println!("artifact dir: {}", dir.display());
    match cause::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {}", m.artifacts.len());
            for (name, a) in &m.artifacts {
                println!(
                    "  {:<32} file={} inputs={} outputs={} params={}",
                    name,
                    a.file.display(),
                    a.inputs.len(),
                    a.outputs.len(),
                    a.meta.get("param_count").map(|s| s.as_str()).unwrap_or("?")
                );
            }
        }
        Err(e) => println!("no manifest ({e}); run `make artifacts`"),
    }
    match cause::runtime::Runtime::new(&dir) {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(|s| s.as_str()) {
        Some("repro") => cmd_repro(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("info") => cmd_info(),
        Some("--help") | Some("-h") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
