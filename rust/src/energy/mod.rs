//! Energy model for the edge device.
//!
//! The paper's pilot study (Fig. 2b) shows energy consumption is *linear*
//! in the number of retrained samples for all four backbones — that is the
//! entire justification for using RSN as the speed metric. We exploit the
//! same linearity in reverse: measured RSN is translated to joules with a
//! per-model coefficient derived from the Jetson Orin Nano power envelope
//! and the per-epoch training times in Table 2.

use crate::config::ModelProfile;

/// Jetson Orin Nano sustained training power, watts (15 W mode).
pub const DEVICE_WATTS: f64 = 15.0;

/// Energy accounting for one model profile.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Joules to (re)train one sample for one epoch.
    pub joules_per_sample_epoch: f64,
    /// Joules per pruning pass (Table 2 "Prune" seconds × watts).
    pub joules_per_prune: f64,
}

impl EnergyModel {
    pub fn for_model(m: &ModelProfile) -> Self {
        let secs_per_sample_epoch = m.train_secs_per_epoch / m.corpus_samples;
        // Table-2 prune passes are ~0.4–5.3 s; scale with model size.
        let prune_secs = 0.03 * m.params_m;
        Self {
            joules_per_sample_epoch: DEVICE_WATTS * secs_per_sample_epoch,
            joules_per_prune: DEVICE_WATTS * prune_secs,
        }
    }

    /// Energy to retrain `samples` for `epochs` epochs.
    pub fn retrain_joules(&self, samples: u64, epochs: u32) -> f64 {
        self.joules_per_sample_epoch * samples as f64 * epochs as f64
    }

    /// Energy for `prunes` pruning passes.
    pub fn prune_joules(&self, prunes: u64) -> f64 {
        self.joules_per_prune * prunes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::{ALL_MODELS, MOBILENETV2, RESNET34};

    #[test]
    fn linear_in_samples_and_epochs() {
        let e = EnergyModel::for_model(&RESNET34);
        let a = e.retrain_joules(1000, 80);
        let b = e.retrain_joules(2000, 80);
        let c = e.retrain_joules(1000, 160);
        assert!((b - 2.0 * a).abs() < 1e-9);
        assert!((c - 2.0 * a).abs() < 1e-9);
    }

    #[test]
    fn bigger_models_cost_more_per_sample() {
        let big = EnergyModel::for_model(&RESNET34);
        let small = EnergyModel::for_model(&MOBILENETV2);
        assert!(big.joules_per_sample_epoch > small.joules_per_sample_epoch);
    }

    #[test]
    fn magnitudes_are_sane() {
        // ResNet-34 on Jetson: ~37 s/epoch over 50k samples at 15 W
        // → ~11 mJ per sample-epoch.
        for m in &ALL_MODELS {
            let e = EnergyModel::for_model(m);
            assert!(e.joules_per_sample_epoch > 1e-4 && e.joules_per_sample_epoch < 1.0,
                "{}: {}", m.name, e.joules_per_sample_epoch);
        }
    }
}
