//! The window lifecycle: FCFS and batched drains, merged-cost battery
//! admission, and window execution.
//!
//! The batched path is factored into three stages so a fleet front-end
//! can interpose between pricing and commitment:
//!
//! 1. [`UnlearningService::price_window`] — plan the window (merging any
//!    carried-over poison) and, when battery-gated, cost each lineage's
//!    resolved chain through the engine (one read-only resolver pass).
//!    Pricing is *destructive* (the planner's collect removes the
//!    window's samples from the lineages), so a priced window must be
//!    held and committed, never discarded.
//! 2. [`admission_decide`] — a pure function of the per-lineage costs
//!    and a battery view: grant the whole plan, grant an affordable
//!    lineage prefix, or starve. The standalone service and the fleet
//!    admission exchange both call exactly this function, which is what
//!    makes `fleet_workers = 1` replay the unsharded service
//!    byte-identically.
//! 3. [`UnlearningService::commit_window`] — draw the reservation,
//!    execute the granted share, park the deferred share as carryover,
//!    and account receipts/latency/energy.
//!
//! [`UnlearningService::execute_window`] composes the three stages for
//! the standalone service.

use anyhow::Result;

use crate::data::trace::UnlearnRequest;
use crate::metrics::LatencyReceipt;
use crate::persist::event::{Event, LatencyRecord, ServeRec, WindowRec};
use crate::sim::Battery;
use crate::unlearning::batch::BatchPlan;

use super::{batch_rec_of, carryover_rec_of, svc_rec_of, BatchReport, ReqMeta, ServiceReport, UnlearningService};

/// A planned-and-priced batch window, held between pricing and commit.
/// Its samples are already removed from the lineage bookkeeping (the
/// planner's collect is destructive), so the only valid next step is
/// [`UnlearningService::commit_window`] — dropping it would strand
/// poisoned versions.
pub(crate) struct PricedWindow {
    plan: BatchPlan,
    metas: Vec<ReqMeta>,
    drained: u64,
    /// Per-lineage retrain joules when battery-gated; `None` on mains or
    /// without a battery (admission is then unconditional).
    pub(crate) costs: Option<Vec<f64>>,
}

/// Battery admission verdict for one priced window.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Admission {
    /// Execute `take` lineages (`None` = the whole plan) reserving
    /// `reserve_j`; anything beyond the prefix is parked as carryover.
    Granted { take: Option<usize>, reserve_j: f64 },
    /// Not even the first lineage is affordable right now.
    Starved { probe_j: f64 },
}

/// Battery admission for a priced window: keep the affordable lineage
/// prefix of the costed plan. Splitting happens at lineage granularity —
/// requests are never dropped, their unfunded lineage work is deferred
/// instead. Pure in its inputs: the standalone service and the fleet's
/// global admission exchange share this exact decision procedure.
pub(crate) fn admission_decide(costs: Option<&[f64]>, battery: Option<&Battery>) -> Admission {
    let (Some(costs), Some(b)) = (costs, battery.filter(|b| !b.mains())) else {
        return Admission::Granted { take: None, reserve_j: 0.0 };
    };
    let mut reserve_j = 0.0;
    let mut take = 0usize;
    for &c in costs {
        if b.can_cover(reserve_j + c) {
            reserve_j += c;
            take += 1;
        } else {
            break;
        }
    }
    if take == costs.len() {
        Admission::Granted { take: None, reserve_j }
    } else if take == 0 {
        Admission::Starved { probe_j: costs.first().copied().unwrap_or(0.0) }
    } else {
        Admission::Granted { take: Some(take), reserve_j }
    }
}

impl UnlearningService {
    /// Conservative energy pre-estimate for the first `w` queued requests:
    /// replaying every requested sample (FCFS drains only; batched drains
    /// reserve the resolver's true merged cost instead).
    fn window_hint_joules(&self, w: usize) -> f64 {
        let rsn_hint: u64 = self.queue.iter().take(w).map(|r| r.total_samples()).sum();
        self.energy.retrain_joules(rsn_hint, self.engine.cfg.epochs_per_round)
    }

    /// Log at most one deferral receipt per episode (a stuck head polled
    /// by many drain calls previously produced one receipt per call,
    /// inflating deferral counts in the satellite scenario).
    fn log_deferral(&mut self, user: u32, round: u32, est_joules: f64) {
        if self.head_deferral_logged {
            return;
        }
        self.head_deferral_logged = true;
        self.log.push(ServiceReport {
            user,
            round,
            rsn: 0,
            lineages_retrained: 0,
            est_seconds: 0.0,
            est_joules,
            deferred: true,
        });
    }

    /// Serve queued requests strictly FCFS. With a battery, a request
    /// whose estimated energy exceeds the charge is deferred (stays at the
    /// queue head) until `harvest` restores enough charge.
    pub fn drain(&mut self) -> Result<usize> {
        self.check_journal()?;
        let root = crate::obs::begin_root(&mut self.tracer, "drain_fcfs", self.now_tick);
        // A plan carried over from a failed batched window must not be
        // stranded when the caller switches to FCFS drains: flush it
        // first (its samples are already removed from the lineages).
        let mut served = if self.carryover.is_some() {
            self.execute_window(Vec::new())?
        } else {
            0
        };
        while let Some(req) = self.queue.front().cloned() {
            // Conservative pre-estimate: replaying all requested samples.
            let est_j_hint = self.window_hint_joules(1);
            let starved = match &self.battery {
                Some(b) => !b.can_cover(est_j_hint),
                None => false,
            };
            if starved {
                // One brownout per starvation episode (a refused draw),
                // not one per drain() poll of the same stuck head.
                if !self.head_deferral_logged {
                    if let Some(b) = &mut self.battery {
                        let _ = b.draw(est_j_hint);
                    }
                    self.log_deferral(req.user.0, req.round, est_j_hint);
                    self.emit(|svc| {
                        Event::Serve(Box::new(ServeRec {
                            popped: false,
                            store_ops: svc.engine.take_tape(),
                            battery: svc.battery_post(),
                            metrics: svc.metrics_post(),
                            latency: None,
                            report: svc_rec_of(svc.log.last().expect("deferral logged")),
                            head_deferral_logged: true,
                            policy_state: svc.engine.store().policy_state(),
                        }))
                    });
                }
                break; // FCFS: don't skip ahead of the deferred head.
            }
            if let Some(b) = &mut self.battery {
                let drawn = b.draw(est_j_hint);
                debug_assert!(drawn, "covered by the can_cover probe above");
            }
            let serve = crate::obs::begin(&mut self.tracer, "serve", self.now_tick);
            let outcome = match self.engine.process_request(&req) {
                Ok(o) => o,
                Err(e) => {
                    // Partial trainer failure: the tape cannot frame this
                    // as one clean transition — drop it and poison the
                    // journal (live state has diverged from the log;
                    // recovery replays to the last committed event).
                    let _ = self.engine.take_tape();
                    self.poison_journal(&format!("engine error mid-serve: {e:#}"));
                    // Ending the root pops the open serve span with it.
                    crate::obs::end(&mut self.tracer, root, self.now_tick, served as u64);
                    return Err(e);
                }
            };
            crate::obs::end(&mut self.tracer, serve, self.now_tick, outcome.rsn);
            let est_seconds = self
                .engine
                .cfg
                .model
                .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
            let est_joules = self
                .energy
                .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
            if let Some(b) = &mut self.battery {
                b.settle(est_joules, est_j_hint);
            }
            let queued_ticks = self.now_tick.saturating_sub(req.arrival_tick);
            let slo = self.planner.policy.slo();
            let slo_met = slo.map_or(true, |s| queued_ticks <= s);
            // Built here (not read back from the receipt vec) because the
            // vec is capped: the receipt may fold into the histogram only.
            let latency_rec = LatencyRecord {
                user: req.user.0,
                round: req.round,
                queued_ticks,
                slo_met,
            };
            self.engine.metrics.record_latency(LatencyReceipt {
                user: req.user.0,
                round: req.round,
                queued_ticks,
                slo_met,
            });
            self.log.push(ServiceReport {
                user: req.user.0,
                round: req.round,
                rsn: outcome.rsn,
                lineages_retrained: outcome.lineages_retrained,
                est_seconds,
                est_joules,
                deferred: false,
            });
            self.queue.pop_front();
            self.head_deferral_logged = false;
            self.emit(|svc| {
                Event::Serve(Box::new(ServeRec {
                    popped: true,
                    store_ops: svc.engine.take_tape(),
                    battery: svc.battery_post(),
                    metrics: svc.metrics_post(),
                    latency: Some(latency_rec),
                    report: svc_rec_of(svc.log.last().expect("report just pushed")),
                    head_deferral_logged: false,
                    policy_state: svc.engine.store().policy_state(),
                }))
            });
            served += 1;
        }
        // End of the drain = end of the commit scope: seal the
        // group-commit window and ship the sealed frames.
        self.journal_seal();
        crate::obs::end(&mut self.tracer, root, self.now_tick, served as u64);
        Ok(served)
    }

    /// Serve queued requests in coalesced windows per the configured
    /// [`BatchPlanner`](crate::unlearning::BatchPlanner): each window's
    /// poison sets are merged so a lineage touched by R requests replays
    /// once instead of R times. Under a deadline policy, windows close
    /// exactly when the oldest queued request's SLO leaves no more slack.
    /// Returns the number of requests served. With a battery, admission
    /// reserves the true merged plan cost and splits the plan at lineage
    /// granularity when only a prefix is affordable (one deferral receipt
    /// per starvation episode).
    pub fn drain_batched(&mut self) -> Result<usize> {
        self.drain_windows(false)
    }

    /// Serve everything queued regardless of deadline slack (end of run /
    /// device shutdown): the whole queue coalesces into one window, which
    /// is where `Deadline { slo_ticks: u64::MAX }` meets `Coalesce`.
    pub fn flush_batched(&mut self) -> Result<usize> {
        self.drain_windows(true)
    }

    fn drain_windows(&mut self, flush: bool) -> Result<usize> {
        self.check_journal()?;
        let root = crate::obs::begin_root(
            &mut self.tracer,
            if flush { "drain_flush" } else { "drain" },
            self.now_tick,
        );
        let mut served = 0;
        loop {
            let w = self.next_window(flush);
            if w == 0 {
                // Flush a carried-over plan even when no window opens —
                // its samples are already removed, so its poison must
                // still be replayed (and its requests counted).
                if self.has_carryover() {
                    served += self.execute_window(Vec::new())?;
                }
                break;
            }
            let window = self.take_window(w);
            let n = self.execute_window(window)?;
            served += n;
            if n == 0 && self.has_carryover() {
                // Battery-starved: the window's plan is parked; draining
                // further windows would only park more unfunded work.
                break;
            }
        }
        self.journal_seal();
        crate::obs::end(&mut self.tracer, root, self.now_tick, served as u64);
        Ok(served)
    }

    /// The window the planner would close right now: the whole queue when
    /// flushing, else the policy's choice given queue depth and the
    /// oldest request's age. 0 means "hold".
    pub(crate) fn next_window(&self, flush: bool) -> usize {
        if flush {
            self.queue.len()
        } else {
            let oldest_age = self
                .queue
                .front()
                .map(|r| self.now_tick.saturating_sub(r.arrival_tick));
            self.planner.window_size_at(self.queue.len(), oldest_age)
        }
    }

    /// Pop the next `w` queued requests in FCFS order.
    pub(crate) fn take_window(&mut self, w: usize) -> Vec<UnlearnRequest> {
        self.queue.drain(..w).collect()
    }

    /// Whether a carried-over plan is parked awaiting a future window.
    pub(crate) fn has_carryover(&self) -> bool {
        self.carryover.is_some()
    }

    /// Stage 1: plan the window (merging any carried-over poison) and
    /// price it per lineage when battery-gated. Destructive — see the
    /// type docs on [`PricedWindow`].
    pub(crate) fn price_window(&mut self, window: Vec<UnlearnRequest>) -> PricedWindow {
        let span = crate::obs::begin(&mut self.tracer, "price", self.now_tick);
        let drained = window.len() as u64;
        let mut metas: Vec<ReqMeta> = Vec::with_capacity(window.len());
        if let Some((_, prev_metas)) = &self.carryover {
            // Carried-over requests arrived first; receipts keep order.
            metas.extend(prev_metas.iter().copied());
        }
        metas.extend(window.iter().map(|r| ReqMeta {
            user: r.user.0,
            round: r.round,
            arrival_tick: r.arrival_tick,
        }));
        let mut plan = self.planner.plan(&mut self.engine, &window);
        if let Some((prev_plan, _)) = self.carryover.take() {
            plan.merge(prev_plan);
        }
        let costs = match self.battery.as_ref().filter(|b| !b.mains()) {
            None => None,
            Some(_) => {
                let epochs = self.engine.cfg.epochs_per_round;
                Some(
                    self.engine
                        .plan_lineage_rsn(&plan)
                        .into_iter()
                        .map(|rsn| self.energy.retrain_joules(rsn, epochs))
                        .collect(),
                )
            }
        };
        crate::obs::end(&mut self.tracer, span, self.now_tick, drained);
        PricedWindow { plan, metas, drained, costs }
    }

    /// Stage 3: commit a priced window under an admission verdict.
    /// Unaffordable lineages — or the whole plan, on an engine error —
    /// are stashed for a later window with the energy reservation
    /// released; the requests are NOT re-queued, since re-collecting them
    /// would remove additional, never-requested samples. Returns the
    /// number of requests served.
    pub(crate) fn commit_window(&mut self, pw: PricedWindow, admission: Admission) -> Result<usize> {
        let commit = crate::obs::begin(&mut self.tracer, "commit", self.now_tick);
        let PricedWindow { mut plan, metas, drained, costs: _ } = pw;
        let (reserve_j, defer) = match admission {
            Admission::Granted { take, reserve_j } => {
                let defer = match take {
                    None => None,
                    Some(t) => {
                        let t = t.min(plan.lineages.len());
                        (t < plan.lineages.len()).then(|| BatchPlan {
                            lineages: plan.lineages.split_off(t),
                            requests: 0,
                        })
                    }
                };
                (reserve_j, defer)
            }
            Admission::Starved { probe_j } => {
                let fresh_episode = !self.head_deferral_logged;
                if fresh_episode {
                    self.head_deferral_logged = true;
                    // Record the episode's brownout (the refused draw).
                    if let Some(b) = &mut self.battery {
                        let _ = b.draw(probe_j);
                    }
                    self.batch_log.push(BatchReport {
                        requests: 0,
                        rsn: 0,
                        lineages_retrained: 0,
                        retrains_coalesced: 0,
                        oldest_queued_ticks: 0,
                        est_seconds: 0.0,
                        est_joules: probe_j,
                        deferred: true,
                    });
                }
                self.carryover = Some((plan, metas));
                self.emit(|svc| {
                    Event::Window(Box::new(WindowRec {
                        drained,
                        store_ops: svc.engine.take_tape(),
                        battery: svc.battery_post(),
                        metrics: svc.metrics_post(),
                        latency: vec![],
                        report: if fresh_episode {
                            Some(batch_rec_of(svc.batch_log.last().expect("just pushed")))
                        } else {
                            None
                        },
                        carryover: carryover_rec_of(&svc.carryover),
                        head_deferral_logged: svc.head_deferral_logged,
                        policy_state: svc.engine.store().policy_state(),
                    }))
                });
                crate::obs::end(&mut self.tracer, commit, self.now_tick, 0);
                return Ok(0);
            }
        };

        if let Some(b) = &mut self.battery {
            let drawn = b.draw(reserve_j);
            debug_assert!(drawn, "admission sized the reservation to the charge");
        }

        let coalesced = plan.coalesced_retrains();
        let window_requests = plan.requests;
        debug_assert_eq!(window_requests, metas.len(), "one meta per merged request");
        let retrain = crate::obs::begin(&mut self.tracer, "retrain", self.now_tick);
        let outcome = match self.engine.execute_plan(&plan) {
            Ok(outcome) => {
                crate::obs::end(&mut self.tracer, retrain, self.now_tick, outcome.rsn);
                outcome
            }
            Err(e) => {
                crate::obs::end(&mut self.tracer, retrain, self.now_tick, 0);
                if let Some(b) = &mut self.battery {
                    b.refund(reserve_j);
                }
                // Re-join the deferred share so nothing is stranded.
                if let Some(d) = defer {
                    plan.merge(d);
                }
                self.carryover = Some((plan, metas));
                // The partially executed plan's store mutations are real:
                // frame them so recovery lands on this exact state.
                self.emit(|svc| {
                    Event::Window(Box::new(WindowRec {
                        drained,
                        store_ops: svc.engine.take_tape(),
                        battery: svc.battery_post(),
                        metrics: svc.metrics_post(),
                        latency: vec![],
                        report: None,
                        carryover: carryover_rec_of(&svc.carryover),
                        head_deferral_logged: svc.head_deferral_logged,
                        policy_state: svc.engine.store().policy_state(),
                    }))
                });
                crate::obs::end(&mut self.tracer, commit, self.now_tick, 0);
                return Err(e);
            }
        };
        // The executed share serves (and accounts) the window's requests;
        // any battery-deferred lineage share replays later via carryover.
        if let Some(d) = defer {
            self.carryover = Some((d, Vec::new()));
        }
        self.engine.metrics.record_requests(window_requests as u64, outcome.rsn);
        self.engine.metrics.batches += 1;
        self.engine.metrics.batched_requests += window_requests as u64;
        self.engine.metrics.retrains_coalesced += coalesced;

        let slo = self.planner.policy.slo();
        let mut oldest_queued = 0u64;
        // Built alongside the receipts (not sliced back out of the receipt
        // vec) because the vec is capped: late receipts fold into the
        // histogram only.
        let mut latency_records = Vec::with_capacity(metas.len());
        for m in &metas {
            let queued_ticks = self.now_tick.saturating_sub(m.arrival_tick);
            oldest_queued = oldest_queued.max(queued_ticks);
            let slo_met = slo.map_or(true, |s| queued_ticks <= s);
            latency_records.push(LatencyRecord {
                user: m.user,
                round: m.round,
                queued_ticks,
                slo_met,
            });
            self.engine.metrics.record_latency(LatencyReceipt {
                user: m.user,
                round: m.round,
                queued_ticks,
                slo_met,
            });
        }

        let est_seconds = self
            .engine
            .cfg
            .model
            .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
        let est_joules = self
            .energy
            .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
        if let Some(b) = &mut self.battery {
            b.settle(est_joules, reserve_j);
        }
        self.batch_log.push(BatchReport {
            requests: window_requests,
            rsn: outcome.rsn,
            lineages_retrained: outcome.lineages_retrained,
            retrains_coalesced: coalesced,
            oldest_queued_ticks: oldest_queued,
            est_seconds,
            est_joules,
            deferred: false,
        });
        self.head_deferral_logged = false;
        self.emit(|svc| {
            Event::Window(Box::new(WindowRec {
                drained,
                store_ops: svc.engine.take_tape(),
                battery: svc.battery_post(),
                metrics: svc.metrics_post(),
                latency: latency_records,
                report: Some(batch_rec_of(svc.batch_log.last().expect("just pushed"))),
                carryover: carryover_rec_of(&svc.carryover),
                head_deferral_logged: false,
                policy_state: svc.engine.store().policy_state(),
            }))
        });
        crate::obs::end(&mut self.tracer, commit, self.now_tick, window_requests as u64);
        Ok(window_requests)
    }

    /// Plan, admit against the battery, execute, and account one batch
    /// window (stages 1–3 composed for the standalone service).
    pub(crate) fn execute_window(&mut self, window: Vec<UnlearnRequest>) -> Result<usize> {
        let pw = self.price_window(window);
        let span = crate::obs::begin(&mut self.tracer, "admit", self.now_tick);
        let admission = admission_decide(pw.costs.as_deref(), self.battery.as_ref());
        let granted = matches!(admission, Admission::Granted { .. });
        crate::obs::end(&mut self.tracer, span, self.now_tick, u64::from(granted));
        self.commit_window(pw, admission)
    }
}
