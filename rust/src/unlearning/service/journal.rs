//! Durability glue: journal attachment/recovery, log-before-ack event
//! emission, replay of journaled transitions, and snapshot
//! capture/restore (the compactor's image).

use anyhow::Result;

use crate::data::trace::UnlearnRequest;
use crate::load::LatencyHistogram;
use crate::metrics::{LatencyReceipt, RunMetrics};
use crate::persist::event::{BatteryPost, Event, LatencyRecord, MetricsPost};
use crate::persist::recovery::{self, RecoveryReport};
use crate::persist::snapshot::{BatteryImage, MetricsImage, StateImage};
use crate::persist::{
    Durability, DurabilityMode, Replica, ShipReceipt, ShipTransport, Shipper,
};
use crate::sim::Battery;

use super::{
    batch_from_rec, batch_rec_of, carryover_from_rec, carryover_rec_of, req_from_rec,
    req_rec_of, svc_from_rec, svc_rec_of, Journal, UnlearningService,
};

/// Aggregate journal counters, surfaced per-shard through the fleet
/// front-end's merged receipts and consumed by the chaos soak's
/// replica-boundedness invariant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Events appended over the journal's lifetime.
    pub appended: u64,
    /// Fsync barriers issued (appended / fsyncs = group-commit
    /// amortization).
    pub fsyncs: u64,
    /// Next event sequence number.
    pub next_seq: u64,
    /// Events in the live log tail (since the last compaction).
    pub events_in_log: u64,
    /// Payload bytes in the live log tail.
    pub log_bytes: u64,
    /// Bytes of the current generation's snapshot (0 if none).
    pub snapshot_bytes: u64,
}

impl JournalStats {
    /// Bytes of the source's live durable state (snapshot + log tail) —
    /// the bound a compacting peer replica must stay within.
    pub fn live_bytes(&self) -> u64 {
        self.log_bytes + self.snapshot_bytes
    }
}

impl UnlearningService {
    /// Attach a durability journal, first recovering whatever state the
    /// backing filesystem holds (snapshot + write-ahead log tail, torn
    /// writes repaired). Call this on a **freshly built** service — same
    /// system variant, batch planner, and battery profile as the crashed
    /// instance — before driving it; recovery then reconstructs the
    /// pre-crash state receipt-identically and arms log-before-ack
    /// journaling for everything that follows.
    pub fn attach_durability(&mut self, d: Durability) -> Result<RecoveryReport> {
        if d.mode == DurabilityMode::Off {
            return Ok(RecoveryReport::default());
        }
        let (mut log, report) = recovery::recover(self, d.fs)
            .map_err(|e| anyhow::anyhow!("durability recovery: {e}"))?;
        log.set_fsync(d.fsync);
        self.engine.set_taping(true);
        self.journal = Some(Journal {
            log,
            mode: d.mode,
            compact_every: d.compact_every,
            shipper: None,
            err: None,
        });
        Ok(report)
    }

    /// The attached durability mode ([`DurabilityMode::Off`] when none).
    pub fn durability_mode(&self) -> DurabilityMode {
        self.journal.as_ref().map_or(DurabilityMode::Off, |j| j.mode)
    }

    /// First journal append/compaction failure, if any (surfaced as an
    /// error by the next fallible entry point).
    pub fn durability_error(&self) -> Option<&str> {
        self.journal.as_ref().and_then(|j| j.err.as_deref())
    }

    /// Events currently in the log tail (0 without a journal).
    pub fn journal_events(&self) -> u64 {
        self.journal.as_ref().map_or(0, |j| j.log.events_in_log())
    }

    /// Write a snapshot of the full service state and truncate the log
    /// prefix it materializes (the compactor; also triggered automatically
    /// every `compact_every` events). A failed compaction poisons the
    /// journal: the in-memory log position can no longer be trusted to
    /// match the committed manifest, so further acks would lie.
    pub fn compact_now(&mut self) -> Result<()> {
        let Some(mut j) = self.journal.take() else {
            return Ok(());
        };
        if let Some(e) = &j.err {
            let msg = e.clone();
            self.journal = Some(j);
            return Err(anyhow::anyhow!("durability journal failed earlier: {msg}"));
        }
        let snap = crate::obs::begin(&mut self.tracer, "snapshot", self.now_tick);
        let image = self.capture_image();
        let bytes = image.encode(j.mode.spills());
        let snapshot_bytes = bytes.len() as u64;
        let res = j.log.compact(&bytes);
        match &res {
            Err(e) => j.err = Some(format!("compaction: {e}")),
            Ok(()) => {
                // Re-base the peer replica at the new generation: the
                // snapshot materializes everything below next_seq.
                let base = j.log.manifest().next_seq;
                if let Some(sh) = j.shipper.as_mut() {
                    sh.on_compact(base, bytes);
                }
            }
        }
        self.journal = Some(j);
        crate::obs::end(&mut self.tracer, snap, self.now_tick, snapshot_bytes);
        if res.is_ok() {
            self.journal_seal();
        }
        res.map_err(|e| anyhow::anyhow!("compaction: {e}"))
    }

    /// Seal the current group-commit window: one fsync barrier covers
    /// every event appended since the last seal, then the sealed frames
    /// ship to the peer (one flush opportunity — the shipper's backoff
    /// may skip it). Every commit scope (drain, batched window, round
    /// ingest, compaction) ends here; a failed barrier poisons the
    /// journal exactly like a failed append.
    pub(crate) fn journal_seal(&mut self) {
        let tick = self.now_tick;
        let Some(j) = self.journal.as_mut() else { return };
        if j.err.is_some() {
            return;
        }
        let seal = crate::obs::begin(&mut self.tracer, "seal", tick);
        if let Err(e) = j.log.sync_now() {
            j.err = Some(format!("fsync: {e}"));
            crate::obs::end(&mut self.tracer, seal, tick, 0);
            return;
        }
        if let Some(sh) = j.shipper.as_mut() {
            let ship = crate::obs::begin(&mut self.tracer, "ship", tick);
            sh.flush();
            let pending = sh.receipt().pending;
            crate::obs::end(&mut self.tracer, ship, tick, pending);
        }
        crate::obs::end(&mut self.tracer, seal, tick, 0);
    }

    /// Force the group-commit window closed from outside (device
    /// shutdown, fleet checkpoint): fsync barrier + ship. Errors if the
    /// journal is (or becomes) poisoned.
    pub fn sync_journal(&mut self) -> Result<()> {
        self.check_journal()?;
        self.journal_seal();
        self.check_journal()
    }

    /// Lifetime (events appended, fsync barriers issued) — the group
    /// commit amortization ratio. `None` without a journal.
    pub fn journal_fsync_stats(&self) -> Option<(u64, u64)> {
        self.journal.as_ref().map(|j| j.log.fsync_stats())
    }

    /// Aggregate journal counters (see [`JournalStats`]). `None` without
    /// a journal.
    pub fn journal_stats(&self) -> Option<JournalStats> {
        self.journal.as_ref().map(|j| {
            let (appended, fsyncs) = j.log.fsync_stats();
            JournalStats {
                appended,
                fsyncs,
                next_seq: j.log.next_seq(),
                events_in_log: j.log.events_in_log(),
                log_bytes: j.log.log_bytes(),
                snapshot_bytes: j.log.snapshot_bytes().map_or(0, |s| s.len() as u64),
            }
        })
    }

    /// The journal's durable state as a [`Replica`]-shaped value — the
    /// current generation's snapshot plus the complete log-tail frames.
    /// Equality with the peer's shipped [`Replica`] is the chaos soak's
    /// byte-convergence check. `None` without a journal.
    pub fn journal_image(&self) -> Option<Replica> {
        self.journal.as_ref().map(|j| Replica {
            base_seq: j.log.manifest().next_seq,
            snapshot: j.log.snapshot_bytes(),
            frames: j.log.tail_frames(),
        })
    }

    /// The journal's next event sequence number (0 without a journal) —
    /// the high edge the shipping watermark chases.
    pub fn journal_seq(&self) -> u64 {
        self.journal.as_ref().map_or(0, |j| j.log.next_seq())
    }

    /// Start shipping this journal's log to a peer over `transport`,
    /// identifying as shard `source`. The current generation (snapshot +
    /// log tail) is staged immediately and delivered at the first seal,
    /// so the peer converges to a full copy, not just the future suffix.
    /// `retry_limit` bounds consecutive delivery faults before shipping
    /// fails terminally (the local journal is unaffected).
    pub fn enable_shipping(
        &mut self,
        source: usize,
        transport: Box<dyn ShipTransport>,
        retry_limit: u32,
    ) -> Result<()> {
        self.check_journal()?;
        let Some(j) = self.journal.as_mut() else {
            return Err(anyhow::anyhow!("log shipping requires an attached durability journal"));
        };
        let mut sh = Shipper::new(source, transport, retry_limit);
        sh.prime(j.log.manifest().next_seq, j.log.snapshot_bytes(), j.log.tail_frames());
        j.shipper = Some(sh);
        self.journal_seal();
        Ok(())
    }

    /// Shipping state for receipts (`None` when shipping is not enabled).
    pub fn shipping_state(&self) -> Option<ShipReceipt> {
        self.journal.as_ref().and_then(|j| j.shipper.as_ref()).map(Shipper::receipt)
    }

    /// Record the first durability failure; everything after it is
    /// refused (appends stop, fallible entry points error) — nothing is
    /// silently un-durable.
    pub(super) fn poison_journal(&mut self, msg: &str) {
        if let Some(j) = self.journal.as_mut() {
            if j.err.is_none() {
                j.err = Some(msg.to_string());
            }
        }
    }

    pub(crate) fn check_journal(&self) -> Result<()> {
        match self.durability_error() {
            Some(e) => Err(anyhow::anyhow!("durability journal failed earlier: {e}")),
            None => Ok(()),
        }
    }

    /// Build-and-append an event; the builder only runs when a journal is
    /// attached, so `durability = off` pays nothing.
    pub(super) fn emit(&mut self, build: impl FnOnce(&mut Self) -> Event) {
        match &self.journal {
            // A poisoned journal must not keep appending: a failed append
            // can leave a torn frame mid-file, and frames written after it
            // would be invisible to recovery (scan stops at the tear) —
            // acked-but-unrecoverable, the one thing the log must never do.
            None => return,
            Some(j) if j.err.is_some() => return,
            Some(_) => {}
        }
        let ev = build(self);
        self.append_event(ev);
    }

    fn append_event(&mut self, ev: Event) {
        let due = {
            let Some(j) = self.journal.as_mut() else { return };
            let seq = j.log.next_seq();
            let payload = ev.encode(seq, j.mode.spills());
            if let Err(e) = j.log.append_payload(&payload) {
                if j.err.is_none() {
                    j.err = Some(e.to_string());
                }
                return;
            }
            // Stage for the peer; frames ship at the next seal, after the
            // fsync barrier covers them.
            if let Some(sh) = j.shipper.as_mut() {
                sh.stage(seq, payload);
            }
            j.compact_every > 0 && j.log.events_in_log() >= j.compact_every
        };
        if due {
            // compact_now stashes its own error into the journal.
            let _ = self.compact_now();
        }
    }

    /// Absolute post-transition metric record.
    pub(super) fn metrics_post(&self) -> MetricsPost {
        let m = &self.engine.metrics;
        MetricsPost {
            warm_retrains: m.warm_retrains,
            scratch_retrains: m.scratch_retrains,
            lineages_retrained: m.lineages_retrained,
            prunes: m.prunes,
            energy_joules: m.energy_joules,
            ckpts_stored: m.ckpts_stored,
            ckpts_replaced: m.ckpts_replaced,
            ckpts_rejected: m.ckpts_rejected,
            ckpts_invalidated: m.ckpts_invalidated,
            batches: m.batches,
            batched_requests: m.batched_requests,
            retrains_coalesced: m.retrains_coalesced,
            round_slots: m.rsn_by_round.len() as u64,
            rsn_last: m.rsn_by_round.last().copied().unwrap_or(0),
            requests_last: m.requests_by_round.last().copied().unwrap_or(0),
        }
    }

    pub(super) fn battery_post(&self) -> Option<BatteryPost> {
        self.battery
            .as_ref()
            .map(|b| BatteryPost { charge_j: b.charge_j, brownouts: b.brownouts })
    }

    fn apply_metrics_post(&mut self, p: &MetricsPost) {
        let m = &mut self.engine.metrics;
        m.warm_retrains = p.warm_retrains;
        m.scratch_retrains = p.scratch_retrains;
        m.lineages_retrained = p.lineages_retrained;
        m.prunes = p.prunes;
        m.energy_joules = p.energy_joules;
        m.ckpts_stored = p.ckpts_stored;
        m.ckpts_replaced = p.ckpts_replaced;
        m.ckpts_rejected = p.ckpts_rejected;
        m.ckpts_invalidated = p.ckpts_invalidated;
        m.batches = p.batches;
        m.batched_requests = p.batched_requests;
        m.retrains_coalesced = p.retrains_coalesced;
        while (m.rsn_by_round.len() as u64) < p.round_slots {
            m.rsn_by_round.push(0);
        }
        while (m.requests_by_round.len() as u64) < p.round_slots {
            m.requests_by_round.push(0);
        }
        if p.round_slots > 0 {
            if let Some(last) = m.rsn_by_round.last_mut() {
                *last = p.rsn_last;
            }
            if let Some(last) = m.requests_by_round.last_mut() {
                *last = p.requests_last;
            }
        }
    }

    fn apply_battery_post(&mut self, post: &Option<BatteryPost>) {
        if let (Some(b), Some(p)) = (self.battery.as_mut(), post) {
            b.charge_j = p.charge_j;
            b.brownouts = p.brownouts;
        }
    }

    /// Replay one journaled transition (crash recovery). Mirrors exactly
    /// what the live transition mutated: queue pops re-remove their own
    /// samples through the real proportional-split code, store admissions
    /// re-apply their recorded victim sets, scalars restore from absolute
    /// post-values.
    pub(crate) fn replay_event(&mut self, ev: &Event) {
        match ev {
            Event::Advance { ticks } => {
                self.now_tick = self.now_tick.saturating_add(*ticks);
            }
            Event::Harvest { battery } => self.apply_battery_post(battery),
            Event::Submit(rec) => self.queue.push_back(req_from_rec(rec)),
            Event::Round(rec) => {
                self.now_tick = self.now_tick.saturating_add(1);
                self.engine.replay_round(rec);
                self.apply_metrics_post(&rec.metrics);
            }
            Event::Serve(rec) => {
                if rec.popped {
                    if let Some(req) = self.queue.pop_front() {
                        for (b, n) in &req.parts {
                            self.engine.replay_remove(b.0, *n);
                        }
                    }
                }
                self.engine.replay_store_ops(&rec.store_ops);
                self.apply_metrics_post(&rec.metrics);
                if let Some(l) = &rec.latency {
                    self.engine.metrics.record_latency(LatencyReceipt {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    });
                }
                self.log.push(svc_from_rec(&rec.report));
                self.apply_battery_post(&rec.battery);
                self.head_deferral_logged = rec.head_deferral_logged;
                self.engine.store_mut().restore_policy_state(&rec.policy_state);
            }
            Event::Window(rec) => {
                let n = (rec.drained as usize).min(self.queue.len());
                let reqs: Vec<UnlearnRequest> = self.queue.drain(..n).collect();
                for req in &reqs {
                    for (b, cnt) in &req.parts {
                        self.engine.replay_remove(b.0, *cnt);
                    }
                }
                self.engine.replay_store_ops(&rec.store_ops);
                self.apply_metrics_post(&rec.metrics);
                for l in &rec.latency {
                    self.engine.metrics.record_latency(LatencyReceipt {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    });
                }
                if let Some(b) = &rec.report {
                    self.batch_log.push(batch_from_rec(b));
                }
                self.carryover = carryover_from_rec(&rec.carryover);
                self.apply_battery_post(&rec.battery);
                self.head_deferral_logged = rec.head_deferral_logged;
                self.engine.store_mut().restore_policy_state(&rec.policy_state);
            }
        }
    }

    /// Materialize the full service state (the compactor's snapshot).
    pub(crate) fn capture_image(&self) -> StateImage {
        let m = &self.engine.metrics;
        let (hist_counts, hist_count, hist_sum, hist_max) = m.latency_hist.to_parts();
        StateImage {
            now_tick: self.now_tick,
            head_deferral_logged: self.head_deferral_logged,
            queue: self.queue.iter().map(req_rec_of).collect(),
            carryover: carryover_rec_of(&self.carryover),
            battery: self.battery.as_ref().map(|b| BatteryImage {
                capacity_j: b.capacity_j,
                charge_j: b.charge_j,
                harvest_watts: b.harvest_watts,
                brownouts: b.brownouts,
            }),
            svc_log: self.log.iter().map(svc_rec_of).collect(),
            batch_log: self.batch_log.iter().map(batch_rec_of).collect(),
            round: self.engine.round(),
            rounds: self.engine.capture_rounds(),
            partitioner_state: self.engine.partitioner_state(),
            store: self.engine.capture_store_image(),
            metrics: MetricsImage {
                rsn_by_round: m.rsn_by_round.clone(),
                requests_by_round: m.requests_by_round.clone(),
                warm_retrains: m.warm_retrains,
                scratch_retrains: m.scratch_retrains,
                lineages_retrained: m.lineages_retrained,
                energy_joules: m.energy_joules,
                prunes: m.prunes,
                ckpts_stored: m.ckpts_stored,
                ckpts_replaced: m.ckpts_replaced,
                ckpts_rejected: m.ckpts_rejected,
                ckpts_invalidated: m.ckpts_invalidated,
                batches: m.batches,
                batched_requests: m.batched_requests,
                retrains_coalesced: m.retrains_coalesced,
                latency: m
                    .latency
                    .iter()
                    .map(|l| LatencyRecord {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    })
                    .collect(),
                accuracy_by_round: m.accuracy_by_round.clone(),
                latency_dropped: m.latency_dropped,
                latency_slo_miss: m.latency_slo_miss,
                hist_counts,
                hist_count,
                hist_sum_hi: (hist_sum >> 64) as u64,
                hist_sum_lo: hist_sum as u64,
                hist_max,
            },
        }
    }

    /// Restore from a compaction snapshot (recovery, before log replay).
    pub(crate) fn restore_image(&mut self, img: &StateImage) {
        self.now_tick = img.now_tick;
        self.head_deferral_logged = img.head_deferral_logged;
        self.queue = img.queue.iter().map(req_from_rec).collect();
        self.carryover = carryover_from_rec(&img.carryover);
        if let Some(bi) = &img.battery {
            self.battery = Some(Battery {
                capacity_j: bi.capacity_j,
                charge_j: bi.charge_j,
                harvest_watts: bi.harvest_watts,
                brownouts: bi.brownouts,
            });
        }
        self.log = img.svc_log.iter().map(svc_from_rec).collect();
        self.batch_log = img.batch_log.iter().map(batch_from_rec).collect();
        self.engine.restore_rounds(&img.rounds);
        self.engine.set_round(img.round);
        self.engine.restore_partitioner_state(&img.partitioner_state);
        self.engine.restore_store_image(&img.store);
        self.engine.metrics = RunMetrics {
            rsn_by_round: img.metrics.rsn_by_round.clone(),
            requests_by_round: img.metrics.requests_by_round.clone(),
            warm_retrains: img.metrics.warm_retrains,
            scratch_retrains: img.metrics.scratch_retrains,
            lineages_retrained: img.metrics.lineages_retrained,
            energy_joules: img.metrics.energy_joules,
            prunes: img.metrics.prunes,
            ckpts_stored: img.metrics.ckpts_stored,
            ckpts_replaced: img.metrics.ckpts_replaced,
            ckpts_rejected: img.metrics.ckpts_rejected,
            ckpts_invalidated: img.metrics.ckpts_invalidated,
            batches: img.metrics.batches,
            batched_requests: img.metrics.batched_requests,
            retrains_coalesced: img.metrics.retrains_coalesced,
            latency: img
                .metrics
                .latency
                .iter()
                .map(|l| LatencyReceipt {
                    user: l.user,
                    round: l.round,
                    queued_ticks: l.queued_ticks,
                    slo_met: l.slo_met,
                })
                .collect(),
            accuracy_by_round: img.metrics.accuracy_by_round.clone(),
            latency_dropped: img.metrics.latency_dropped,
            latency_slo_miss: img.metrics.latency_slo_miss,
            latency_hist: LatencyHistogram::from_parts(
                img.metrics.hist_counts.clone(),
                img.metrics.hist_count,
                (u128::from(img.metrics.hist_sum_hi) << 64)
                    | u128::from(img.metrics.hist_sum_lo),
                img.metrics.hist_max,
            ),
        };
    }
}
