//! Queue-fronted unlearning service.
//!
//! Wraps an [`Engine`] with the request lifecycle a real edge deployment
//! needs: a service clock (ticks), queueing, per-request and per-batch
//! receipts (RSN, latency estimate, energy, queueing delay), optional
//! battery gating (satellite mode: defer retraining when the state of
//! charge cannot cover it), and a service log.
//!
//! Two drain modes:
//! * [`UnlearningService::drain`] — strictly FCFS, one retrain pass per
//!   request (the paper's service model).
//! * [`UnlearningService::drain_batched`] — windows of queued requests are
//!   merged by the configured [`BatchPlanner`]. Under
//!   [`BatchPolicy::Deadline`](crate::unlearning::BatchPolicy::Deadline)
//!   the planner holds the queue while every request can still meet its
//!   latency SLO and closes the window at the last admissible tick, so
//!   coalescing is maximized *subject to* the per-request deadline.
//!
//! Battery admission is **merged-cost aware**: a window's already-merged
//! `(lineage, segment)` poison set is costed through the engine's own
//! chain resolver (one read-only pass), so the reservation equals the true
//! coalesced retrain cost rather than the sum of conservative per-request
//! hints — the old hint-sum gate under-coalesced exactly when coalescing
//! paid most. On insufficient charge the plan splits at lineage
//! granularity: the affordable lineage prefix executes now, the rest is
//! carried over (its samples are already removed from the bookkeeping, so
//! only the replay work waits for harvest).
//!
//! The implementation is split by concern: [`windows`] holds the drain /
//! price / admit / commit path (the window lifecycle the fleet worker also
//! drives stage-by-stage), [`journal`] holds the durability glue (event
//! emission, replay, snapshot capture/restore).

mod journal;
mod windows;

pub use journal::JournalStats;
pub(crate) use windows::{admission_decide, Admission, PricedWindow};

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::data::dataset::{BlockId, EdgePopulation, UserId};
use crate::data::trace::UnlearnRequest;
use crate::energy::EnergyModel;
use crate::persist::event::{
    BatchReportRec, Event, MetaRec, PlacementRecord, PlanRec, ReqRecord, RoundRec,
    SvcReportRec,
};
use crate::persist::log::EventLog;
use crate::persist::{DurabilityMode, Shipper};
use crate::sim::Battery;
use crate::unlearning::batch::{BatchPlan, BatchPlanner, LineagePlan};
use crate::util::Json;

/// Receipt for one served unlearning request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceReport {
    pub user: u32,
    pub round: u32,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Estimated device seconds for the retrain (profile-based).
    pub est_seconds: f64,
    /// Estimated joules for the retrain.
    pub est_joules: f64,
    /// Deferred because the battery could not cover the retrain.
    pub deferred: bool,
}

/// Receipt for one served (or deferred) batch window.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchReport {
    /// Requests merged into this window (0 for a deferral receipt).
    pub requests: usize,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Per-request lineage retrains avoided by coalescing this window.
    pub retrains_coalesced: u64,
    /// Queueing delay of the window's oldest request at serve time, ticks.
    pub oldest_queued_ticks: u64,
    /// Estimated device seconds for the window's retraining.
    pub est_seconds: f64,
    /// Estimated joules for the window's retraining.
    pub est_joules: f64,
    /// Deferred because the battery could not cover even one lineage.
    pub deferred: bool,
}

/// Receipt bookkeeping for a request whose poison travels in a plan: what
/// the latency receipt needs once the plan finally executes.
#[derive(Clone, Copy, Debug)]
struct ReqMeta {
    user: u32,
    round: u32,
    arrival_tick: u64,
}

/// Attached durability state: the armed write-ahead log plus the mode and
/// auto-compaction cadence.
struct Journal {
    log: EventLog,
    mode: DurabilityMode,
    compact_every: u64,
    /// Cross-shard log shipping: sealed frames stream to a peer replica
    /// (`None` = shipping not enabled; every path stays untouched).
    shipper: Option<Shipper>,
    /// First append/compaction error. Durable emission happens inside
    /// infallible entry points (`submit`), so the error is stashed here
    /// and surfaced by the next fallible call — nothing is silently
    /// un-durable.
    err: Option<String>,
}

fn req_rec_of(req: &UnlearnRequest) -> ReqRecord {
    ReqRecord {
        user: req.user.0,
        round: req.round,
        arrival_tick: req.arrival_tick,
        parts: req.parts.iter().map(|(b, n)| (b.0, *n)).collect(),
    }
}

fn req_from_rec(rec: &ReqRecord) -> UnlearnRequest {
    UnlearnRequest {
        round: rec.round,
        user: UserId(rec.user),
        arrival_tick: rec.arrival_tick,
        parts: rec.parts.iter().map(|(b, n)| (BlockId(*b), *n)).collect(),
    }
}

fn svc_rec_of(r: &ServiceReport) -> SvcReportRec {
    SvcReportRec {
        user: r.user,
        round: r.round,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as u64,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn svc_from_rec(r: &SvcReportRec) -> ServiceReport {
    ServiceReport {
        user: r.user,
        round: r.round,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as usize,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn batch_rec_of(r: &BatchReport) -> BatchReportRec {
    BatchReportRec {
        requests: r.requests as u64,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as u64,
        retrains_coalesced: r.retrains_coalesced,
        oldest_queued_ticks: r.oldest_queued_ticks,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn batch_from_rec(r: &BatchReportRec) -> BatchReport {
    BatchReport {
        requests: r.requests as usize,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as usize,
        retrains_coalesced: r.retrains_coalesced,
        oldest_queued_ticks: r.oldest_queued_ticks,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn carryover_rec_of(c: &Option<(BatchPlan, Vec<ReqMeta>)>) -> Option<(PlanRec, Vec<MetaRec>)> {
    c.as_ref().map(|(plan, metas)| {
        (
            PlanRec {
                lineages: plan
                    .lineages
                    .iter()
                    .map(|lp| {
                        (
                            lp.lineage as u64,
                            lp.segments.iter().map(|s| *s as u64).collect(),
                            lp.requests_touching as u64,
                        )
                    })
                    .collect(),
                requests: plan.requests as u64,
            },
            metas
                .iter()
                .map(|m| MetaRec { user: m.user, round: m.round, arrival_tick: m.arrival_tick })
                .collect(),
        )
    })
}

fn carryover_from_rec(
    c: &Option<(PlanRec, Vec<MetaRec>)>,
) -> Option<(BatchPlan, Vec<ReqMeta>)> {
    c.as_ref().map(|(plan, metas)| {
        (
            BatchPlan {
                lineages: plan
                    .lineages
                    .iter()
                    .map(|(l, segs, touching)| LineagePlan {
                        lineage: *l as usize,
                        segments: segs.iter().map(|s| *s as usize).collect(),
                        requests_touching: *touching as usize,
                    })
                    .collect(),
                requests: plan.requests as usize,
            },
            metas
                .iter()
                .map(|m| ReqMeta { user: m.user, round: m.round, arrival_tick: m.arrival_tick })
                .collect(),
        )
    })
}

/// Queue-fronted unlearning service over an engine.
pub struct UnlearningService {
    engine: Engine,
    queue: VecDeque<UnlearnRequest>,
    energy: EnergyModel,
    battery: Option<Battery>,
    planner: BatchPlanner,
    /// Logical service clock, ticks. [`UnlearningService::ingest_round`]
    /// advances it by one; drivers may interleave finer-grained
    /// [`UnlearningService::advance`] calls between submissions.
    now_tick: u64,
    /// One deferral receipt per episode: set when the queue head defers,
    /// cleared when anything is served (or the head changes by serving).
    head_deferral_logged: bool,
    /// Poison collected for a window that could not (fully) execute — an
    /// engine error, or lineages beyond the affordable battery prefix.
    /// Its samples are already removed from the lineages, so the plan is
    /// carried over and merged into the next executed window (exactness
    /// is preserved across errors and brownouts); the metas keep the
    /// latency receipts of requests not yet accounted.
    carryover: Option<(BatchPlan, Vec<ReqMeta>)>,
    /// Per-request receipts (FCFS drains).
    pub log: Vec<ServiceReport>,
    /// Per-window receipts (batched drains).
    pub batch_log: Vec<BatchReport>,
    /// Durability journal ([`UnlearningService::attach_durability`]);
    /// `None` keeps every code path byte-identical to the in-memory
    /// service.
    journal: Option<Journal>,
    /// Deterministic span tracer ([`UnlearningService::enable_obs`]);
    /// `None` (the default) keeps the hot path span-free.
    tracer: Option<crate::obs::Tracer>,
    /// Fleet shard index this service runs as (0 for the unsharded
    /// service), used to key per-shard registry labels.
    shard_tag: u32,
}

impl UnlearningService {
    pub fn new(engine: Engine) -> Self {
        let energy = EnergyModel::for_model(&engine.cfg.model);
        let planner = BatchPlanner::from_config(&engine.cfg);
        Self {
            engine,
            queue: VecDeque::new(),
            energy,
            battery: None,
            planner,
            now_tick: 0,
            head_deferral_logged: false,
            carryover: None,
            log: vec![],
            batch_log: vec![],
            journal: None,
            tracer: None,
            shard_tag: 0,
        }
    }

    /// Enable battery gating (energy-harvesting deployments).
    pub fn with_battery(mut self, battery: Battery) -> Self {
        self.battery = Some(battery);
        self
    }

    /// Override the batch planner (policy + window) from the config's.
    pub fn with_planner(mut self, planner: BatchPlanner) -> Self {
        self.planner = planner;
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn battery(&self) -> Option<&Battery> {
        self.battery.as_ref()
    }

    pub fn planner(&self) -> &BatchPlanner {
        &self.planner
    }

    /// Requests still waiting in the queue (not yet planned).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests whose samples are already removed but whose replay work is
    /// parked in the carryover plan (battery-starved or after an engine
    /// error), awaiting a future window.
    pub fn carryover_requests(&self) -> usize {
        self.carryover.as_ref().map_or(0, |(p, _)| p.requests)
    }

    /// Lineages with replay work parked in the carryover plan. A window
    /// split for battery reasons parks its unfunded share with
    /// `requests = 0` (the executed prefix already served and accounted
    /// every request), so shutdown loops must poll *this* — not
    /// [`UnlearningService::carryover_requests`] — to know whether
    /// poisoned versions still await retraining.
    pub fn carryover_lineages(&self) -> usize {
        self.carryover.as_ref().map_or(0, |(p, _)| p.lineages.len())
    }

    /// Current service-clock time, ticks.
    pub fn now(&self) -> u64 {
        self.now_tick
    }

    /// Advance the service clock (fine-grained arrival modelling; round
    /// ingestion advances it by one tick on its own).
    pub fn advance(&mut self, ticks: u64) {
        self.now_tick = self.now_tick.saturating_add(ticks);
        self.emit(|_| Event::Advance { ticks });
    }

    /// Run one training round (new data arrival); advances the clock.
    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        self.check_journal()?;
        self.now_tick = self.now_tick.saturating_add(1);
        let tick = self.now_tick;
        let span = crate::obs::begin_root(&mut self.tracer, "ingest", tick);
        let report = match self.engine.run_round(pop) {
            Ok(r) => r,
            Err(e) => {
                // A trainer failure mid-round leaves state the journal
                // cannot frame as one transition: drop the partial tape
                // and poison the journal — the live state has diverged
                // from the log, so continuing to ack writes would be a
                // silent durability lie (recovery replays to the last
                // committed event).
                let _ = self.engine.take_tape();
                self.poison_journal(&format!("engine error mid-round: {e:#}"));
                crate::obs::end(&mut self.tracer, span, tick, 0);
                return Err(e);
            }
        };
        let placements = report.placements.len() as u64;
        let accuracy = self
            .engine
            .metrics
            .accuracy_by_round
            .last()
            .copied()
            .flatten();
        self.emit(|svc| {
            Event::Round(Box::new(RoundRec {
                round: report.round,
                placements: report
                    .placements
                    .iter()
                    .map(|(p, u)| PlacementRecord {
                        block: p.block.0,
                        user: u.0,
                        shard: p.shard as u64,
                        samples: p.samples,
                    })
                    .collect(),
                store_ops: svc.engine.take_tape(),
                accuracy,
                metrics: svc.metrics_post(),
                partitioner_state: svc.engine.partitioner_state(),
                policy_state: svc.engine.store().policy_state(),
            }))
        });
        // A round ingest is a commit scope: seal the group-commit window
        // (one fsync) and ship the sealed frames.
        self.journal_seal();
        crate::obs::end(&mut self.tracer, span, self.now_tick, placements);
        Ok(())
    }

    /// Enqueue a request (FCFS order preserved), stamping its arrival on
    /// the service clock — queueing-delay receipts and the deadline
    /// planner both measure against this stamp. With durability attached
    /// the acceptance is logged before this returns (log-before-ack); an
    /// append failure is surfaced by the next fallible call.
    pub fn submit(&mut self, req: UnlearnRequest) {
        let mut req = req;
        req.arrival_tick = self.now_tick;
        let rec = req_rec_of(&req);
        self.queue.push_back(req);
        self.emit(|_| Event::Submit(rec));
    }

    /// Advance harvest time (satellite mode).
    pub fn harvest(&mut self, secs: f64) {
        if let Some(b) = &mut self.battery {
            b.harvest(secs);
            let battery = Some(crate::persist::event::BatteryPost {
                charge_j: b.charge_j,
                brownouts: b.brownouts,
            });
            self.emit(|_| Event::Harvest { battery });
        }
    }

    /// Turn on span tracing: every subsequent drain / price / admit /
    /// retrain / seal / ship / snapshot scope records a span into a
    /// per-shard fixed-capacity ring ([`crate::obs::Tracer`]). The tracer
    /// never touches receipts or the journal, so enabling it cannot
    /// perturb any replayed or compared state.
    pub fn enable_obs(&mut self) {
        if self.tracer.is_none() {
            self.tracer = Some(crate::obs::Tracer::new(self.shard_tag));
        }
    }

    /// Whether span tracing is enabled.
    pub fn obs_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// Key this service as fleet shard `tag` (registry labels, span lane).
    /// Call before [`UnlearningService::enable_obs`].
    pub fn set_shard_tag(&mut self, tag: u32) {
        self.shard_tag = tag;
    }

    /// Fleet shard index (0 for the unsharded service).
    pub fn shard_tag(&self) -> u32 {
        self.shard_tag
    }

    /// Snapshot of the retained span records, ring order (oldest first).
    /// Empty without [`UnlearningService::enable_obs`].
    pub fn obs_records(&self) -> Vec<crate::obs::SpanRec> {
        self.tracer.as_ref().map_or_else(Vec::new, crate::obs::Tracer::records)
    }

    /// Stamp an instant marker (scenario phase, fault injection) into the
    /// trace at the current service tick. No-op when tracing is off.
    pub fn obs_marker(&mut self, name: &'static str) {
        let tick = self.now_tick;
        crate::obs::marker(&mut self.tracer, name, tick, 0);
    }

    /// Adopt `parent` as the parent of the next root span — how the fleet
    /// front-end's drain span links to the worker-side drain it caused
    /// across the channel boundary. No-op when tracing is off.
    pub fn obs_set_parent(&mut self, parent: u64) {
        crate::obs::adopt_parent(&mut self.tracer, parent);
    }

    pub(crate) fn tracer_mut(&mut self) -> &mut Option<crate::obs::Tracer> {
        &mut self.tracer
    }

    /// Unified named-metrics registry: engine counters, queue depth,
    /// battery / journal / shipping state, and the queue-delay histogram,
    /// shard-mergeable via [`crate::obs::Registry::merge`]. Always
    /// available — no [`UnlearningService::enable_obs`] required.
    /// Deliberately excludes tracer state, so a fleet-of-one worker's
    /// registry stays byte-identical to the unsharded service's.
    pub fn registry(&self) -> crate::obs::Registry {
        let mut reg = crate::obs::Registry::new();
        let m = &self.engine.metrics;
        reg.set_counter("req.requests", m.total_requests());
        reg.set_counter("req.rsn", m.total_rsn());
        reg.set_counter("retrain.warm", m.warm_retrains);
        reg.set_counter("retrain.scratch", m.scratch_retrains);
        reg.set_counter("retrain.coalesced", m.retrains_coalesced);
        reg.set_counter("retrain.lineages", m.lineages_retrained);
        reg.set_counter("store.ckpts_stored", m.ckpts_stored);
        reg.set_counter("store.ckpts_replaced", m.ckpts_replaced);
        reg.set_counter("store.ckpts_rejected", m.ckpts_rejected);
        reg.set_counter("store.ckpts_invalidated", m.ckpts_invalidated);
        reg.set_counter("window.batches", m.batches);
        reg.set_counter("window.requests", m.batched_requests);
        reg.set_counter("prunes", m.prunes);
        reg.set_counter("latency.receipts", m.latency.len() as u64 + m.latency_dropped);
        reg.set_counter("latency.dropped", m.latency_dropped);
        reg.set_counter("latency.slo_miss", m.latency_slo_miss);
        reg.set_counter("queue.pending", self.queue.len() as u64);
        reg.set_gauge("energy.joules", m.energy_joules);
        if let Some(b) = &self.battery {
            reg.set_counter("battery.brownouts", b.brownouts);
            reg.set_gauge("battery.charge_j", b.charge_j);
            reg.set_gauge("battery.capacity_j", b.capacity_j);
        }
        if let Some(js) = self.journal_stats() {
            reg.set_counter("journal.appended", js.appended);
            reg.set_counter("journal.fsyncs", js.fsyncs);
            reg.set_counter("journal.events_in_log", js.events_in_log);
            reg.set_counter("journal.log_bytes", js.log_bytes);
            reg.set_counter("journal.snapshot_bytes", js.snapshot_bytes);
        }
        if let Some(e) = self.durability_error() {
            reg.set_label(format!("journal.error.shard{}", self.shard_tag), e);
        }
        if let Some(sr) = self.shipping_state() {
            reg.set_counter("ship.shipped_seq", sr.shipped_seq);
            reg.set_counter("ship.pending", sr.pending);
            reg.set_counter("ship.attempts", sr.attempts);
            reg.set_counter("ship.faults", sr.faults);
            reg.set_counter("ship.failed", u64::from(sr.failed.is_some()));
            if let Some(e) = &sr.last_error {
                reg.set_label(
                    format!("ship.last_error.shard{}", self.shard_tag),
                    e.clone(),
                );
            }
            if let Some(e) = &sr.failed {
                reg.set_label(
                    format!("ship.failed_reason.shard{}", self.shard_tag),
                    e.clone(),
                );
            }
        }
        reg.set_hist("latency.queue_delay", m.latency_hist.clone());
        reg
    }

    /// Deterministic, comparison-friendly digest of the full service
    /// state: clock, queue, carryover, battery, lineage totals, store
    /// layout/stats/bytes, receipt logs, and the metrics JSON. Two
    /// services with equal receipts are observably identical — this is
    /// what the kill-point crash tests compare between a recovered
    /// instance and the uninterrupted in-memory run, and what the fleet
    /// equivalence test compares between a 1-worker fleet and the
    /// unsharded service.
    pub fn state_receipt(&self) -> Json {
        let queue = Json::Arr(
            self.queue
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("user", u64::from(r.user.0))
                        .set("round", u64::from(r.round))
                        .set("arrival", r.arrival_tick)
                        .set(
                            "parts",
                            Json::Arr(
                                r.parts
                                    .iter()
                                    .map(|(b, n)| Json::Arr(vec![Json::from(b.0), Json::from(*n)]))
                                    .collect(),
                            ),
                        )
                })
                .collect(),
        );
        let carryover = match &self.carryover {
            None => Json::Null,
            Some((plan, metas)) => Json::obj()
                .set("requests", plan.requests)
                .set(
                    "lineages",
                    Json::Arr(
                        plan.lineages
                            .iter()
                            .map(|lp| {
                                Json::obj()
                                    .set("lineage", lp.lineage)
                                    .set(
                                        "segments",
                                        lp.segments.iter().map(|s| *s as u64).collect::<Vec<u64>>(),
                                    )
                                    .set("touching", lp.requests_touching)
                            })
                            .collect(),
                    ),
                )
                .set(
                    "metas",
                    Json::Arr(
                        metas
                            .iter()
                            .map(|m| {
                                Json::Arr(vec![
                                    Json::from(u64::from(m.user)),
                                    Json::from(u64::from(m.round)),
                                    Json::from(m.arrival_tick),
                                ])
                            })
                            .collect(),
                    ),
                ),
        };
        let battery = match &self.battery {
            None => Json::Null,
            Some(b) => Json::obj()
                .set("charge_j", b.charge_j)
                .set("capacity_j", b.capacity_j)
                .set("brownouts", b.brownouts),
        };
        let lineages = Json::Arr(
            (0..self.engine.lineages().len())
                .map(|l| {
                    let lin = self.engine.lineages().get(l);
                    Json::obj()
                        .set("total", lin.total_samples())
                        .set("segments", u64::from(lin.segment_count()))
                })
                .collect(),
        );
        let store = self.engine.store();
        let stats = store.stats();
        let resident = Json::Arr(
            store
                .slot_entries()
                .map(|(slot, c)| {
                    Json::Arr(vec![
                        Json::from(slot),
                        Json::from(c.id.0),
                        Json::from(c.lineage),
                        Json::from(u64::from(c.covered_segments)),
                        Json::from(c.size_bytes),
                    ])
                })
                .collect(),
        );
        let svc_log = Json::Arr(
            self.log
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("user", u64::from(r.user))
                        .set("round", u64::from(r.round))
                        .set("rsn", r.rsn)
                        .set("lineages", r.lineages_retrained)
                        .set("est_seconds", r.est_seconds)
                        .set("est_joules", r.est_joules)
                        .set("deferred", r.deferred)
                })
                .collect(),
        );
        let batch_log = Json::Arr(
            self.batch_log
                .iter()
                .map(|b| {
                    Json::obj()
                        .set("requests", b.requests)
                        .set("rsn", b.rsn)
                        .set("lineages", b.lineages_retrained)
                        .set("coalesced", b.retrains_coalesced)
                        .set("oldest", b.oldest_queued_ticks)
                        .set("est_seconds", b.est_seconds)
                        .set("est_joules", b.est_joules)
                        .set("deferred", b.deferred)
                })
                .collect(),
        );
        Json::obj()
            .set("now", self.now_tick)
            .set("head_deferral_logged", self.head_deferral_logged)
            .set("queue", queue)
            .set("carryover", carryover)
            .set("battery", battery)
            .set("lineages", lineages)
            .set(
                "store",
                Json::obj()
                    .set("occupied", store.occupied())
                    .set("stored_bytes", store.stored_bytes())
                    .set("next_id", store.next_id_peek())
                    .set("stored", stats.stored)
                    .set("replaced", stats.replaced)
                    .set("rejected", stats.rejected)
                    .set("invalidated", stats.invalidated)
                    .set("resident", resident),
            )
            .set("svc_log", svc_log)
            .set("batch_log", batch_log)
            .set("engine_round", u64::from(self.engine.round()))
            .set("metrics", self.engine.metrics.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::system::SystemVariant;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::PopulationConfig;
    use crate::data::trace::{RequestTrace, TraceConfig};
    use crate::sim::device::AI_CUBESAT;
    use crate::unlearning::batch::BatchPolicy;

    fn setup() -> (UnlearningService, EdgePopulation, RequestTrace) {
        let cfg = ExperimentConfig {
            users: 20,
            rounds: 4,
            shards: 4,
            ..Default::default()
        };
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(8_000),
            users: cfg.users,
            rounds: cfg.rounds,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed: 11,
        });
        let trace = RequestTrace::generate(&pop, &TraceConfig::paper_default(12).with_prob(0.4));
        let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        (UnlearningService::new(engine), pop, trace)
    }

    #[test]
    fn fcfs_serves_all_on_mains() {
        let (mut svc, pop, trace) = setup();
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.log.iter().filter(|r| !r.deferred).count(), submitted);
        assert!(svc.engine().metrics.total_rsn() > 0);
        // Every served request left a latency receipt; same-tick service
        // means zero queueing delay under this driver.
        assert_eq!(svc.engine().metrics.latency.len(), submitted);
        assert_eq!(svc.engine().metrics.slo_violations(), 0);
    }

    #[test]
    fn batched_serves_all_and_coalesces() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain_batched().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        let m = &svc.engine().metrics;
        assert_eq!(m.total_requests(), submitted as u64);
        assert_eq!(m.batched_requests, submitted as u64);
        // One window per round with pending work.
        assert!(m.batches >= 1 && m.batches <= 4, "batches {}", m.batches);
        let batch_requests: usize = svc.batch_log.iter().map(|b| b.requests).sum();
        assert_eq!(batch_requests, submitted);
        assert_eq!(m.latency.len(), submitted);
    }

    #[test]
    fn deadline_holds_then_closes_at_slo() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(
            BatchPolicy::Deadline { slo_ticks: 2 },
            0,
        ));
        svc.ingest_round(&pop).unwrap();
        svc.ingest_round(&pop).unwrap();
        let mut submitted = 0;
        for req in trace.at(1).iter().chain(trace.at(2)) {
            svc.submit(req.clone());
            submitted += 1;
        }
        assert!(submitted >= 2, "trace produced too few requests");
        // Age 0 and 1: the planner holds the whole queue.
        assert_eq!(svc.drain_batched().unwrap(), 0);
        svc.advance(1);
        assert_eq!(svc.drain_batched().unwrap(), 0);
        assert_eq!(svc.pending(), submitted);
        // Age 2 == SLO: the window closes over everything queued.
        svc.advance(1);
        assert_eq!(svc.drain_batched().unwrap(), submitted);
        assert_eq!(svc.pending(), 0);
        let m = &svc.engine().metrics;
        assert_eq!(m.batches, 1, "one coalesced window at the deadline");
        assert_eq!(m.latency.len(), submitted);
        assert!(m.latency.iter().all(|r| r.queued_ticks == 2 && r.slo_met));
    }

    #[test]
    fn flush_serves_infinite_slo_queue() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(
            BatchPolicy::Deadline { slo_ticks: u64::MAX },
            0,
        ));
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            assert_eq!(svc.drain_batched().unwrap(), 0, "infinite SLO never closes");
        }
        assert_eq!(svc.pending(), submitted);
        // Flush: the whole queue coalesces into one window (the Coalesce
        // degenerate point).
        assert_eq!(svc.flush_batched().unwrap(), submitted);
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.engine().metrics.batches, 1);
    }

    #[test]
    fn battery_defers_until_harvest() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5; // almost empty
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 1, "request should be deferred");
        assert!(svc.log.last().unwrap().deferred);
        // Harvest a lot, then it goes through.
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
    }

    #[test]
    fn deferral_logged_once_per_episode() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5;
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        // Polling a starving queue repeatedly must not inflate the count.
        for _ in 0..5 {
            svc.drain().unwrap();
        }
        assert_eq!(svc.log.iter().filter(|r| r.deferred).count(), 1);
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
        // A fresh starvation episode logs again.
        let req2 = trace
            .at(2)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(3).first().cloned().expect("trace has requests"));
        if let Some(b) = &mut svc.battery {
            b.charge_j = 0.0;
        }
        svc.submit(req2);
        for _ in 0..3 {
            svc.drain().unwrap();
        }
        assert_eq!(svc.log.iter().filter(|r| r.deferred).count(), 2);
    }

    #[test]
    fn batched_battery_defers_and_recovers() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5;
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery)
            .with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
        // Two rounds ingested so every submitted request poisons live data.
        svc.ingest_round(&pop).unwrap();
        svc.ingest_round(&pop).unwrap();
        let mut submitted = 0;
        for req in trace.at(1).iter().chain(trace.at(2)).take(4) {
            svc.submit(req.clone());
            submitted += 1;
        }
        assert!(submitted > 0, "trace produced no requests");
        for _ in 0..4 {
            svc.drain_batched().unwrap();
        }
        // Merged-cost admission: the plan is collected (samples removed,
        // queue empty) but parked unfunded — requests are not yet served.
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.carryover_requests(), submitted);
        assert_eq!(svc.engine().metrics.total_requests(), 0);
        assert_eq!(svc.batch_log.iter().filter(|b| b.deferred).count(), 1);
        svc.harvest(1e7);
        svc.drain_batched().unwrap();
        assert_eq!(svc.carryover_requests(), 0);
        assert_eq!(svc.engine().metrics.total_requests(), submitted as u64);
        let served: usize =
            svc.batch_log.iter().filter(|b| !b.deferred).map(|b| b.requests).sum();
        assert_eq!(served, submitted);
        // Battery never exceeds capacity after refunds.
        let b = svc.battery().unwrap();
        assert!(b.charge_j <= b.capacity_j);
    }
}
