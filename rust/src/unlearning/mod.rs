//! The unlearning *service*: a queue-fronted façade over the engine, the
//! shape a deployment embeds (examples use it; experiments drive the
//! engine directly for determinism), plus the batched request-coalescing
//! subsystem that turns R same-window retrains of a lineage into one.

pub mod batch;
pub mod service;

pub use batch::{BatchPlan, BatchPlanner, BatchPolicy, LineagePlan};
pub use service::{BatchReport, ServiceReport, UnlearningService};
