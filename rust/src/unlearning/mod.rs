//! The unlearning *service*: a queue-fronted façade over the engine, the
//! shape a deployment embeds (examples use it; experiments drive the
//! engine directly for determinism).

pub mod service;

pub use service::{ServiceReport, UnlearningService};
