//! The unlearning *service*: a queue-fronted façade over the engine, the
//! shape a deployment embeds (examples use it; experiments drive the
//! engine directly for determinism), plus the batched request-coalescing
//! subsystem that turns R same-window retrains of a lineage into one —
//! optionally deadline-aware ([`BatchPolicy::Deadline`]): coalescing is
//! maximized subject to a per-request queueing-delay SLO, with FCFS and
//! whole-queue coalescing as the SLO = 0 / SLO = ∞ degenerate points.

pub mod batch;
pub mod service;

pub use batch::{BatchPlan, BatchPlanner, BatchPolicy, LineagePlan};
pub use service::{BatchReport, JournalStats, ServiceReport, UnlearningService};
