//! Batched unlearning: request coalescing and retrain planning.
//!
//! The paper's service model is strictly FCFS: every request retrains each
//! affected lineage on its own, so a burst of R same-window requests
//! touching one lineage pays the replay cost R times. The batch subsystem
//! drains the service queue in windows, merges all queued requests'
//! poisoned `(lineage, segment)` sets, and emits **one retrain plan per
//! lineage**: warm-start from the newest clean checkpoint below the
//! *minimum* poisoned segment and replay forward once. Every poisoned
//! sub-model version is still invalidated (Alg. 3 line 11), so the
//! exact-unlearning guarantee is unchanged — only the redundant replays
//! disappear.
//!
//! Layering: [`BatchPolicy`] is the config knob, [`BatchPlanner`] decides
//! window sizes and builds [`BatchPlan`]s, and
//! [`Engine::execute_plan`](crate::coordinator::engine::Engine::execute_plan)
//! resolves and runs a plan (in parallel across lineages when the training
//! backend supports off-thread workers).

use std::collections::{BTreeMap, BTreeSet};

use crate::config::ExperimentConfig;
use crate::coordinator::engine::Engine;
use crate::data::trace::UnlearnRequest;

/// How the service merges queued requests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BatchPolicy {
    /// One request per window — the paper's service model.
    Fcfs,
    /// Merge a window's poison sets and retrain each lineage once.
    #[default]
    Coalesce,
    /// Deadline-aware coalescing: keep accumulating requests while the
    /// oldest queued request can still meet its latency SLO, and close the
    /// window (serving everything queued, coalesced) the moment waiting any
    /// longer would violate it. `Fcfs` and `Coalesce` are the degenerate
    /// points of this policy: `slo_ticks = 0` leaves no slack to wait, so
    /// every request is served in its own immediate window (the paper's
    /// FCFS model); `slo_ticks = u64::MAX` never closes on a deadline, so
    /// the whole queue coalesces into one window at flush time.
    Deadline {
        /// Max queueing delay (service-clock ticks) any request may incur.
        slo_ticks: u64,
    },
}

impl BatchPolicy {
    pub fn display(&self) -> &'static str {
        match self {
            BatchPolicy::Fcfs => "fcfs",
            BatchPolicy::Coalesce => "coalesce",
            BatchPolicy::Deadline { .. } => "deadline",
        }
    }

    /// Parse a policy name. `deadline` gets `slo_ticks = 0`; the config
    /// layer rebinds it to the configured `batch_slo`.
    pub fn by_name(name: &str) -> Option<BatchPolicy> {
        match name.trim().to_ascii_lowercase().as_str() {
            "fcfs" => Some(BatchPolicy::Fcfs),
            "coalesce" | "batch" | "batched" => Some(BatchPolicy::Coalesce),
            "deadline" | "slo" => Some(BatchPolicy::Deadline { slo_ticks: 0 }),
            _ => None,
        }
    }

    /// The latency SLO this policy promises, if any. Receipts mark
    /// `slo_met` against this bound; policies without one always meet it.
    pub fn slo(&self) -> Option<u64> {
        match self {
            BatchPolicy::Deadline { slo_ticks } => Some(*slo_ticks),
            _ => None,
        }
    }
}

/// One lineage's merged retrain work for a window: the union of poisoned
/// segment indices across every request, sorted ascending.
#[derive(Clone, Debug)]
pub struct LineagePlan {
    pub lineage: usize,
    /// Poisoned segment indices, sorted ascending, deduplicated.
    pub segments: Vec<usize>,
    /// How many of the window's requests poisoned this lineage.
    pub requests_touching: usize,
}

/// A window's worth of unlearning work, coalesced per lineage.
#[derive(Clone, Debug, Default)]
pub struct BatchPlan {
    /// One entry per affected lineage (ascending lineage index).
    pub lineages: Vec<LineagePlan>,
    /// Requests whose samples were removed into this plan.
    pub requests: usize,
}

impl BatchPlan {
    /// Remove the window's samples from the lineage bookkeeping (Alg. 3
    /// line 7, once per request) and merge the resulting poison sets into
    /// one plan. A lineage poisoned by several requests appears once, with
    /// the union of their segments.
    pub fn collect(engine: &mut Engine, reqs: &[UnlearnRequest]) -> BatchPlan {
        let mut merged: BTreeMap<usize, (BTreeSet<usize>, usize)> = BTreeMap::new();
        for req in reqs {
            for (lineage, segs) in engine.collect_poison(req) {
                let entry = merged.entry(lineage).or_default();
                entry.0.extend(segs);
                entry.1 += 1;
            }
        }
        BatchPlan {
            lineages: merged
                .into_iter()
                .map(|(lineage, (segs, requests_touching))| LineagePlan {
                    lineage,
                    segments: segs.into_iter().collect(),
                    requests_touching,
                })
                .collect(),
            requests: reqs.len(),
        }
    }

    /// No lineage was poisoned (requests targeted already-forgotten data).
    pub fn is_empty(&self) -> bool {
        self.lineages.is_empty()
    }

    /// Per-request lineage retrains avoided by merging: a lineage touched
    /// by k requests retrains once instead of k times.
    pub fn coalesced_retrains(&self) -> u64 {
        self.lineages
            .iter()
            .map(|l| l.requests_touching.saturating_sub(1) as u64)
            .sum()
    }

    /// Merge another plan's poison sets into this one. Used by the service
    /// to carry an *unexecuted* plan over to the next window after an
    /// engine error: the failed window's samples are already removed from
    /// the lineage bookkeeping (so its requests cannot be re-queued — a
    /// second `collect` would remove additional never-requested samples);
    /// the poison and the request count travel in the plan instead, and
    /// are served/accounted when a window finally executes.
    pub fn merge(&mut self, other: BatchPlan) {
        self.requests += other.requests;
        for olp in other.lineages {
            match self.lineages.iter_mut().find(|l| l.lineage == olp.lineage) {
                Some(lp) => {
                    for q in olp.segments {
                        if !lp.segments.contains(&q) {
                            lp.segments.push(q);
                        }
                    }
                    lp.segments.sort_unstable();
                    lp.requests_touching += olp.requests_touching;
                }
                None => self.lineages.push(olp),
            }
        }
        self.lineages.sort_by_key(|l| l.lineage);
    }
}

/// Plans service windows: how many queued requests to merge per batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPlanner {
    pub policy: BatchPolicy,
    /// Max requests merged per window; 0 = drain the whole queue at once.
    /// Ignored under [`BatchPolicy::Fcfs`].
    pub window: usize,
}

impl BatchPlanner {
    pub fn new(policy: BatchPolicy, window: usize) -> Self {
        Self { policy, window }
    }

    /// Planner matching an experiment config's batch knobs.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        Self::new(cfg.batch_policy, cfg.batch_window)
    }

    /// Requests to drain into the next window given the queue depth,
    /// ignoring deadline pressure (legacy entry point; under
    /// [`BatchPolicy::Deadline`] it never closes a window — use
    /// [`BatchPlanner::window_size_at`] with the oldest request's age).
    pub fn window_size(&self, queued: usize) -> usize {
        self.window_size_at(queued, None)
    }

    /// Requests to drain into the next window. `oldest_age` is the current
    /// queueing delay of the queue head (`now - arrival_tick`), `None` when
    /// the queue is empty. One code path covers the whole policy spectrum:
    ///
    /// * `Fcfs` — one request per window, always.
    /// * `Coalesce` — everything queued (capped by `window`), always.
    /// * `Deadline { slo_ticks: 0 }` — zero slack: one request per
    ///   window, immediately (≡ `Fcfs`).
    /// * `Deadline { slo_ticks }` — hold (return 0) while the oldest
    ///   request's age is below its SLO; once serving can no longer be
    ///   postponed, close the window over everything queued (capped by
    ///   `window`). `slo_ticks = u64::MAX` never closes (≡ `Coalesce`
    ///   deferred to an explicit flush).
    pub fn window_size_at(&self, queued: usize, oldest_age: Option<u64>) -> usize {
        match self.policy {
            BatchPolicy::Fcfs | BatchPolicy::Deadline { slo_ticks: 0 } => queued.min(1),
            BatchPolicy::Coalesce if self.window == 0 => queued,
            BatchPolicy::Coalesce => queued.min(self.window),
            BatchPolicy::Deadline { slo_ticks } => match oldest_age {
                Some(age) if age >= slo_ticks => {
                    if self.window == 0 {
                        queued
                    } else {
                        queued.min(self.window)
                    }
                }
                _ => 0,
            },
        }
    }

    /// Collect one window's merged plan (see [`BatchPlan::collect`]).
    pub fn plan(&self, engine: &mut Engine, window: &[UnlearnRequest]) -> BatchPlan {
        BatchPlan::collect(engine, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            BatchPolicy::Fcfs,
            BatchPolicy::Coalesce,
            BatchPolicy::Deadline { slo_ticks: 0 },
        ] {
            assert_eq!(BatchPolicy::by_name(p.display()), Some(p));
        }
        assert_eq!(BatchPolicy::by_name("batched"), Some(BatchPolicy::Coalesce));
        assert_eq!(
            BatchPolicy::by_name("slo"),
            Some(BatchPolicy::Deadline { slo_ticks: 0 })
        );
        assert!(BatchPolicy::by_name("lifo").is_none());
    }

    #[test]
    fn slo_accessor_only_on_deadline() {
        assert_eq!(BatchPolicy::Fcfs.slo(), None);
        assert_eq!(BatchPolicy::Coalesce.slo(), None);
        assert_eq!(BatchPolicy::Deadline { slo_ticks: 7 }.slo(), Some(7));
    }

    #[test]
    fn window_sizes_respect_policy() {
        let fcfs = BatchPlanner::new(BatchPolicy::Fcfs, 0);
        assert_eq!(fcfs.window_size(9), 1);
        assert_eq!(fcfs.window_size(0), 0);

        let unbounded = BatchPlanner::new(BatchPolicy::Coalesce, 0);
        assert_eq!(unbounded.window_size(9), 9);

        let capped = BatchPlanner::new(BatchPolicy::Coalesce, 4);
        assert_eq!(capped.window_size(9), 4);
        assert_eq!(capped.window_size(3), 3);
    }

    #[test]
    fn deadline_window_closes_exactly_at_slo() {
        let d = BatchPlanner::new(BatchPolicy::Deadline { slo_ticks: 3 }, 0);
        // Below the SLO the planner holds; at/over the bound it flushes.
        assert_eq!(d.window_size_at(5, Some(0)), 0);
        assert_eq!(d.window_size_at(5, Some(2)), 0);
        assert_eq!(d.window_size_at(5, Some(3)), 5);
        assert_eq!(d.window_size_at(5, Some(9)), 5);
        assert_eq!(d.window_size_at(0, None), 0);
        // Legacy entry point carries no deadline pressure: always holds.
        assert_eq!(d.window_size(5), 0);

        // The cap still applies when a deadline closes the window.
        let capped = BatchPlanner::new(BatchPolicy::Deadline { slo_ticks: 3 }, 2);
        assert_eq!(capped.window_size_at(5, Some(4)), 2);

        // Degenerate points: slo=0 ≡ FCFS, slo=∞ never closes.
        let zero = BatchPlanner::new(BatchPolicy::Deadline { slo_ticks: 0 }, 0);
        assert_eq!(zero.window_size_at(5, Some(0)), 1);
        let inf = BatchPlanner::new(BatchPolicy::Deadline { slo_ticks: u64::MAX }, 0);
        assert_eq!(inf.window_size_at(5, Some(1_000_000)), 0);
    }

    #[test]
    fn merge_unions_poison_sets() {
        let mut a = BatchPlan {
            lineages: vec![LineagePlan { lineage: 0, segments: vec![1], requests_touching: 1 }],
            requests: 2,
        };
        let b = BatchPlan {
            lineages: vec![
                LineagePlan { lineage: 0, segments: vec![3, 1], requests_touching: 2 },
                LineagePlan { lineage: 5, segments: vec![0], requests_touching: 1 },
            ],
            requests: 3,
        };
        a.merge(b);
        assert_eq!(a.requests, 5, "carried-over requests are counted when served");
        assert_eq!(a.lineages.len(), 2);
        assert_eq!(a.lineages[0].segments, vec![1, 3]);
        assert_eq!(a.lineages[0].requests_touching, 3);
        assert_eq!(a.lineages[1].lineage, 5);
    }

    #[test]
    fn coalesced_retrains_counts_merges() {
        let plan = BatchPlan {
            lineages: vec![
                LineagePlan { lineage: 0, segments: vec![1, 3], requests_touching: 4 },
                LineagePlan { lineage: 2, segments: vec![0], requests_touching: 1 },
            ],
            requests: 5,
        };
        assert_eq!(plan.coalesced_retrains(), 3);
        assert!(!plan.is_empty());
        assert!(BatchPlan::default().is_empty());
    }
}
