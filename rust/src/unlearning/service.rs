//! Queue-fronted unlearning service.
//!
//! Wraps an [`Engine`] with the request lifecycle a real edge deployment
//! needs: a service clock (ticks), queueing, per-request and per-batch
//! receipts (RSN, latency estimate, energy, queueing delay), optional
//! battery gating (satellite mode: defer retraining when the state of
//! charge cannot cover it), and a service log.
//!
//! Two drain modes:
//! * [`UnlearningService::drain`] — strictly FCFS, one retrain pass per
//!   request (the paper's service model).
//! * [`UnlearningService::drain_batched`] — windows of queued requests are
//!   merged by the configured [`BatchPlanner`]. Under
//!   [`BatchPolicy::Deadline`](crate::unlearning::BatchPolicy::Deadline)
//!   the planner holds the queue while every request can still meet its
//!   latency SLO and closes the window at the last admissible tick, so
//!   coalescing is maximized *subject to* the per-request deadline.
//!
//! Battery admission is **merged-cost aware**: a window's already-merged
//! `(lineage, segment)` poison set is costed through the engine's own
//! chain resolver (one read-only pass), so the reservation equals the true
//! coalesced retrain cost rather than the sum of conservative per-request
//! hints — the old hint-sum gate under-coalesced exactly when coalescing
//! paid most. On insufficient charge the plan splits at lineage
//! granularity: the affordable lineage prefix executes now, the rest is
//! carried over (its samples are already removed from the bookkeeping, so
//! only the replay work waits for harvest).

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::data::dataset::{BlockId, EdgePopulation, UserId};
use crate::data::trace::UnlearnRequest;
use crate::energy::EnergyModel;
use crate::metrics::{LatencyReceipt, RunMetrics};
use crate::persist::event::{
    BatchReportRec, BatteryPost, Event, LatencyRecord, MetaRec, MetricsPost,
    PlacementRecord, PlanRec, ReqRecord, RoundRec, ServeRec, SvcReportRec, WindowRec,
};
use crate::persist::log::EventLog;
use crate::persist::recovery::{self, RecoveryReport};
use crate::persist::snapshot::{BatteryImage, MetricsImage, StateImage};
use crate::persist::{Durability, DurabilityMode};
use crate::sim::Battery;
use crate::unlearning::batch::{BatchPlan, BatchPlanner, LineagePlan};
use crate::util::Json;

/// Receipt for one served unlearning request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceReport {
    pub user: u32,
    pub round: u32,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Estimated device seconds for the retrain (profile-based).
    pub est_seconds: f64,
    /// Estimated joules for the retrain.
    pub est_joules: f64,
    /// Deferred because the battery could not cover the retrain.
    pub deferred: bool,
}

/// Receipt for one served (or deferred) batch window.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchReport {
    /// Requests merged into this window (0 for a deferral receipt).
    pub requests: usize,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Per-request lineage retrains avoided by coalescing this window.
    pub retrains_coalesced: u64,
    /// Queueing delay of the window's oldest request at serve time, ticks.
    pub oldest_queued_ticks: u64,
    /// Estimated device seconds for the window's retraining.
    pub est_seconds: f64,
    /// Estimated joules for the window's retraining.
    pub est_joules: f64,
    /// Deferred because the battery could not cover even one lineage.
    pub deferred: bool,
}

/// Receipt bookkeeping for a request whose poison travels in a plan: what
/// the latency receipt needs once the plan finally executes.
#[derive(Clone, Copy, Debug)]
struct ReqMeta {
    user: u32,
    round: u32,
    arrival_tick: u64,
}

/// Attached durability state: the armed write-ahead log plus the mode and
/// auto-compaction cadence.
struct Journal {
    log: EventLog,
    mode: DurabilityMode,
    compact_every: u64,
    /// First append/compaction error. Durable emission happens inside
    /// infallible entry points (`submit`), so the error is stashed here
    /// and surfaced by the next fallible call — nothing is silently
    /// un-durable.
    err: Option<String>,
}

/// Battery admission verdict for one window's merged plan.
enum Admission {
    /// The whole plan is affordable; reserve this much.
    Granted { reserve_j: f64 },
    /// Only a lineage prefix is affordable; `defer` holds the rest.
    Split { defer: BatchPlan, reserve_j: f64 },
    /// Not even the first lineage is affordable right now.
    Starved { probe_j: f64 },
}

fn req_rec_of(req: &UnlearnRequest) -> ReqRecord {
    ReqRecord {
        user: req.user.0,
        round: req.round,
        arrival_tick: req.arrival_tick,
        parts: req.parts.iter().map(|(b, n)| (b.0, *n)).collect(),
    }
}

fn req_from_rec(rec: &ReqRecord) -> UnlearnRequest {
    UnlearnRequest {
        round: rec.round,
        user: UserId(rec.user),
        arrival_tick: rec.arrival_tick,
        parts: rec.parts.iter().map(|(b, n)| (BlockId(*b), *n)).collect(),
    }
}

fn svc_rec_of(r: &ServiceReport) -> SvcReportRec {
    SvcReportRec {
        user: r.user,
        round: r.round,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as u64,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn svc_from_rec(r: &SvcReportRec) -> ServiceReport {
    ServiceReport {
        user: r.user,
        round: r.round,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as usize,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn batch_rec_of(r: &BatchReport) -> BatchReportRec {
    BatchReportRec {
        requests: r.requests as u64,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as u64,
        retrains_coalesced: r.retrains_coalesced,
        oldest_queued_ticks: r.oldest_queued_ticks,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn batch_from_rec(r: &BatchReportRec) -> BatchReport {
    BatchReport {
        requests: r.requests as usize,
        rsn: r.rsn,
        lineages_retrained: r.lineages_retrained as usize,
        retrains_coalesced: r.retrains_coalesced,
        oldest_queued_ticks: r.oldest_queued_ticks,
        est_seconds: r.est_seconds,
        est_joules: r.est_joules,
        deferred: r.deferred,
    }
}

fn carryover_rec_of(c: &Option<(BatchPlan, Vec<ReqMeta>)>) -> Option<(PlanRec, Vec<MetaRec>)> {
    c.as_ref().map(|(plan, metas)| {
        (
            PlanRec {
                lineages: plan
                    .lineages
                    .iter()
                    .map(|lp| {
                        (
                            lp.lineage as u64,
                            lp.segments.iter().map(|s| *s as u64).collect(),
                            lp.requests_touching as u64,
                        )
                    })
                    .collect(),
                requests: plan.requests as u64,
            },
            metas
                .iter()
                .map(|m| MetaRec { user: m.user, round: m.round, arrival_tick: m.arrival_tick })
                .collect(),
        )
    })
}

fn carryover_from_rec(
    c: &Option<(PlanRec, Vec<MetaRec>)>,
) -> Option<(BatchPlan, Vec<ReqMeta>)> {
    c.as_ref().map(|(plan, metas)| {
        (
            BatchPlan {
                lineages: plan
                    .lineages
                    .iter()
                    .map(|(l, segs, touching)| LineagePlan {
                        lineage: *l as usize,
                        segments: segs.iter().map(|s| *s as usize).collect(),
                        requests_touching: *touching as usize,
                    })
                    .collect(),
                requests: plan.requests as usize,
            },
            metas
                .iter()
                .map(|m| ReqMeta { user: m.user, round: m.round, arrival_tick: m.arrival_tick })
                .collect(),
        )
    })
}

/// Queue-fronted unlearning service over an engine.
pub struct UnlearningService {
    engine: Engine,
    queue: VecDeque<UnlearnRequest>,
    energy: EnergyModel,
    battery: Option<Battery>,
    planner: BatchPlanner,
    /// Logical service clock, ticks. [`UnlearningService::ingest_round`]
    /// advances it by one; drivers may interleave finer-grained
    /// [`UnlearningService::advance`] calls between submissions.
    now_tick: u64,
    /// One deferral receipt per episode: set when the queue head defers,
    /// cleared when anything is served (or the head changes by serving).
    head_deferral_logged: bool,
    /// Poison collected for a window that could not (fully) execute — an
    /// engine error, or lineages beyond the affordable battery prefix.
    /// Its samples are already removed from the lineages, so the plan is
    /// carried over and merged into the next executed window (exactness
    /// is preserved across errors and brownouts); the metas keep the
    /// latency receipts of requests not yet accounted.
    carryover: Option<(BatchPlan, Vec<ReqMeta>)>,
    /// Per-request receipts (FCFS drains).
    pub log: Vec<ServiceReport>,
    /// Per-window receipts (batched drains).
    pub batch_log: Vec<BatchReport>,
    /// Durability journal ([`UnlearningService::attach_durability`]);
    /// `None` keeps every code path byte-identical to the in-memory
    /// service.
    journal: Option<Journal>,
}

impl UnlearningService {
    pub fn new(engine: Engine) -> Self {
        let energy = EnergyModel::for_model(&engine.cfg.model);
        let planner = BatchPlanner::from_config(&engine.cfg);
        Self {
            engine,
            queue: VecDeque::new(),
            energy,
            battery: None,
            planner,
            now_tick: 0,
            head_deferral_logged: false,
            carryover: None,
            log: vec![],
            batch_log: vec![],
            journal: None,
        }
    }

    /// Enable battery gating (energy-harvesting deployments).
    pub fn with_battery(mut self, battery: Battery) -> Self {
        self.battery = Some(battery);
        self
    }

    /// Override the batch planner (policy + window) from the config's.
    pub fn with_planner(mut self, planner: BatchPlanner) -> Self {
        self.planner = planner;
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn battery(&self) -> Option<&Battery> {
        self.battery.as_ref()
    }

    pub fn planner(&self) -> &BatchPlanner {
        &self.planner
    }

    /// Requests still waiting in the queue (not yet planned).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests whose samples are already removed but whose replay work is
    /// parked in the carryover plan (battery-starved or after an engine
    /// error), awaiting a future window.
    pub fn carryover_requests(&self) -> usize {
        self.carryover.as_ref().map_or(0, |(p, _)| p.requests)
    }

    /// Lineages with replay work parked in the carryover plan. A window
    /// split for battery reasons parks its unfunded share with
    /// `requests = 0` (the executed prefix already served and accounted
    /// every request), so shutdown loops must poll *this* — not
    /// [`UnlearningService::carryover_requests`] — to know whether
    /// poisoned versions still await retraining.
    pub fn carryover_lineages(&self) -> usize {
        self.carryover.as_ref().map_or(0, |(p, _)| p.lineages.len())
    }

    /// Current service-clock time, ticks.
    pub fn now(&self) -> u64 {
        self.now_tick
    }

    /// Advance the service clock (fine-grained arrival modelling; round
    /// ingestion advances it by one tick on its own).
    pub fn advance(&mut self, ticks: u64) {
        self.now_tick = self.now_tick.saturating_add(ticks);
        self.emit(|_| Event::Advance { ticks });
    }

    /// Run one training round (new data arrival); advances the clock.
    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        self.check_journal()?;
        self.now_tick = self.now_tick.saturating_add(1);
        let report = match self.engine.run_round(pop) {
            Ok(r) => r,
            Err(e) => {
                // A trainer failure mid-round leaves state the journal
                // cannot frame as one transition: drop the partial tape
                // and poison the journal — the live state has diverged
                // from the log, so continuing to ack writes would be a
                // silent durability lie (recovery replays to the last
                // committed event).
                let _ = self.engine.take_tape();
                self.poison_journal(&format!("engine error mid-round: {e:#}"));
                return Err(e);
            }
        };
        let accuracy = self
            .engine
            .metrics
            .accuracy_by_round
            .last()
            .copied()
            .flatten();
        self.emit(|svc| {
            Event::Round(Box::new(RoundRec {
                round: report.round,
                placements: report
                    .placements
                    .iter()
                    .map(|(p, u)| PlacementRecord {
                        block: p.block.0,
                        user: u.0,
                        shard: p.shard as u64,
                        samples: p.samples,
                    })
                    .collect(),
                store_ops: svc.engine.take_tape(),
                accuracy,
                metrics: svc.metrics_post(),
                partitioner_state: svc.engine.partitioner_state(),
                policy_state: svc.engine.store().policy_state(),
            }))
        });
        Ok(())
    }

    /// Enqueue a request (FCFS order preserved), stamping its arrival on
    /// the service clock — queueing-delay receipts and the deadline
    /// planner both measure against this stamp. With durability attached
    /// the acceptance is logged before this returns (log-before-ack); an
    /// append failure is surfaced by the next fallible call.
    pub fn submit(&mut self, req: UnlearnRequest) {
        let mut req = req;
        req.arrival_tick = self.now_tick;
        let rec = req_rec_of(&req);
        self.queue.push_back(req);
        self.emit(|_| Event::Submit(rec));
    }

    /// Conservative energy pre-estimate for the first `w` queued requests:
    /// replaying every requested sample (FCFS drains only; batched drains
    /// reserve the resolver's true merged cost instead).
    fn window_hint_joules(&self, w: usize) -> f64 {
        let rsn_hint: u64 = self.queue.iter().take(w).map(|r| r.total_samples()).sum();
        self.energy.retrain_joules(rsn_hint, self.engine.cfg.epochs_per_round)
    }

    /// Log at most one deferral receipt per episode (a stuck head polled
    /// by many drain calls previously produced one receipt per call,
    /// inflating deferral counts in the satellite scenario).
    fn log_deferral(&mut self, user: u32, round: u32, est_joules: f64) {
        if self.head_deferral_logged {
            return;
        }
        self.head_deferral_logged = true;
        self.log.push(ServiceReport {
            user,
            round,
            rsn: 0,
            lineages_retrained: 0,
            est_seconds: 0.0,
            est_joules,
            deferred: true,
        });
    }

    /// Serve queued requests strictly FCFS. With a battery, a request
    /// whose estimated energy exceeds the charge is deferred (stays at the
    /// queue head) until `harvest` restores enough charge.
    pub fn drain(&mut self) -> Result<usize> {
        self.check_journal()?;
        // A plan carried over from a failed batched window must not be
        // stranded when the caller switches to FCFS drains: flush it
        // first (its samples are already removed from the lineages).
        let mut served = if self.carryover.is_some() {
            self.execute_window(Vec::new())?
        } else {
            0
        };
        while let Some(req) = self.queue.front().cloned() {
            // Conservative pre-estimate: replaying all requested samples.
            let est_j_hint = self.window_hint_joules(1);
            let starved = match &self.battery {
                Some(b) => !b.can_cover(est_j_hint),
                None => false,
            };
            if starved {
                // One brownout per starvation episode (a refused draw),
                // not one per drain() poll of the same stuck head.
                if !self.head_deferral_logged {
                    if let Some(b) = &mut self.battery {
                        let _ = b.draw(est_j_hint);
                    }
                    self.log_deferral(req.user.0, req.round, est_j_hint);
                    self.emit(|svc| {
                        Event::Serve(Box::new(ServeRec {
                            popped: false,
                            store_ops: svc.engine.take_tape(),
                            battery: svc.battery_post(),
                            metrics: svc.metrics_post(),
                            latency: None,
                            report: svc_rec_of(svc.log.last().expect("deferral logged")),
                            head_deferral_logged: true,
                            policy_state: svc.engine.store().policy_state(),
                        }))
                    });
                }
                break; // FCFS: don't skip ahead of the deferred head.
            }
            if let Some(b) = &mut self.battery {
                let drawn = b.draw(est_j_hint);
                debug_assert!(drawn, "covered by the can_cover probe above");
            }
            let outcome = match self.engine.process_request(&req) {
                Ok(o) => o,
                Err(e) => {
                    // Partial trainer failure: the tape cannot frame this
                    // as one clean transition — drop it and poison the
                    // journal (live state has diverged from the log;
                    // recovery replays to the last committed event).
                    let _ = self.engine.take_tape();
                    self.poison_journal(&format!("engine error mid-serve: {e:#}"));
                    return Err(e);
                }
            };
            let est_seconds = self
                .engine
                .cfg
                .model
                .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
            let est_joules = self
                .energy
                .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
            if let Some(b) = &mut self.battery {
                b.settle(est_joules, est_j_hint);
            }
            let queued_ticks = self.now_tick.saturating_sub(req.arrival_tick);
            let slo = self.planner.policy.slo();
            self.engine.metrics.record_latency(LatencyReceipt {
                user: req.user.0,
                round: req.round,
                queued_ticks,
                slo_met: slo.map_or(true, |s| queued_ticks <= s),
            });
            self.log.push(ServiceReport {
                user: req.user.0,
                round: req.round,
                rsn: outcome.rsn,
                lineages_retrained: outcome.lineages_retrained,
                est_seconds,
                est_joules,
                deferred: false,
            });
            self.queue.pop_front();
            self.head_deferral_logged = false;
            self.emit(|svc| {
                let last = {
                    let l = svc.engine.metrics.latency.last().expect("receipt just recorded");
                    LatencyRecord {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    }
                };
                Event::Serve(Box::new(ServeRec {
                    popped: true,
                    store_ops: svc.engine.take_tape(),
                    battery: svc.battery_post(),
                    metrics: svc.metrics_post(),
                    latency: Some(last),
                    report: svc_rec_of(svc.log.last().expect("report just pushed")),
                    head_deferral_logged: false,
                    policy_state: svc.engine.store().policy_state(),
                }))
            });
            served += 1;
        }
        Ok(served)
    }

    /// Serve queued requests in coalesced windows per the configured
    /// [`BatchPlanner`]: each window's poison sets are merged so a lineage
    /// touched by R requests replays once instead of R times. Under a
    /// deadline policy, windows close exactly when the oldest queued
    /// request's SLO leaves no more slack. Returns the number of requests
    /// served. With a battery, admission reserves the true merged plan
    /// cost and splits the plan at lineage granularity when only a prefix
    /// is affordable (one deferral receipt per starvation episode).
    pub fn drain_batched(&mut self) -> Result<usize> {
        self.drain_windows(false)
    }

    /// Serve everything queued regardless of deadline slack (end of run /
    /// device shutdown): the whole queue coalesces into one window, which
    /// is where `Deadline { slo_ticks: u64::MAX }` meets `Coalesce`.
    pub fn flush_batched(&mut self) -> Result<usize> {
        self.drain_windows(true)
    }

    fn drain_windows(&mut self, flush: bool) -> Result<usize> {
        self.check_journal()?;
        let mut served = 0;
        loop {
            let oldest_age = self
                .queue
                .front()
                .map(|r| self.now_tick.saturating_sub(r.arrival_tick));
            let w = if flush {
                self.queue.len()
            } else {
                self.planner.window_size_at(self.queue.len(), oldest_age)
            };
            if w == 0 {
                // Flush a carried-over plan even when no window opens —
                // its samples are already removed, so its poison must
                // still be replayed (and its requests counted).
                if self.carryover.is_some() {
                    served += self.execute_window(Vec::new())?;
                }
                break;
            }
            let window: Vec<UnlearnRequest> = self.queue.drain(..w).collect();
            let n = self.execute_window(window)?;
            served += n;
            if n == 0 && self.carryover.is_some() {
                // Battery-starved: the window's plan is parked; draining
                // further windows would only park more unfunded work.
                break;
            }
        }
        Ok(served)
    }

    /// Battery admission for a window's merged plan: cost each lineage's
    /// resolved chain (the true coalesced replay, one read-only resolver
    /// pass) and keep the affordable prefix. Splitting happens at lineage
    /// granularity — requests are never dropped, their unfunded lineage
    /// work is deferred instead.
    fn admit(&self, plan: &mut BatchPlan) -> Admission {
        let Some(b) = self.battery.as_ref().filter(|b| !b.mains()) else {
            return Admission::Granted { reserve_j: 0.0 };
        };
        let epochs = self.engine.cfg.epochs_per_round;
        let costs: Vec<f64> = self
            .engine
            .plan_lineage_rsn(plan)
            .into_iter()
            .map(|rsn| self.energy.retrain_joules(rsn, epochs))
            .collect();
        let mut reserve_j = 0.0;
        let mut take = 0;
        for &c in &costs {
            if b.can_cover(reserve_j + c) {
                reserve_j += c;
                take += 1;
            } else {
                break;
            }
        }
        if take == plan.lineages.len() {
            Admission::Granted { reserve_j }
        } else if take == 0 {
            Admission::Starved { probe_j: costs.first().copied().unwrap_or(0.0) }
        } else {
            let deferred = plan.lineages.split_off(take);
            Admission::Split {
                defer: BatchPlan { lineages: deferred, requests: 0 },
                reserve_j,
            }
        }
    }

    /// Plan (merging any carried-over poison), admit against the battery,
    /// execute, and account one batch window. Unaffordable lineages — or
    /// the whole plan, on an engine error — are stashed for a later
    /// window with the energy reservation released; the requests are NOT
    /// re-queued, since re-collecting them would remove additional,
    /// never-requested samples. Returns the number of requests served.
    fn execute_window(&mut self, window: Vec<UnlearnRequest>) -> Result<usize> {
        let drained = window.len() as u64;
        let mut metas: Vec<ReqMeta> = Vec::with_capacity(window.len());
        if let Some((_, prev_metas)) = &self.carryover {
            // Carried-over requests arrived first; receipts keep order.
            metas.extend(prev_metas.iter().copied());
        }
        metas.extend(window.iter().map(|r| ReqMeta {
            user: r.user.0,
            round: r.round,
            arrival_tick: r.arrival_tick,
        }));
        let mut plan = self.planner.plan(&mut self.engine, &window);
        if let Some((prev_plan, _)) = self.carryover.take() {
            plan.merge(prev_plan);
        }

        let admission = self.admit(&mut plan);
        let (reserve_j, defer) = match admission {
            Admission::Granted { reserve_j } => (reserve_j, None),
            Admission::Split { defer, reserve_j } => (reserve_j, Some(defer)),
            Admission::Starved { probe_j } => {
                let fresh_episode = !self.head_deferral_logged;
                if fresh_episode {
                    self.head_deferral_logged = true;
                    // Record the episode's brownout (the refused draw).
                    if let Some(b) = &mut self.battery {
                        let _ = b.draw(probe_j);
                    }
                    self.batch_log.push(BatchReport {
                        requests: 0,
                        rsn: 0,
                        lineages_retrained: 0,
                        retrains_coalesced: 0,
                        oldest_queued_ticks: 0,
                        est_seconds: 0.0,
                        est_joules: probe_j,
                        deferred: true,
                    });
                }
                self.carryover = Some((plan, metas));
                self.emit(|svc| {
                    Event::Window(Box::new(WindowRec {
                        drained,
                        store_ops: svc.engine.take_tape(),
                        battery: svc.battery_post(),
                        metrics: svc.metrics_post(),
                        latency: vec![],
                        report: if fresh_episode {
                            Some(batch_rec_of(svc.batch_log.last().expect("just pushed")))
                        } else {
                            None
                        },
                        carryover: carryover_rec_of(&svc.carryover),
                        head_deferral_logged: svc.head_deferral_logged,
                        policy_state: svc.engine.store().policy_state(),
                    }))
                });
                return Ok(0);
            }
        };

        if let Some(b) = &mut self.battery {
            let drawn = b.draw(reserve_j);
            debug_assert!(drawn, "admission sized the reservation to the charge");
        }

        let coalesced = plan.coalesced_retrains();
        let window_requests = plan.requests;
        debug_assert_eq!(window_requests, metas.len(), "one meta per merged request");
        let outcome = match self.engine.execute_plan(&plan) {
            Ok(outcome) => outcome,
            Err(e) => {
                if let Some(b) = &mut self.battery {
                    b.refund(reserve_j);
                }
                // Re-join the deferred share so nothing is stranded.
                if let Some(d) = defer {
                    plan.merge(d);
                }
                self.carryover = Some((plan, metas));
                // The partially executed plan's store mutations are real:
                // frame them so recovery lands on this exact state.
                self.emit(|svc| {
                    Event::Window(Box::new(WindowRec {
                        drained,
                        store_ops: svc.engine.take_tape(),
                        battery: svc.battery_post(),
                        metrics: svc.metrics_post(),
                        latency: vec![],
                        report: None,
                        carryover: carryover_rec_of(&svc.carryover),
                        head_deferral_logged: svc.head_deferral_logged,
                        policy_state: svc.engine.store().policy_state(),
                    }))
                });
                return Err(e);
            }
        };
        // The executed share serves (and accounts) the window's requests;
        // any battery-deferred lineage share replays later via carryover.
        if let Some(d) = defer {
            self.carryover = Some((d, Vec::new()));
        }
        self.engine.metrics.record_requests(window_requests as u64, outcome.rsn);
        self.engine.metrics.batches += 1;
        self.engine.metrics.batched_requests += window_requests as u64;
        self.engine.metrics.retrains_coalesced += coalesced;

        let slo = self.planner.policy.slo();
        let mut oldest_queued = 0u64;
        for m in &metas {
            let queued_ticks = self.now_tick.saturating_sub(m.arrival_tick);
            oldest_queued = oldest_queued.max(queued_ticks);
            self.engine.metrics.record_latency(LatencyReceipt {
                user: m.user,
                round: m.round,
                queued_ticks,
                slo_met: slo.map_or(true, |s| queued_ticks <= s),
            });
        }

        let est_seconds = self
            .engine
            .cfg
            .model
            .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
        let est_joules = self
            .energy
            .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
        if let Some(b) = &mut self.battery {
            b.settle(est_joules, reserve_j);
        }
        self.batch_log.push(BatchReport {
            requests: window_requests,
            rsn: outcome.rsn,
            lineages_retrained: outcome.lineages_retrained,
            retrains_coalesced: coalesced,
            oldest_queued_ticks: oldest_queued,
            est_seconds,
            est_joules,
            deferred: false,
        });
        self.head_deferral_logged = false;
        self.emit(|svc| {
            let receipts = &svc.engine.metrics.latency;
            let latency = receipts[receipts.len() - window_requests..]
                .iter()
                .map(|l| LatencyRecord {
                    user: l.user,
                    round: l.round,
                    queued_ticks: l.queued_ticks,
                    slo_met: l.slo_met,
                })
                .collect();
            Event::Window(Box::new(WindowRec {
                drained,
                store_ops: svc.engine.take_tape(),
                battery: svc.battery_post(),
                metrics: svc.metrics_post(),
                latency,
                report: Some(batch_rec_of(svc.batch_log.last().expect("just pushed"))),
                carryover: carryover_rec_of(&svc.carryover),
                head_deferral_logged: false,
                policy_state: svc.engine.store().policy_state(),
            }))
        });
        Ok(window_requests)
    }

    /// Advance harvest time (satellite mode).
    pub fn harvest(&mut self, secs: f64) {
        if let Some(b) = &mut self.battery {
            b.harvest(secs);
            let battery = Some(BatteryPost { charge_j: b.charge_j, brownouts: b.brownouts });
            self.emit(|_| Event::Harvest { battery });
        }
    }

    // -- Durability --------------------------------------------------------

    /// Attach a durability journal, first recovering whatever state the
    /// backing filesystem holds (snapshot + write-ahead log tail, torn
    /// writes repaired). Call this on a **freshly built** service — same
    /// system variant, batch planner, and battery profile as the crashed
    /// instance — before driving it; recovery then reconstructs the
    /// pre-crash state receipt-identically and arms log-before-ack
    /// journaling for everything that follows.
    pub fn attach_durability(&mut self, d: Durability) -> Result<RecoveryReport> {
        if d.mode == DurabilityMode::Off {
            return Ok(RecoveryReport::default());
        }
        let (log, report) = recovery::recover(self, d.fs)
            .map_err(|e| anyhow::anyhow!("durability recovery: {e}"))?;
        self.engine.set_taping(true);
        self.journal =
            Some(Journal { log, mode: d.mode, compact_every: d.compact_every, err: None });
        Ok(report)
    }

    /// The attached durability mode ([`DurabilityMode::Off`] when none).
    pub fn durability_mode(&self) -> DurabilityMode {
        self.journal.as_ref().map_or(DurabilityMode::Off, |j| j.mode)
    }

    /// First journal append/compaction failure, if any (surfaced as an
    /// error by the next fallible entry point).
    pub fn durability_error(&self) -> Option<&str> {
        self.journal.as_ref().and_then(|j| j.err.as_deref())
    }

    /// Events currently in the log tail (0 without a journal).
    pub fn journal_events(&self) -> u64 {
        self.journal.as_ref().map_or(0, |j| j.log.events_in_log())
    }

    /// Write a snapshot of the full service state and truncate the log
    /// prefix it materializes (the compactor; also triggered automatically
    /// every `compact_every` events). A failed compaction poisons the
    /// journal: the in-memory log position can no longer be trusted to
    /// match the committed manifest, so further acks would lie.
    pub fn compact_now(&mut self) -> Result<()> {
        let Some(mut j) = self.journal.take() else {
            return Ok(());
        };
        if let Some(e) = &j.err {
            let msg = e.clone();
            self.journal = Some(j);
            return Err(anyhow::anyhow!("durability journal failed earlier: {msg}"));
        }
        let image = self.capture_image();
        let bytes = image.encode(j.mode.spills());
        let res = j.log.compact(&bytes);
        if let Err(e) = &res {
            j.err = Some(format!("compaction: {e}"));
        }
        self.journal = Some(j);
        res.map_err(|e| anyhow::anyhow!("compaction: {e}"))
    }

    /// Record the first durability failure; everything after it is
    /// refused (appends stop, fallible entry points error) — nothing is
    /// silently un-durable.
    fn poison_journal(&mut self, msg: &str) {
        if let Some(j) = self.journal.as_mut() {
            if j.err.is_none() {
                j.err = Some(msg.to_string());
            }
        }
    }

    fn check_journal(&self) -> Result<()> {
        match self.durability_error() {
            Some(e) => Err(anyhow::anyhow!("durability journal failed earlier: {e}")),
            None => Ok(()),
        }
    }

    /// Build-and-append an event; the builder only runs when a journal is
    /// attached, so `durability = off` pays nothing.
    fn emit(&mut self, build: impl FnOnce(&mut Self) -> Event) {
        match &self.journal {
            // A poisoned journal must not keep appending: a failed append
            // can leave a torn frame mid-file, and frames written after it
            // would be invisible to recovery (scan stops at the tear) —
            // acked-but-unrecoverable, the one thing the log must never do.
            None => return,
            Some(j) if j.err.is_some() => return,
            Some(_) => {}
        }
        let ev = build(self);
        self.append_event(ev);
    }

    fn append_event(&mut self, ev: Event) {
        let due = {
            let Some(j) = self.journal.as_mut() else { return };
            let payload = ev.encode(j.log.next_seq(), j.mode.spills());
            if let Err(e) = j.log.append_payload(&payload) {
                if j.err.is_none() {
                    j.err = Some(e.to_string());
                }
                return;
            }
            j.compact_every > 0 && j.log.events_in_log() >= j.compact_every
        };
        if due {
            // compact_now stashes its own error into the journal.
            let _ = self.compact_now();
        }
    }

    /// Absolute post-transition metric record.
    fn metrics_post(&self) -> MetricsPost {
        let m = &self.engine.metrics;
        MetricsPost {
            warm_retrains: m.warm_retrains,
            scratch_retrains: m.scratch_retrains,
            lineages_retrained: m.lineages_retrained,
            prunes: m.prunes,
            energy_joules: m.energy_joules,
            ckpts_stored: m.ckpts_stored,
            ckpts_replaced: m.ckpts_replaced,
            ckpts_rejected: m.ckpts_rejected,
            ckpts_invalidated: m.ckpts_invalidated,
            batches: m.batches,
            batched_requests: m.batched_requests,
            retrains_coalesced: m.retrains_coalesced,
            round_slots: m.rsn_by_round.len() as u64,
            rsn_last: m.rsn_by_round.last().copied().unwrap_or(0),
            requests_last: m.requests_by_round.last().copied().unwrap_or(0),
        }
    }

    fn battery_post(&self) -> Option<BatteryPost> {
        self.battery
            .as_ref()
            .map(|b| BatteryPost { charge_j: b.charge_j, brownouts: b.brownouts })
    }

    fn apply_metrics_post(&mut self, p: &MetricsPost) {
        let m = &mut self.engine.metrics;
        m.warm_retrains = p.warm_retrains;
        m.scratch_retrains = p.scratch_retrains;
        m.lineages_retrained = p.lineages_retrained;
        m.prunes = p.prunes;
        m.energy_joules = p.energy_joules;
        m.ckpts_stored = p.ckpts_stored;
        m.ckpts_replaced = p.ckpts_replaced;
        m.ckpts_rejected = p.ckpts_rejected;
        m.ckpts_invalidated = p.ckpts_invalidated;
        m.batches = p.batches;
        m.batched_requests = p.batched_requests;
        m.retrains_coalesced = p.retrains_coalesced;
        while (m.rsn_by_round.len() as u64) < p.round_slots {
            m.rsn_by_round.push(0);
        }
        while (m.requests_by_round.len() as u64) < p.round_slots {
            m.requests_by_round.push(0);
        }
        if p.round_slots > 0 {
            if let Some(last) = m.rsn_by_round.last_mut() {
                *last = p.rsn_last;
            }
            if let Some(last) = m.requests_by_round.last_mut() {
                *last = p.requests_last;
            }
        }
    }

    fn apply_battery_post(&mut self, post: &Option<BatteryPost>) {
        if let (Some(b), Some(p)) = (self.battery.as_mut(), post) {
            b.charge_j = p.charge_j;
            b.brownouts = p.brownouts;
        }
    }

    /// Replay one journaled transition (crash recovery). Mirrors exactly
    /// what the live transition mutated: queue pops re-remove their own
    /// samples through the real proportional-split code, store admissions
    /// re-apply their recorded victim sets, scalars restore from absolute
    /// post-values.
    pub(crate) fn replay_event(&mut self, ev: &Event) {
        match ev {
            Event::Advance { ticks } => {
                self.now_tick = self.now_tick.saturating_add(*ticks);
            }
            Event::Harvest { battery } => self.apply_battery_post(battery),
            Event::Submit(rec) => self.queue.push_back(req_from_rec(rec)),
            Event::Round(rec) => {
                self.now_tick = self.now_tick.saturating_add(1);
                self.engine.replay_round(rec);
                self.apply_metrics_post(&rec.metrics);
            }
            Event::Serve(rec) => {
                if rec.popped {
                    if let Some(req) = self.queue.pop_front() {
                        for (b, n) in &req.parts {
                            self.engine.replay_remove(b.0, *n);
                        }
                    }
                }
                self.engine.replay_store_ops(&rec.store_ops);
                self.apply_metrics_post(&rec.metrics);
                if let Some(l) = &rec.latency {
                    self.engine.metrics.record_latency(LatencyReceipt {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    });
                }
                self.log.push(svc_from_rec(&rec.report));
                self.apply_battery_post(&rec.battery);
                self.head_deferral_logged = rec.head_deferral_logged;
                self.engine.store_mut().restore_policy_state(&rec.policy_state);
            }
            Event::Window(rec) => {
                let n = (rec.drained as usize).min(self.queue.len());
                let reqs: Vec<UnlearnRequest> = self.queue.drain(..n).collect();
                for req in &reqs {
                    for (b, cnt) in &req.parts {
                        self.engine.replay_remove(b.0, *cnt);
                    }
                }
                self.engine.replay_store_ops(&rec.store_ops);
                self.apply_metrics_post(&rec.metrics);
                for l in &rec.latency {
                    self.engine.metrics.record_latency(LatencyReceipt {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    });
                }
                if let Some(b) = &rec.report {
                    self.batch_log.push(batch_from_rec(b));
                }
                self.carryover = carryover_from_rec(&rec.carryover);
                self.apply_battery_post(&rec.battery);
                self.head_deferral_logged = rec.head_deferral_logged;
                self.engine.store_mut().restore_policy_state(&rec.policy_state);
            }
        }
    }

    /// Materialize the full service state (the compactor's snapshot).
    pub(crate) fn capture_image(&self) -> StateImage {
        let m = &self.engine.metrics;
        StateImage {
            now_tick: self.now_tick,
            head_deferral_logged: self.head_deferral_logged,
            queue: self.queue.iter().map(req_rec_of).collect(),
            carryover: carryover_rec_of(&self.carryover),
            battery: self.battery.as_ref().map(|b| BatteryImage {
                capacity_j: b.capacity_j,
                charge_j: b.charge_j,
                harvest_watts: b.harvest_watts,
                brownouts: b.brownouts,
            }),
            svc_log: self.log.iter().map(svc_rec_of).collect(),
            batch_log: self.batch_log.iter().map(batch_rec_of).collect(),
            round: self.engine.round(),
            rounds: self.engine.capture_rounds(),
            partitioner_state: self.engine.partitioner_state(),
            store: self.engine.capture_store_image(),
            metrics: MetricsImage {
                rsn_by_round: m.rsn_by_round.clone(),
                requests_by_round: m.requests_by_round.clone(),
                warm_retrains: m.warm_retrains,
                scratch_retrains: m.scratch_retrains,
                lineages_retrained: m.lineages_retrained,
                energy_joules: m.energy_joules,
                prunes: m.prunes,
                ckpts_stored: m.ckpts_stored,
                ckpts_replaced: m.ckpts_replaced,
                ckpts_rejected: m.ckpts_rejected,
                ckpts_invalidated: m.ckpts_invalidated,
                batches: m.batches,
                batched_requests: m.batched_requests,
                retrains_coalesced: m.retrains_coalesced,
                latency: m
                    .latency
                    .iter()
                    .map(|l| LatencyRecord {
                        user: l.user,
                        round: l.round,
                        queued_ticks: l.queued_ticks,
                        slo_met: l.slo_met,
                    })
                    .collect(),
                accuracy_by_round: m.accuracy_by_round.clone(),
            },
        }
    }

    /// Restore from a compaction snapshot (recovery, before log replay).
    pub(crate) fn restore_image(&mut self, img: &StateImage) {
        self.now_tick = img.now_tick;
        self.head_deferral_logged = img.head_deferral_logged;
        self.queue = img.queue.iter().map(req_from_rec).collect();
        self.carryover = carryover_from_rec(&img.carryover);
        if let Some(bi) = &img.battery {
            self.battery = Some(Battery {
                capacity_j: bi.capacity_j,
                charge_j: bi.charge_j,
                harvest_watts: bi.harvest_watts,
                brownouts: bi.brownouts,
            });
        }
        self.log = img.svc_log.iter().map(svc_from_rec).collect();
        self.batch_log = img.batch_log.iter().map(batch_from_rec).collect();
        self.engine.restore_rounds(&img.rounds);
        self.engine.set_round(img.round);
        self.engine.restore_partitioner_state(&img.partitioner_state);
        self.engine.restore_store_image(&img.store);
        self.engine.metrics = RunMetrics {
            rsn_by_round: img.metrics.rsn_by_round.clone(),
            requests_by_round: img.metrics.requests_by_round.clone(),
            warm_retrains: img.metrics.warm_retrains,
            scratch_retrains: img.metrics.scratch_retrains,
            lineages_retrained: img.metrics.lineages_retrained,
            energy_joules: img.metrics.energy_joules,
            prunes: img.metrics.prunes,
            ckpts_stored: img.metrics.ckpts_stored,
            ckpts_replaced: img.metrics.ckpts_replaced,
            ckpts_rejected: img.metrics.ckpts_rejected,
            ckpts_invalidated: img.metrics.ckpts_invalidated,
            batches: img.metrics.batches,
            batched_requests: img.metrics.batched_requests,
            retrains_coalesced: img.metrics.retrains_coalesced,
            latency: img
                .metrics
                .latency
                .iter()
                .map(|l| LatencyReceipt {
                    user: l.user,
                    round: l.round,
                    queued_ticks: l.queued_ticks,
                    slo_met: l.slo_met,
                })
                .collect(),
            accuracy_by_round: img.metrics.accuracy_by_round.clone(),
        };
    }

    /// Deterministic, comparison-friendly digest of the full service
    /// state: clock, queue, carryover, battery, lineage totals, store
    /// layout/stats/bytes, receipt logs, and the metrics JSON. Two
    /// services with equal receipts are observably identical — this is
    /// what the kill-point crash tests compare between a recovered
    /// instance and the uninterrupted in-memory run.
    pub fn state_receipt(&self) -> Json {
        let queue = Json::Arr(
            self.queue
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("user", u64::from(r.user.0))
                        .set("round", u64::from(r.round))
                        .set("arrival", r.arrival_tick)
                        .set(
                            "parts",
                            Json::Arr(
                                r.parts
                                    .iter()
                                    .map(|(b, n)| Json::Arr(vec![Json::from(b.0), Json::from(*n)]))
                                    .collect(),
                            ),
                        )
                })
                .collect(),
        );
        let carryover = match &self.carryover {
            None => Json::Null,
            Some((plan, metas)) => Json::obj()
                .set("requests", plan.requests)
                .set(
                    "lineages",
                    Json::Arr(
                        plan.lineages
                            .iter()
                            .map(|lp| {
                                Json::obj()
                                    .set("lineage", lp.lineage)
                                    .set(
                                        "segments",
                                        lp.segments.iter().map(|s| *s as u64).collect::<Vec<u64>>(),
                                    )
                                    .set("touching", lp.requests_touching)
                            })
                            .collect(),
                    ),
                )
                .set(
                    "metas",
                    Json::Arr(
                        metas
                            .iter()
                            .map(|m| {
                                Json::Arr(vec![
                                    Json::from(u64::from(m.user)),
                                    Json::from(u64::from(m.round)),
                                    Json::from(m.arrival_tick),
                                ])
                            })
                            .collect(),
                    ),
                ),
        };
        let battery = match &self.battery {
            None => Json::Null,
            Some(b) => Json::obj()
                .set("charge_j", b.charge_j)
                .set("capacity_j", b.capacity_j)
                .set("brownouts", b.brownouts),
        };
        let lineages = Json::Arr(
            (0..self.engine.lineages().len())
                .map(|l| {
                    let lin = self.engine.lineages().get(l);
                    Json::obj()
                        .set("total", lin.total_samples())
                        .set("segments", u64::from(lin.segment_count()))
                })
                .collect(),
        );
        let store = self.engine.store();
        let stats = store.stats();
        let resident = Json::Arr(
            store
                .slot_entries()
                .map(|(slot, c)| {
                    Json::Arr(vec![
                        Json::from(slot),
                        Json::from(c.id.0),
                        Json::from(c.lineage),
                        Json::from(u64::from(c.covered_segments)),
                        Json::from(c.size_bytes),
                    ])
                })
                .collect(),
        );
        let svc_log = Json::Arr(
            self.log
                .iter()
                .map(|r| {
                    Json::obj()
                        .set("user", u64::from(r.user))
                        .set("round", u64::from(r.round))
                        .set("rsn", r.rsn)
                        .set("lineages", r.lineages_retrained)
                        .set("est_seconds", r.est_seconds)
                        .set("est_joules", r.est_joules)
                        .set("deferred", r.deferred)
                })
                .collect(),
        );
        let batch_log = Json::Arr(
            self.batch_log
                .iter()
                .map(|b| {
                    Json::obj()
                        .set("requests", b.requests)
                        .set("rsn", b.rsn)
                        .set("lineages", b.lineages_retrained)
                        .set("coalesced", b.retrains_coalesced)
                        .set("oldest", b.oldest_queued_ticks)
                        .set("est_seconds", b.est_seconds)
                        .set("est_joules", b.est_joules)
                        .set("deferred", b.deferred)
                })
                .collect(),
        );
        Json::obj()
            .set("now", self.now_tick)
            .set("head_deferral_logged", self.head_deferral_logged)
            .set("queue", queue)
            .set("carryover", carryover)
            .set("battery", battery)
            .set("lineages", lineages)
            .set(
                "store",
                Json::obj()
                    .set("occupied", store.occupied())
                    .set("stored_bytes", store.stored_bytes())
                    .set("next_id", store.next_id_peek())
                    .set("stored", stats.stored)
                    .set("replaced", stats.replaced)
                    .set("rejected", stats.rejected)
                    .set("invalidated", stats.invalidated)
                    .set("resident", resident),
            )
            .set("svc_log", svc_log)
            .set("batch_log", batch_log)
            .set("engine_round", u64::from(self.engine.round()))
            .set("metrics", self.engine.metrics.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::system::SystemVariant;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::PopulationConfig;
    use crate::data::trace::{RequestTrace, TraceConfig};
    use crate::sim::device::AI_CUBESAT;
    use crate::unlearning::batch::BatchPolicy;

    fn setup() -> (UnlearningService, EdgePopulation, RequestTrace) {
        let cfg = ExperimentConfig {
            users: 20,
            rounds: 4,
            shards: 4,
            ..Default::default()
        };
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(8_000),
            users: cfg.users,
            rounds: cfg.rounds,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed: 11,
        });
        let trace = RequestTrace::generate(&pop, &TraceConfig::paper_default(12).with_prob(0.4));
        let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        (UnlearningService::new(engine), pop, trace)
    }

    #[test]
    fn fcfs_serves_all_on_mains() {
        let (mut svc, pop, trace) = setup();
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.log.iter().filter(|r| !r.deferred).count(), submitted);
        assert!(svc.engine().metrics.total_rsn() > 0);
        // Every served request left a latency receipt; same-tick service
        // means zero queueing delay under this driver.
        assert_eq!(svc.engine().metrics.latency.len(), submitted);
        assert_eq!(svc.engine().metrics.slo_violations(), 0);
    }

    #[test]
    fn batched_serves_all_and_coalesces() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain_batched().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        let m = &svc.engine().metrics;
        assert_eq!(m.total_requests(), submitted as u64);
        assert_eq!(m.batched_requests, submitted as u64);
        // One window per round with pending work.
        assert!(m.batches >= 1 && m.batches <= 4, "batches {}", m.batches);
        let batch_requests: usize = svc.batch_log.iter().map(|b| b.requests).sum();
        assert_eq!(batch_requests, submitted);
        assert_eq!(m.latency.len(), submitted);
    }

    #[test]
    fn deadline_holds_then_closes_at_slo() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(
            BatchPolicy::Deadline { slo_ticks: 2 },
            0,
        ));
        svc.ingest_round(&pop).unwrap();
        svc.ingest_round(&pop).unwrap();
        let mut submitted = 0;
        for req in trace.at(1).iter().chain(trace.at(2)) {
            svc.submit(req.clone());
            submitted += 1;
        }
        assert!(submitted >= 2, "trace produced too few requests");
        // Age 0 and 1: the planner holds the whole queue.
        assert_eq!(svc.drain_batched().unwrap(), 0);
        svc.advance(1);
        assert_eq!(svc.drain_batched().unwrap(), 0);
        assert_eq!(svc.pending(), submitted);
        // Age 2 == SLO: the window closes over everything queued.
        svc.advance(1);
        assert_eq!(svc.drain_batched().unwrap(), submitted);
        assert_eq!(svc.pending(), 0);
        let m = &svc.engine().metrics;
        assert_eq!(m.batches, 1, "one coalesced window at the deadline");
        assert_eq!(m.latency.len(), submitted);
        assert!(m.latency.iter().all(|r| r.queued_ticks == 2 && r.slo_met));
    }

    #[test]
    fn flush_serves_infinite_slo_queue() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(
            BatchPolicy::Deadline { slo_ticks: u64::MAX },
            0,
        ));
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            assert_eq!(svc.drain_batched().unwrap(), 0, "infinite SLO never closes");
        }
        assert_eq!(svc.pending(), submitted);
        // Flush: the whole queue coalesces into one window (the Coalesce
        // degenerate point).
        assert_eq!(svc.flush_batched().unwrap(), submitted);
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.engine().metrics.batches, 1);
    }

    #[test]
    fn battery_defers_until_harvest() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5; // almost empty
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 1, "request should be deferred");
        assert!(svc.log.last().unwrap().deferred);
        // Harvest a lot, then it goes through.
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
    }

    #[test]
    fn deferral_logged_once_per_episode() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5;
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        // Polling a starving queue repeatedly must not inflate the count.
        for _ in 0..5 {
            svc.drain().unwrap();
        }
        assert_eq!(svc.log.iter().filter(|r| r.deferred).count(), 1);
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
        // A fresh starvation episode logs again.
        let req2 = trace
            .at(2)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(3).first().cloned().expect("trace has requests"));
        if let Some(b) = &mut svc.battery {
            b.charge_j = 0.0;
        }
        svc.submit(req2);
        for _ in 0..3 {
            svc.drain().unwrap();
        }
        assert_eq!(svc.log.iter().filter(|r| r.deferred).count(), 2);
    }

    #[test]
    fn batched_battery_defers_and_recovers() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5;
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery)
            .with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
        // Two rounds ingested so every submitted request poisons live data.
        svc.ingest_round(&pop).unwrap();
        svc.ingest_round(&pop).unwrap();
        let mut submitted = 0;
        for req in trace.at(1).iter().chain(trace.at(2)).take(4) {
            svc.submit(req.clone());
            submitted += 1;
        }
        assert!(submitted > 0, "trace produced no requests");
        for _ in 0..4 {
            svc.drain_batched().unwrap();
        }
        // Merged-cost admission: the plan is collected (samples removed,
        // queue empty) but parked unfunded — requests are not yet served.
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.carryover_requests(), submitted);
        assert_eq!(svc.engine().metrics.total_requests(), 0);
        assert_eq!(svc.batch_log.iter().filter(|b| b.deferred).count(), 1);
        svc.harvest(1e7);
        svc.drain_batched().unwrap();
        assert_eq!(svc.carryover_requests(), 0);
        assert_eq!(svc.engine().metrics.total_requests(), submitted as u64);
        let served: usize =
            svc.batch_log.iter().filter(|b| !b.deferred).map(|b| b.requests).sum();
        assert_eq!(served, submitted);
        // Battery never exceeds capacity after refunds.
        let b = svc.battery().unwrap();
        assert!(b.charge_j <= b.capacity_j);
    }
}
