//! Queue-fronted unlearning service.
//!
//! Wraps an [`Engine`] with the request lifecycle a real edge deployment
//! needs: queueing, per-request and per-batch receipts (RSN, latency
//! estimate, energy), optional battery gating (satellite mode: defer
//! retraining when the state of charge cannot cover it), and a service log.
//!
//! Two drain modes:
//! * [`UnlearningService::drain`] — strictly FCFS, one retrain pass per
//!   request (the paper's service model).
//! * [`UnlearningService::drain_batched`] — windows of queued requests are
//!   merged by the configured [`BatchPlanner`], so a lineage poisoned by R
//!   requests in one window replays once instead of R times, and
//!   independent lineages retrain in parallel when the backend allows.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::data::dataset::EdgePopulation;
use crate::data::trace::UnlearnRequest;
use crate::energy::EnergyModel;
use crate::sim::Battery;
use crate::unlearning::batch::BatchPlanner;

/// Receipt for one served unlearning request.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub user: u32,
    pub round: u32,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Estimated device seconds for the retrain (profile-based).
    pub est_seconds: f64,
    /// Estimated joules for the retrain.
    pub est_joules: f64,
    /// Deferred because the battery could not cover the retrain.
    pub deferred: bool,
}

/// Receipt for one served (or deferred) batch window.
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Requests merged into this window (0 for a deferral receipt).
    pub requests: usize,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Per-request lineage retrains avoided by coalescing this window.
    pub retrains_coalesced: u64,
    /// Estimated device seconds for the window's retraining.
    pub est_seconds: f64,
    /// Estimated joules for the window's retraining.
    pub est_joules: f64,
    /// Deferred because the battery could not cover even one request.
    pub deferred: bool,
}

/// Queue-fronted unlearning service over an engine.
pub struct UnlearningService {
    engine: Engine,
    queue: VecDeque<UnlearnRequest>,
    energy: EnergyModel,
    battery: Option<Battery>,
    planner: BatchPlanner,
    /// One deferral receipt per episode: set when the queue head defers,
    /// cleared when anything is served (or the head changes by serving).
    head_deferral_logged: bool,
    /// Poison collected for a window whose execution failed: its samples
    /// are already removed from the lineages, so the plan is carried over
    /// and merged into the next executed window (exactness is preserved
    /// across engine errors).
    carryover: Option<crate::unlearning::batch::BatchPlan>,
    /// Per-request receipts (FCFS drains).
    pub log: Vec<ServiceReport>,
    /// Per-window receipts (batched drains).
    pub batch_log: Vec<BatchReport>,
}

impl UnlearningService {
    pub fn new(engine: Engine) -> Self {
        let energy = EnergyModel::for_model(&engine.cfg.model);
        let planner = BatchPlanner::from_config(&engine.cfg);
        Self {
            engine,
            queue: VecDeque::new(),
            energy,
            battery: None,
            planner,
            head_deferral_logged: false,
            carryover: None,
            log: vec![],
            batch_log: vec![],
        }
    }

    /// Enable battery gating (energy-harvesting deployments).
    pub fn with_battery(mut self, battery: Battery) -> Self {
        self.battery = Some(battery);
        self
    }

    /// Override the batch planner (policy + window) from the config's.
    pub fn with_planner(mut self, planner: BatchPlanner) -> Self {
        self.planner = planner;
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn battery(&self) -> Option<&Battery> {
        self.battery.as_ref()
    }

    pub fn planner(&self) -> &BatchPlanner {
        &self.planner
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run one training round (new data arrival).
    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        self.engine.run_round(pop)?;
        Ok(())
    }

    /// Enqueue a request (FCFS order preserved).
    pub fn submit(&mut self, req: UnlearnRequest) {
        self.queue.push_back(req);
    }

    /// Conservative energy pre-estimate for the first `w` queued requests:
    /// replaying every requested sample.
    fn window_hint_joules(&self, w: usize) -> f64 {
        let rsn_hint: u64 = self.queue.iter().take(w).map(|r| r.total_samples()).sum();
        self.energy.retrain_joules(rsn_hint, self.engine.cfg.epochs_per_round)
    }

    /// Log at most one deferral receipt per episode (a stuck head polled
    /// by many drain calls previously produced one receipt per call,
    /// inflating deferral counts in the satellite scenario).
    fn log_deferral(&mut self, user: u32, round: u32, est_joules: f64) {
        if self.head_deferral_logged {
            return;
        }
        self.head_deferral_logged = true;
        self.log.push(ServiceReport {
            user,
            round,
            rsn: 0,
            lineages_retrained: 0,
            est_seconds: 0.0,
            est_joules,
            deferred: true,
        });
    }

    /// Serve queued requests strictly FCFS. With a battery, a request
    /// whose estimated energy exceeds the charge is deferred (stays at the
    /// queue head) until `harvest` restores enough charge.
    pub fn drain(&mut self) -> Result<usize> {
        // A plan carried over from a failed batched window must not be
        // stranded when the caller switches to FCFS drains: flush it
        // first (its samples are already removed from the lineages).
        let mut served = if self.carryover.is_some() {
            self.execute_window(Vec::new(), 0.0)?
        } else {
            0
        };
        while let Some(req) = self.queue.front().cloned() {
            // Conservative pre-estimate: replaying all requested samples.
            let est_j_hint = self.window_hint_joules(1);
            let starved = match &self.battery {
                Some(b) => !b.can_cover(est_j_hint),
                None => false,
            };
            if starved {
                // One brownout per starvation episode (a refused draw),
                // not one per drain() poll of the same stuck head.
                if !self.head_deferral_logged {
                    if let Some(b) = &mut self.battery {
                        let _ = b.draw(est_j_hint);
                    }
                }
                self.log_deferral(req.user.0, req.round, est_j_hint);
                break; // FCFS: don't skip ahead of the deferred head.
            }
            if let Some(b) = &mut self.battery {
                let drawn = b.draw(est_j_hint);
                debug_assert!(drawn, "covered by the can_cover probe above");
            }
            let outcome = self.engine.process_request(&req)?;
            let est_seconds = self
                .engine
                .cfg
                .model
                .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
            let est_joules = self
                .energy
                .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
            self.settle_energy(est_joules, est_j_hint);
            self.log.push(ServiceReport {
                user: req.user.0,
                round: req.round,
                rsn: outcome.rsn,
                lineages_retrained: outcome.lineages_retrained,
                est_seconds,
                est_joules,
                deferred: false,
            });
            self.queue.pop_front();
            self.head_deferral_logged = false;
            served += 1;
        }
        Ok(served)
    }

    /// Serve queued requests in coalesced windows per the configured
    /// [`BatchPlanner`]: each window's poison sets are merged so a lineage
    /// touched by R requests replays once instead of R times. Returns the
    /// number of requests served. With a battery, the window shrinks to
    /// the affordable prefix; when even one request is unaffordable the
    /// queue defers (one receipt per episode) until `harvest`.
    pub fn drain_batched(&mut self) -> Result<usize> {
        let mut served = 0;
        loop {
            let mut w = self.planner.window_size(self.queue.len());
            if w == 0 {
                // Flush a carried-over plan even when no new requests
                // arrive — its samples are already removed, so its poison
                // must still be replayed (and its requests counted).
                if self.carryover.is_some() {
                    served += self.execute_window(Vec::new(), 0.0)?;
                }
                break;
            }
            let mut hint_j = 0.0;
            if let Some(b) = &self.battery {
                // One forward pass over the queue finds the affordable
                // prefix (per-request hints are non-negative, so prefix
                // cost is monotone — no need to re-sum per candidate).
                let epochs = self.engine.cfg.epochs_per_round;
                let mut affordable = 0;
                let mut prefix = 0.0;
                for req in self.queue.iter().take(w) {
                    let next =
                        prefix + self.energy.retrain_joules(req.total_samples(), epochs);
                    if !b.can_cover(next) {
                        break;
                    }
                    prefix = next;
                    affordable += 1;
                }
                w = affordable;
                hint_j = prefix;
            }
            if self.battery.is_some() && w == 0 {
                let head_hint = self.window_hint_joules(1);
                if !self.head_deferral_logged {
                    self.head_deferral_logged = true;
                    // Record the episode's brownout (the refused draw),
                    // matching drain()'s per-episode accounting.
                    if let Some(b) = &mut self.battery {
                        let _ = b.draw(head_hint);
                    }
                    self.batch_log.push(BatchReport {
                        requests: 0,
                        rsn: 0,
                        lineages_retrained: 0,
                        retrains_coalesced: 0,
                        est_seconds: 0.0,
                        est_joules: head_hint,
                        deferred: true,
                    });
                }
                break;
            }
            if let Some(b) = &mut self.battery {
                let drawn = b.draw(hint_j);
                debug_assert!(drawn, "window was sized to the affordable prefix");
            }

            let window: Vec<UnlearnRequest> = self.queue.drain(..w).collect();
            served += self.execute_window(window, hint_j)?;
        }
        Ok(served)
    }

    /// Plan (merging any carried-over poison), execute, and account one
    /// batch window. On engine error the merged plan — samples already
    /// removed, request counts included — is stashed for a later window
    /// and the energy reservation is released; the requests are NOT
    /// re-queued, since re-collecting them would remove additional,
    /// never-requested samples. Returns the number of requests served.
    fn execute_window(&mut self, window: Vec<UnlearnRequest>, hint_j: f64) -> Result<usize> {
        let mut plan = self.planner.plan(&mut self.engine, &window);
        if let Some(prev) = self.carryover.take() {
            plan.merge(prev);
        }
        let coalesced = plan.coalesced_retrains();
        let window_requests = plan.requests;
        let outcome = match self.engine.execute_plan(&plan) {
            Ok(outcome) => outcome,
            Err(e) => {
                if let Some(b) = &mut self.battery {
                    b.refund(hint_j);
                }
                self.carryover = Some(plan);
                return Err(e);
            }
        };
        self.engine.metrics.record_requests(window_requests as u64, outcome.rsn);
        self.engine.metrics.batches += 1;
        self.engine.metrics.batched_requests += window_requests as u64;
        self.engine.metrics.retrains_coalesced += coalesced;

        let est_seconds = self
            .engine
            .cfg
            .model
            .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
        let est_joules = self
            .energy
            .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
        self.settle_energy(est_joules, hint_j);
        self.batch_log.push(BatchReport {
            requests: window_requests,
            rsn: outcome.rsn,
            lineages_retrained: outcome.lineages_retrained,
            retrains_coalesced: coalesced,
            est_seconds,
            est_joules,
            deferred: false,
        });
        self.head_deferral_logged = false;
        Ok(window_requests)
    }

    /// Settle the battery against the actual retrain cost: deduct the
    /// overrun beyond the reservation (the work already ran — no gating,
    /// no brownout), or refund the over-reserved part.
    fn settle_energy(&mut self, actual_joules: f64, reserved_joules: f64) {
        if let Some(b) = &mut self.battery {
            let delta = actual_joules - reserved_joules;
            if delta > 0.0 {
                b.deduct(delta);
            } else {
                b.refund(-delta);
            }
        }
    }

    /// Advance harvest time (satellite mode).
    pub fn harvest(&mut self, secs: f64) {
        if let Some(b) = &mut self.battery {
            b.harvest(secs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::system::SystemVariant;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::PopulationConfig;
    use crate::data::trace::{RequestTrace, TraceConfig};
    use crate::sim::device::AI_CUBESAT;
    use crate::unlearning::batch::BatchPolicy;

    fn setup() -> (UnlearningService, EdgePopulation, RequestTrace) {
        let cfg = ExperimentConfig {
            users: 20,
            rounds: 4,
            shards: 4,
            ..Default::default()
        };
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(8_000),
            users: cfg.users,
            rounds: cfg.rounds,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed: 11,
        });
        let trace = RequestTrace::generate(&pop, &TraceConfig::paper_default(12).with_prob(0.4));
        let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        (UnlearningService::new(engine), pop, trace)
    }

    #[test]
    fn fcfs_serves_all_on_mains() {
        let (mut svc, pop, trace) = setup();
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.log.iter().filter(|r| !r.deferred).count(), submitted);
        assert!(svc.engine().metrics.total_rsn() > 0);
    }

    #[test]
    fn batched_serves_all_and_coalesces() {
        let (mut svc, pop, trace) = setup();
        svc = svc.with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain_batched().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        let m = &svc.engine().metrics;
        assert_eq!(m.total_requests(), submitted as u64);
        assert_eq!(m.batched_requests, submitted as u64);
        // One window per round with pending work.
        assert!(m.batches >= 1 && m.batches <= 4, "batches {}", m.batches);
        let batch_requests: usize = svc.batch_log.iter().map(|b| b.requests).sum();
        assert_eq!(batch_requests, submitted);
    }

    #[test]
    fn battery_defers_until_harvest() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5; // almost empty
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 1, "request should be deferred");
        assert!(svc.log.last().unwrap().deferred);
        // Harvest a lot, then it goes through.
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
    }

    #[test]
    fn deferral_logged_once_per_episode() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5;
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        // Polling a starving queue repeatedly must not inflate the count.
        for _ in 0..5 {
            svc.drain().unwrap();
        }
        assert_eq!(svc.log.iter().filter(|r| r.deferred).count(), 1);
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
        // A fresh starvation episode logs again.
        let req2 = trace
            .at(2)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(3).first().cloned().expect("trace has requests"));
        if let Some(b) = &mut svc.battery {
            b.charge_j = 0.0;
        }
        svc.submit(req2);
        for _ in 0..3 {
            svc.drain().unwrap();
        }
        assert_eq!(svc.log.iter().filter(|r| r.deferred).count(), 2);
    }

    #[test]
    fn batched_battery_defers_and_recovers() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5;
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery)
            .with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
        svc.ingest_round(&pop).unwrap();
        let mut submitted = 0;
        for req in trace.at(1).iter().chain(trace.at(2)).take(4) {
            svc.submit(req.clone());
            submitted += 1;
        }
        assert!(submitted > 0, "trace produced no requests");
        for _ in 0..4 {
            svc.drain_batched().unwrap();
        }
        assert_eq!(svc.pending(), submitted, "all requests should defer");
        assert_eq!(svc.batch_log.iter().filter(|b| b.deferred).count(), 1);
        svc.harvest(1e7);
        svc.drain_batched().unwrap();
        assert_eq!(svc.pending(), 0);
        // Battery never exceeds capacity after refunds.
        let b = svc.battery().unwrap();
        assert!(b.charge_j <= b.capacity_j);
    }
}
