//! Queue-fronted unlearning service.
//!
//! Wraps an [`Engine`] with the request lifecycle a real edge deployment
//! needs: FCFS queueing, per-request receipts (RSN, latency estimate,
//! energy), optional battery gating (satellite mode: defer retraining when
//! the state of charge cannot cover it), and a service log.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::data::dataset::EdgePopulation;
use crate::data::trace::UnlearnRequest;
use crate::energy::EnergyModel;
use crate::sim::Battery;

/// Receipt for one served unlearning request.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub user: u32,
    pub round: u32,
    pub rsn: u64,
    pub lineages_retrained: usize,
    /// Estimated device seconds for the retrain (profile-based).
    pub est_seconds: f64,
    /// Estimated joules for the retrain.
    pub est_joules: f64,
    /// Deferred because the battery could not cover the retrain.
    pub deferred: bool,
}

/// FCFS unlearning service over an engine.
pub struct UnlearningService {
    engine: Engine,
    queue: VecDeque<UnlearnRequest>,
    energy: EnergyModel,
    battery: Option<Battery>,
    pub log: Vec<ServiceReport>,
}

impl UnlearningService {
    pub fn new(engine: Engine) -> Self {
        let energy = EnergyModel::for_model(&engine.cfg.model);
        Self { engine, queue: VecDeque::new(), energy, battery: None, log: vec![] }
    }

    /// Enable battery gating (energy-harvesting deployments).
    pub fn with_battery(mut self, battery: Battery) -> Self {
        self.battery = Some(battery);
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn battery(&self) -> Option<&Battery> {
        self.battery.as_ref()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Run one training round (new data arrival).
    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        self.engine.run_round(pop)?;
        Ok(())
    }

    /// Enqueue a request (FCFS).
    pub fn submit(&mut self, req: UnlearnRequest) {
        self.queue.push_back(req);
    }

    /// Serve queued requests in order. With a battery, a request whose
    /// estimated energy exceeds the charge is deferred (stays at the queue
    /// head) until `harvest` restores enough charge.
    pub fn drain(&mut self) -> Result<usize> {
        let mut served = 0;
        while let Some(req) = self.queue.front().cloned() {
            // Conservative pre-estimate: replaying all requested samples.
            let est_rsn_hint = req.total_samples();
            let est_j_hint = self
                .energy
                .retrain_joules(est_rsn_hint, self.engine.cfg.epochs_per_round);
            if let Some(b) = &mut self.battery {
                if !b.draw(est_j_hint) {
                    self.log.push(ServiceReport {
                        user: req.user.0,
                        round: req.round,
                        rsn: 0,
                        lineages_retrained: 0,
                        est_seconds: 0.0,
                        est_joules: est_j_hint,
                        deferred: true,
                    });
                    break; // FCFS: don't skip ahead of the deferred head.
                }
            }
            let outcome = self.engine.process_request(&req)?;
            let est_seconds = self
                .engine
                .cfg
                .model
                .train_secs(outcome.rsn, self.engine.cfg.epochs_per_round);
            let est_joules = self
                .energy
                .retrain_joules(outcome.rsn, self.engine.cfg.epochs_per_round);
            // Charge the actual cost difference (beyond the reservation).
            if let Some(b) = &mut self.battery {
                let delta = est_joules - est_j_hint;
                if delta > 0.0 {
                    let _ = b.draw(delta);
                } else {
                    b.charge_j = (b.charge_j - delta).min(b.capacity_j);
                }
            }
            self.log.push(ServiceReport {
                user: req.user.0,
                round: req.round,
                rsn: outcome.rsn,
                lineages_retrained: outcome.lineages_retrained,
                est_seconds,
                est_joules,
                deferred: false,
            });
            self.queue.pop_front();
            served += 1;
        }
        Ok(served)
    }

    /// Advance harvest time (satellite mode).
    pub fn harvest(&mut self, secs: f64) {
        if let Some(b) = &mut self.battery {
            b.harvest(secs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::system::SystemVariant;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::PopulationConfig;
    use crate::data::trace::{RequestTrace, TraceConfig};
    use crate::sim::device::AI_CUBESAT;

    fn setup() -> (UnlearningService, EdgePopulation, RequestTrace) {
        let cfg = ExperimentConfig {
            users: 20,
            rounds: 4,
            shards: 4,
            ..Default::default()
        };
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(8_000),
            users: cfg.users,
            rounds: cfg.rounds,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed: 11,
        });
        let trace = RequestTrace::generate(&pop, &TraceConfig::paper_default(12).with_prob(0.4));
        let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        (UnlearningService::new(engine), pop, trace)
    }

    #[test]
    fn fcfs_serves_all_on_mains() {
        let (mut svc, pop, trace) = setup();
        let mut submitted = 0;
        for t in 1..=4 {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
                submitted += 1;
            }
            svc.drain().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.log.iter().filter(|r| !r.deferred).count(), submitted);
        assert!(svc.engine().metrics.total_rsn() > 0);
    }

    #[test]
    fn battery_defers_until_harvest() {
        let (mut svc, pop, trace) = setup();
        let mut battery = Battery::new(&AI_CUBESAT);
        battery.charge_j = 0.5; // almost empty
        svc = UnlearningService::new(SystemVariant::Cause
            .build_cost(&svc.engine().cfg.clone())
            .unwrap())
            .with_battery(battery);
        svc.ingest_round(&pop).unwrap();
        let req = trace
            .at(1)
            .first()
            .cloned()
            .unwrap_or_else(|| trace.at(2).first().cloned().expect("trace has requests"));
        svc.submit(req);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 1, "request should be deferred");
        assert!(svc.log.last().unwrap().deferred);
        // Harvest a lot, then it goes through.
        svc.harvest(1e6);
        svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
    }
}
