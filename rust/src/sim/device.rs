//! Resource-constrained device profiles and a battery/harvest model.

/// Static resource envelope of an edge device.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Memory available for sub-model storage, bytes.
    pub memory_bytes: u64,
    /// Sustained training power draw, watts.
    pub train_watts: f64,
    /// Battery capacity, joules (0 = mains powered).
    pub battery_joules: f64,
    /// Mean harvest (solar) power, watts (0 = none).
    pub harvest_watts: f64,
}

/// The paper's testbed device (8 GB unified memory; 2 GB reserved for
/// sub-model storage per §5.1).
pub const JETSON_ORIN_NANO: DeviceProfile = DeviceProfile {
    name: "jetson-orin-nano",
    memory_bytes: 2 * 1024 * 1024 * 1024,
    train_watts: 15.0,
    battery_joules: 0.0,
    harvest_watts: 0.0,
};

/// A cubesat-class AI satellite: tight memory, battery + solar harvest.
pub const AI_CUBESAT: DeviceProfile = DeviceProfile {
    name: "ai-cubesat",
    memory_bytes: 512 * 1024 * 1024,
    train_watts: 10.0,
    // ~20 Wh battery.
    battery_joules: 20.0 * 3600.0,
    // Orbit-averaged solar input budgeted to compute.
    harvest_watts: 4.0,
};

/// Battery state with harvesting; time advances in discrete steps.
#[derive(Clone, Debug)]
pub struct Battery {
    pub capacity_j: f64,
    pub charge_j: f64,
    pub harvest_watts: f64,
    /// Energy requests refused for lack of charge.
    pub brownouts: u64,
}

impl Battery {
    pub fn new(profile: &DeviceProfile) -> Self {
        Self {
            capacity_j: profile.battery_joules,
            charge_j: profile.battery_joules,
            harvest_watts: profile.harvest_watts,
            brownouts: 0,
        }
    }

    /// True if the device is mains powered (infinite energy).
    pub fn mains(&self) -> bool {
        self.capacity_j <= 0.0
    }

    /// Harvest for `secs` seconds.
    pub fn harvest(&mut self, secs: f64) {
        if !self.mains() {
            self.charge_j = (self.charge_j + self.harvest_watts * secs).min(self.capacity_j);
        }
    }

    /// Try to spend `joules`; returns false (and counts a brownout) when
    /// the charge is insufficient — the caller must defer the work.
    pub fn draw(&mut self, joules: f64) -> bool {
        if self.mains() {
            return true;
        }
        if joules <= self.charge_j {
            self.charge_j -= joules;
            true
        } else {
            self.brownouts += 1;
            false
        }
    }

    /// Would a `draw(joules)` succeed right now? (No brownout recorded.)
    pub fn can_cover(&self, joules: f64) -> bool {
        self.mains() || joules <= self.charge_j
    }

    /// Return over-reserved energy to the battery, clamped at capacity.
    /// Negative refunds are ignored (use `draw` to spend); mains devices
    /// have no charge state to refund.
    pub fn refund(&mut self, joules: f64) {
        if !self.mains() {
            self.charge_j = (self.charge_j + joules.max(0.0)).min(self.capacity_j);
        }
    }

    /// Deduct energy for work that has *already* run (post-hoc
    /// settlement): unconditional, clamped at zero, and no brownout is
    /// recorded — `draw` gates work that has not run yet. Debt beyond an
    /// empty battery is forgiven (the simulation cannot un-run the work).
    pub fn deduct(&mut self, joules: f64) {
        if !self.mains() {
            self.charge_j = (self.charge_j - joules.max(0.0)).max(0.0);
        }
    }

    /// Settle a reservation against the actual cost of work that already
    /// ran: deduct the overrun beyond `reserved_j` (no gating, no brownout
    /// — the work cannot be un-run) or refund the over-reserved remainder.
    /// Both directions clamp (`[0, capacity]`), so a window that was split
    /// for battery reasons — where only the *executed* lineages' merged
    /// cost was ever reserved — can never refund energy it did not draw:
    /// the unexecuted lineages' share was left in the battery, not drawn
    /// and refunded, closing the under-refund edge of hint-based
    /// reservations.
    pub fn settle(&mut self, actual_j: f64, reserved_j: f64) {
        let delta = actual_j - reserved_j;
        if delta > 0.0 {
            self.deduct(delta);
        } else {
            self.refund(-delta);
        }
    }

    /// State of charge in [0, 1] (1.0 when mains powered).
    pub fn soc(&self) -> f64 {
        if self.mains() {
            1.0
        } else {
            self.charge_j / self.capacity_j
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mains_never_browns_out() {
        let mut b = Battery::new(&JETSON_ORIN_NANO);
        assert!(b.mains());
        assert!(b.draw(1e12));
        assert_eq!(b.brownouts, 0);
        assert_eq!(b.soc(), 1.0);
    }

    #[test]
    fn battery_drains_and_harvests() {
        let mut b = Battery::new(&AI_CUBESAT);
        assert!(b.draw(1000.0));
        let soc = b.soc();
        assert!(soc < 1.0);
        b.harvest(500.0); // 4 W * 500 s = 2000 J back
        assert!(b.soc() > soc);
        assert!(b.soc() <= 1.0);
    }

    #[test]
    fn brownout_on_empty() {
        let mut b = Battery::new(&AI_CUBESAT);
        assert!(!b.draw(b.capacity_j + 1.0));
        assert_eq!(b.brownouts, 1);
        // Charge untouched by the refused draw.
        assert_eq!(b.charge_j, b.capacity_j);
    }

    #[test]
    fn harvest_caps_at_capacity() {
        let mut b = Battery::new(&AI_CUBESAT);
        b.harvest(1e9);
        assert_eq!(b.charge_j, b.capacity_j);
    }

    #[test]
    fn over_refund_clamps_at_capacity() {
        let mut b = Battery::new(&AI_CUBESAT);
        assert!(b.draw(1000.0));
        // Refund far more than was drawn: charge must clamp, not overflow.
        b.refund(1e9);
        assert_eq!(b.charge_j, b.capacity_j);
        // Refund of the exact over-reservation restores the difference.
        assert!(b.draw(500.0));
        b.refund(200.0);
        assert!((b.charge_j - (b.capacity_j - 300.0)).abs() < 1e-9);
    }

    #[test]
    fn negative_refund_is_ignored() {
        let mut b = Battery::new(&AI_CUBESAT);
        assert!(b.draw(100.0));
        let before = b.charge_j;
        b.refund(-50.0);
        assert_eq!(b.charge_j, before);
    }

    #[test]
    fn deduct_clamps_at_zero_without_brownout() {
        let mut b = Battery::new(&AI_CUBESAT);
        b.deduct(100.0);
        assert_eq!(b.charge_j, b.capacity_j - 100.0);
        // Debt beyond empty is forgiven; no brownout for completed work.
        b.deduct(1e12);
        assert_eq!(b.charge_j, 0.0);
        assert_eq!(b.brownouts, 0);
        b.deduct(-5.0); // negative deductions ignored
        assert_eq!(b.charge_j, 0.0);
    }

    #[test]
    fn settle_clamps_both_directions() {
        let mut b = Battery::new(&AI_CUBESAT);
        // Reserve 1000 J, actual cost 400 J: the 600 J difference returns.
        assert!(b.draw(1000.0));
        b.settle(400.0, 1000.0);
        assert!((b.charge_j - (b.capacity_j - 400.0)).abs() < 1e-9);
        // Reserve 100 J, actual 250 J: the 150 J overrun is deducted
        // without a brownout (the work already ran).
        assert!(b.draw(100.0));
        b.settle(250.0, 100.0);
        assert!((b.charge_j - (b.capacity_j - 650.0)).abs() < 1e-9);
        assert_eq!(b.brownouts, 0);

        // Refund clamp: settling a huge over-reservation cannot push the
        // charge past capacity (a split window must not mint energy from
        // the unexecuted share).
        b.settle(0.0, 1e12);
        assert_eq!(b.charge_j, b.capacity_j);
        // Deduct clamp: a huge overrun empties the battery, no further.
        b.settle(1e12, 0.0);
        assert_eq!(b.charge_j, 0.0);
        assert_eq!(b.brownouts, 0);
    }

    #[test]
    fn can_cover_matches_draw_without_side_effects() {
        let b = Battery::new(&AI_CUBESAT);
        assert!(b.can_cover(b.capacity_j));
        assert!(!b.can_cover(b.capacity_j + 1.0));
        assert_eq!(b.brownouts, 0, "can_cover must not record brownouts");
        let mut mains = Battery::new(&JETSON_ORIN_NANO);
        assert!(mains.can_cover(1e12));
        mains.refund(1e12); // no-op on mains
        assert!(mains.mains());
    }
}
