//! Edge-device simulator: memory envelope, battery and energy harvesting.
//!
//! Used by the satellite example (energy-harvesting devices are one of the
//! paper's headline deployment targets) and the scalability experiments.

pub mod device;

pub use device::{Battery, DeviceProfile, JETSON_ORIN_NANO};
