//! Trainer abstraction: the engine drives one of two interchangeable
//! backends through the same code path.
//!
//! * [`CostTrainer`] — pure accounting. RSN, energy, and memory pressure
//!   are closed-form given the coordinator's decisions (the paper's own
//!   argument for the RSN metric: time and energy are linear in samples).
//!   Used for the large sweeps (Figs. 11–14, 16, 17b/c) that the authors
//!   ran on a GPU farm.
//! * [`PjrtTrainer`] — real training through the AOT artifacts (Layer 1+2)
//!   on the PJRT CPU client. Used for every accuracy experiment
//!   (Table 2/3, Figs. 5, 10, 15, 17a) and the e2e example.
//! * [`HostTrainer`] — real `HostTensor` parameters with deterministic
//!   synthetic updates, no PJRT required. Drives the checkpoint codec,
//!   prune-aware snapshots, and the byte-budget store offline
//!   (`bench_compress`, `tests/compressed_store.rs`).

pub mod cost;
pub mod host;
pub mod pjrt;

use std::sync::Arc;

use anyhow::Result;

use crate::data::dataset::BlockId;
use crate::pruning::PruneSchedule;
use crate::runtime::HostTensor;

pub use cost::CostTrainer;
pub use host::{HostTrainer, HostTrainerConfig};
pub use pjrt::{PjrtTrainer, PjrtTrainerConfig};

/// What a training run reports back for accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainOutcome {
    /// Pruning kernel invocations performed.
    pub prune_ops: u64,
}

/// A `Send` worker that runs one lineage's retrain off-thread during a
/// batched unlearning window. Workers are compute/accounting mirrors of the
/// owning [`Trainer`]: they must not need the trainer's in-memory model
/// state, and the engine folds their results back through
/// [`Trainer::absorb`] on the owning thread. Backends whose per-lineage
/// training is stateful and thread-local (PJRT: `Rc`-based handles) simply
/// never hand out workers and the batch executor stays serial.
pub trait LineageWorker: Send {
    /// Train on `blocks` for `epochs`, applying `schedule` pruning passes;
    /// mirrors [`Trainer::run`] for one lineage.
    fn run(
        &mut self,
        blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome>;
}

/// A training backend. `lineage` indices are the engine's shard lineages.
pub trait Trainer {
    /// Reset the lineage's current model: `Some(params)` restores a stored
    /// checkpoint, `None` reinitializes from scratch.
    fn reset(&mut self, lineage: usize, params: Option<&[HostTensor]>) -> Result<()>;

    /// (Incrementally) train the lineage's current model on `blocks`
    /// for `epochs`, applying `schedule` pruning passes interleaved.
    fn run(
        &mut self,
        lineage: usize,
        blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome>;

    /// Checkpoint payload of the lineage's current model:
    /// (size hint in bytes, parameters if this backend has them).
    /// Tensor-carrying backends apply the prune schedule's final magnitude
    /// mask before handing tensors out (stored sparsity is real) and the
    /// engine derives the true stored size from the codec's encoding — the
    /// hint stands only for the accounting backend, whose paper-scale
    /// formula *is* the size. Parameters are handed out under shared
    /// ownership so encoding and restores clone refcounts, never tensor
    /// data.
    fn snapshot(&mut self, lineage: usize) -> Result<(u64, Option<Arc<[HostTensor]>>)>;

    /// Size of one stored checkpoint — defines N_mem slot granularity.
    fn checkpoint_bytes(&self) -> u64;

    /// Ensemble accuracy over the given lineages' current models
    /// (None when this backend cannot measure accuracy).
    fn evaluate(&mut self, lineages: &[usize]) -> Result<Option<f64>>;

    /// A [`LineageWorker`] for off-thread retraining of `lineage` during a
    /// batched unlearning window, when the backend supports it. The default
    /// (`None`) keeps all training on the engine thread.
    fn worker(&self, _lineage: usize) -> Option<Box<dyn LineageWorker>> {
        None
    }

    /// Fold an off-thread worker's outcome back into backend accounting
    /// (`samples` is the replay size the worker processed). Called exactly
    /// once per worker run, on the engine thread.
    fn absorb(&mut self, _lineage: usize, _samples: u64, _epochs: u32, _out: &TrainOutcome) {}
}
