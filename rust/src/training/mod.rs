//! Trainer abstraction: the engine drives one of two interchangeable
//! backends through the same code path.
//!
//! * [`CostTrainer`] — pure accounting. RSN, energy, and memory pressure
//!   are closed-form given the coordinator's decisions (the paper's own
//!   argument for the RSN metric: time and energy are linear in samples).
//!   Used for the large sweeps (Figs. 11–14, 16, 17b/c) that the authors
//!   ran on a GPU farm.
//! * [`PjrtTrainer`] — real training through the AOT artifacts (Layer 1+2)
//!   on the PJRT CPU client. Used for every accuracy experiment
//!   (Table 2/3, Figs. 5, 10, 15, 17a) and the e2e example.

pub mod cost;
pub mod pjrt;

use anyhow::Result;

use crate::data::dataset::BlockId;
use crate::pruning::PruneSchedule;
use crate::runtime::HostTensor;

pub use cost::CostTrainer;
pub use pjrt::{PjrtTrainer, PjrtTrainerConfig};

/// What a training run reports back for accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainOutcome {
    /// Pruning kernel invocations performed.
    pub prune_ops: u64,
}

/// A training backend. `lineage` indices are the engine's shard lineages.
pub trait Trainer {
    /// Reset the lineage's current model: `Some(params)` restores a stored
    /// checkpoint, `None` reinitializes from scratch.
    fn reset(&mut self, lineage: usize, params: Option<&[HostTensor]>) -> Result<()>;

    /// (Incrementally) train the lineage's current model on `blocks`
    /// for `epochs`, applying `schedule` pruning passes interleaved.
    fn run(
        &mut self,
        lineage: usize,
        blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome>;

    /// Checkpoint payload of the lineage's current model:
    /// (stored size in bytes, parameters if this backend has them).
    fn snapshot(&mut self, lineage: usize) -> Result<(u64, Option<Vec<HostTensor>>)>;

    /// Size of one stored checkpoint — defines N_mem slot granularity.
    fn checkpoint_bytes(&self) -> u64;

    /// Ensemble accuracy over the given lineages' current models
    /// (None when this backend cannot measure accuracy).
    fn evaluate(&mut self, lineages: &[usize]) -> Result<Option<f64>>;
}
