//! The accounting backend: no numerics, paper-scale sizes.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelProfile;
use crate::data::dataset::BlockId;
use crate::pruning::PruneSchedule;
use crate::runtime::HostTensor;
use crate::training::{LineageWorker, TrainOutcome, Trainer};

/// Cost-model trainer over a paper-scale [`ModelProfile`].
pub struct CostTrainer {
    profile: ModelProfile,
    /// Final keep fraction of the system's schedule (fixes checkpoint size).
    keep: f64,
    /// Samples×epochs processed (diagnostics / tests).
    pub sample_epochs: u64,
}

impl CostTrainer {
    pub fn new(profile: ModelProfile, schedule: PruneSchedule) -> Self {
        Self { profile, keep: schedule.final_keep(), sample_epochs: 0 }
    }
}

/// Off-thread mirror of [`CostTrainer::run`]: the cost model is a pure
/// function of (samples, epochs, schedule), so the worker carries no state;
/// the shared `sample_epochs` diagnostic is reconciled by `absorb`.
struct CostWorker;

impl LineageWorker for CostWorker {
    fn run(
        &mut self,
        _blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome> {
        Ok(TrainOutcome { prune_ops: schedule.prune_ops(epochs.max(1)) })
    }
}

impl Trainer for CostTrainer {
    fn reset(&mut self, _lineage: usize, _params: Option<&[HostTensor]>) -> Result<()> {
        Ok(())
    }

    fn run(
        &mut self,
        _lineage: usize,
        blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome> {
        let samples: u64 = blocks.iter().map(|(_, n)| n).sum();
        self.sample_epochs += samples * epochs as u64;
        // One prune pass per epoch-chunk; the schedule decides how many act.
        Ok(TrainOutcome { prune_ops: schedule.prune_ops(epochs.max(1)) })
    }

    fn snapshot(&mut self, _lineage: usize) -> Result<(u64, Option<Arc<[HostTensor]>>)> {
        Ok((self.profile.pruned_bytes(self.keep), None))
    }

    fn checkpoint_bytes(&self) -> u64 {
        self.profile.pruned_bytes(self.keep).max(1)
    }

    fn evaluate(&mut self, _lineages: &[usize]) -> Result<Option<f64>> {
        Ok(None)
    }

    fn worker(&self, _lineage: usize) -> Option<Box<dyn LineageWorker>> {
        Some(Box::new(CostWorker))
    }

    fn absorb(&mut self, _lineage: usize, samples: u64, epochs: u32, _out: &TrainOutcome) {
        self.sample_epochs += samples * epochs as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::profiles::RESNET34;

    #[test]
    fn checkpoint_size_reflects_pruning() {
        let dense = CostTrainer::new(RESNET34, PruneSchedule::None);
        let pruned =
            CostTrainer::new(RESNET34, PruneSchedule::Iterative { keep: 0.3, steps: 4 });
        assert!(pruned.checkpoint_bytes() < dense.checkpoint_bytes());
        // δ=70% → more than 2x as many checkpoints fit (Table 2's >50%).
        assert!(dense.checkpoint_bytes() as f64 / pruned.checkpoint_bytes() as f64 > 2.0);
    }

    #[test]
    fn accounts_sample_epochs() {
        let mut t = CostTrainer::new(RESNET34, PruneSchedule::None);
        t.run(0, &[(BlockId(0), 100), (BlockId(1), 50)], 80, PruneSchedule::None).unwrap();
        assert_eq!(t.sample_epochs, 150 * 80);
        assert_eq!(t.evaluate(&[0]).unwrap(), None);
    }

    #[test]
    fn worker_matches_serial_run() {
        let schedule = PruneSchedule::Iterative { keep: 0.3, steps: 4 };
        let mut serial = CostTrainer::new(RESNET34, schedule);
        let blocks = [(BlockId(0), 120), (BlockId(1), 30)];
        let direct = serial.run(0, &blocks, 80, schedule).unwrap();

        let mut parallel = CostTrainer::new(RESNET34, schedule);
        let mut w = parallel.worker(0).expect("cost backend supports workers");
        let off = w.run(&blocks, 80, schedule).unwrap();
        parallel.absorb(0, 150, 80, &off);

        assert_eq!(direct.prune_ops, off.prune_ops);
        assert_eq!(serial.sample_epochs, parallel.sample_epochs);
    }
}
