//! The real backend: trains the AOT proxy models through PJRT.
//!
//! Each lineage owns a [`TrainSession`] over the configured artifact
//! variant. Blocks are materialized from the synthetic population, stepped
//! through `<variant>/train_step`, pruned through `<variant>/prune` per the
//! schedule, and evaluated with `<variant>/predict` + majority vote.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::aggregate::{argmax, ensemble_accuracy};
use crate::data::dataset::{BlockId, EdgePopulation};
use crate::pruning::PruneSchedule;
use crate::runtime::{HostTensor, Runtime, TrainSession};
use crate::training::{TrainOutcome, Trainer};

/// Knobs for the PJRT backend.
#[derive(Clone, Debug)]
pub struct PjrtTrainerConfig {
    /// Artifact variant, e.g. `"mobilenetv2_c10"`.
    pub variant: String,
    /// Epoch cap per training run (the paper's 80 epochs on a Jetson maps
    /// to a handful on the CPU-interpret proxy — documented in DESIGN.md).
    pub max_epochs: u32,
    /// SGD learning rate fed to the train-step artifact.
    pub lr: f32,
    /// Held-out test set size for `evaluate`.
    pub test_samples: usize,
    /// Base seed for per-lineage initialization.
    pub seed: u64,
}

impl Default for PjrtTrainerConfig {
    fn default() -> Self {
        Self {
            variant: "mobilenetv2_c10".into(),
            max_epochs: 3,
            lr: 0.05,
            test_samples: 256,
            seed: 7,
        }
    }
}

/// Real-training backend.
pub struct PjrtTrainer {
    rt: Rc<Runtime>,
    pop: Arc<EdgePopulation>,
    cfg: PjrtTrainerConfig,
    sessions: Vec<Option<TrainSession>>,
    /// Cached test set (features, labels).
    test: Option<(Vec<f32>, Vec<f32>)>,
    /// Dense parameter bytes of one model (from the manifest).
    dense_bytes: u64,
    /// Final keep fraction currently configured (sizes checkpoints).
    keep_hint: f64,
}

impl PjrtTrainer {
    pub fn new(
        rt: Rc<Runtime>,
        pop: Arc<EdgePopulation>,
        cfg: PjrtTrainerConfig,
        max_lineages: usize,
        final_keep: f64,
    ) -> Result<Self> {
        let spec = rt.manifest().get(&format!("{}/train_step", cfg.variant))?;
        let dense_bytes = spec.param_bytes().max(
            spec.inputs
                .iter()
                .filter(|t| t.name.starts_with('p'))
                .map(|t| t.size_bytes())
                .sum(),
        ) as u64;
        let mut sessions = Vec::new();
        sessions.resize_with(max_lineages, || None);
        Ok(Self { rt, pop, cfg, sessions, test: None, dense_bytes, keep_hint: final_keep })
    }

    fn session(&mut self, lineage: usize) -> Result<&mut TrainSession> {
        if self.sessions[lineage].is_none() {
            let seed = self.cfg.seed.wrapping_add(lineage as u64 * 1000 + 1);
            self.sessions[lineage] =
                Some(TrainSession::init(self.rt.clone(), &self.cfg.variant, seed)?);
        }
        Ok(self.sessions[lineage].as_mut().unwrap())
    }

    /// One epoch over the blocks: materialize and step in AOT batches.
    /// With `mask_keep`, the sparsity pattern is re-applied after every
    /// step — masked fine-tuning, the recovery phase of RCMP's
    /// prune-and-retrain loop (plain SGD would regrow pruned weights).
    fn epoch(
        &mut self,
        lineage: usize,
        blocks: &[(BlockId, u64)],
        mask_keep: Option<f32>,
    ) -> Result<f32> {
        let pop = self.pop.clone();
        let lr = self.cfg.lr;
        let mut last_loss = 0.0;
        for (block_id, samples) in blocks {
            if *samples == 0 {
                continue;
            }
            let Some(block) = pop.block(*block_id) else { continue };
            let (xs, ys) = pop.materialize(block, *samples as usize);
            let sess = self.session(lineage)?;
            let bs = sess.batch_size();
            let fd = sess.feature_dim();
            let rows = ys.len();
            let mut r = 0;
            let mut steps_since_mask = 0u32;
            while r < rows {
                let take = bs.min(rows - r);
                last_loss = sess.step(&xs[r * fd..(r + take) * fd], &ys[r..r + take], lr)?;
                r += take;
                steps_since_mask += 1;
                // Re-apply the sparsity pattern every few steps: weight
                // regrowth over <8 SGD steps is negligible and this keeps
                // the prune kernel off the per-step critical path
                // (EXPERIMENTS.md §Perf-L3).
                if let (Some(keep), true) = (mask_keep, steps_since_mask >= 8 || r >= rows) {
                    sess.prune(keep)?;
                    steps_since_mask = 0;
                }
            }
        }
        Ok(last_loss)
    }
}

impl Trainer for PjrtTrainer {
    fn reset(&mut self, lineage: usize, params: Option<&[HostTensor]>) -> Result<()> {
        match params {
            Some(p) => {
                self.sessions[lineage] = Some(TrainSession::from_params(
                    self.rt.clone(),
                    &self.cfg.variant,
                    p.to_vec(),
                )?);
            }
            None => {
                let seed = self.cfg.seed.wrapping_add(lineage as u64 * 1000 + 1);
                self.sessions[lineage] =
                    Some(TrainSession::init(self.rt.clone(), &self.cfg.variant, seed)?);
            }
        }
        Ok(())
    }

    fn run(
        &mut self,
        lineage: usize,
        blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome> {
        self.keep_hint = schedule.final_keep();
        let epochs = epochs.min(self.cfg.max_epochs).max(1);
        let mut prune_ops = 0;
        match schedule {
            PruneSchedule::None | PruneSchedule::OneShot { .. } => {
                // Dense training; OMP's single magnitude-prune happens at
                // snapshot time (one-shot, no recovery — Table 6).
                for _ in 0..epochs {
                    self.epoch(lineage, blocks, None)?;
                }
                if matches!(schedule, PruneSchedule::OneShot { .. }) {
                    prune_ops = 1;
                }
            }
            PruneSchedule::Iterative { keep, .. } => {
                // RCMP (Fig. 4): a first *dense* epoch (never prune
                // untrained weights — magnitudes carry no signal yet),
                // sparsity stepped down between subsequent epochs, then a
                // final *masked* fine-tune epoch at the target keep so the
                // stored model is both sparse and recovered.
                self.epoch(lineage, blocks, None)?;
                for pass in 1..epochs.saturating_sub(1) {
                    self.epoch(lineage, blocks, None)?;
                    if let Some(k) = schedule.keep_at(pass, epochs) {
                        self.session(lineage)?
                            .prune(k as f32)
                            .context("prune pass")?;
                        prune_ops += 1;
                    }
                }
                self.session(lineage)?
                    .prune(keep as f32)
                    .context("target prune")?;
                prune_ops += 1;
                if epochs > 1 {
                    self.epoch(lineage, blocks, Some(keep as f32))?;
                }
            }
        }
        Ok(TrainOutcome { prune_ops })
    }

    fn snapshot(&mut self, lineage: usize) -> Result<(u64, Option<Arc<[HostTensor]>>)> {
        // RCMP stores the *compressed* sub-model: prune a copy at the
        // configured keep fraction through the Layer-1 kernel (the working
        // model keeps training dense), so stored sparsity is real. The
        // returned size is a dense hint only — the engine derives the true
        // stored bytes from the codec's actual encoding of these tensors.
        let keep = self.keep_hint as f32;
        let rt = self.rt.clone();
        let variant = self.cfg.variant.clone();
        let sess = self.session(lineage)?;
        let params = if keep < 1.0 {
            crate::runtime::PruneSession { rt, variant }.prune(sess.params(), keep)?
        } else {
            sess.params().to_vec()
        };
        let dense: u64 = params.iter().map(|p| p.size_bytes() as u64).sum();
        Ok((dense, Some(params.into())))
    }

    fn checkpoint_bytes(&self) -> u64 {
        // Slot size: dense bytes scaled by the configured keep fraction
        // (matches what snapshot() will produce after pruning converges).
        ((self.dense_bytes as f64) * (0.15 + 0.85 * self.keep_hint)).max(1.0) as u64
    }

    fn evaluate(&mut self, lineages: &[usize]) -> Result<Option<f64>> {
        if lineages.is_empty() {
            return Ok(Some(0.0));
        }
        if self.test.is_none() {
            self.test = Some(
                self.pop.materialize_test(self.cfg.test_samples, self.cfg.seed ^ 0x7e57),
            );
        }
        let (xs, ys) = self.test.clone().unwrap();
        let classes = self.pop.cfg.spec.classes;
        let mut per_model = Vec::with_capacity(lineages.len());
        for &l in lineages {
            // Evaluate the *deployed* sub-model — i.e. the compressed
            // parameters the device actually stores (Table 2 measures
            // pruned-model accuracy).
            let (_bytes, params) = self.snapshot(l)?;
            let params = params.expect("pjrt snapshot always has params");
            let sess = self.session(l)?;
            let (bs, fd) = (sess.batch_size(), sess.feature_dim());
            let predict = crate::runtime::PredictSession {
                rt: self.rt.clone(),
                variant: self.cfg.variant.clone(),
            };
            let mut labels = Vec::with_capacity(ys.len());
            let mut r = 0;
            while r < ys.len() {
                let take = bs.min(ys.len() - r);
                let logits =
                    predict.logits(&params, &xs[r * fd..(r + take) * fd], take, bs, fd)?;
                labels.extend(logits.iter().map(|row| argmax(row)));
                r += take;
            }
            per_model.push(labels);
        }
        Ok(Some(ensemble_accuracy(&per_model, &ys, classes)))
    }
}
