//! Host-tensor training backend: real parameter tensors without PJRT.
//!
//! The PJRT backend needs AOT artifacts that are absent in offline builds,
//! and the accounting backend carries no tensors at all — which left every
//! tensor-touching code path (the checkpoint codec, prune-aware snapshots,
//! decode-cached warm starts) without an offline driver. `HostTrainer`
//! fills that gap: each lineage owns a small set of `HostTensor`s, a
//! training run applies a *deterministic, localized* synthetic update (SGD
//! on an edge round touches a correlated subset of weights, which is what
//! makes delta encoding pay), and `snapshot` applies the prune schedule's
//! final magnitude mask before handing the tensors out — so stored
//! sparsity is real, not assumed.
//!
//! This backend models no loss surface; RSN/energy accounting flows
//! through the engine exactly as with [`CostTrainer`](crate::training::CostTrainer).
//! It exists so the byte-budget store and the codec can be exercised (and
//! benchmarked, `benches/bench_compress.rs`) with genuine tensor payloads.

use std::sync::Arc;

use anyhow::Result;

use crate::data::dataset::BlockId;
use crate::prng::Rng;
use crate::pruning::PruneSchedule;
use crate::runtime::codec::{PARAMS_HEADER_BYTES, TENSOR_HEADER_BYTES};
use crate::runtime::HostTensor;
use crate::training::{TrainOutcome, Trainer};

/// Knobs for the host backend.
#[derive(Clone, Debug)]
pub struct HostTrainerConfig {
    /// Parameter tensor shapes of one sub-model.
    pub shapes: Vec<Vec<usize>>,
    /// Base seed for per-lineage initialization and update streams.
    pub seed: u64,
    /// Fraction of each tensor one training run perturbs (update
    /// locality; smaller values make delta encoding pay more).
    pub update_frac: f64,
}

impl Default for HostTrainerConfig {
    fn default() -> Self {
        Self { shapes: vec![vec![64, 64], vec![64]], seed: 7, update_frac: 0.25 }
    }
}

/// Dense encoded upper bound for one sub-model of the given shapes — the
/// codec's worst case (dense fallback), and therefore the correct slot
/// size when a byte budget is normalized to N_mem slots.
pub fn dense_upper_bound(shapes: &[Vec<usize>]) -> u64 {
    PARAMS_HEADER_BYTES
        + shapes
            .iter()
            .map(|dims| {
                TENSOR_HEADER_BYTES
                    + 8 * dims.len() as u64
                    + 4 * dims.iter().product::<usize>() as u64
            })
            .sum::<u64>()
}

/// Host-tensor backend.
pub struct HostTrainer {
    cfg: HostTrainerConfig,
    models: Vec<Option<Vec<HostTensor>>>,
    /// Final keep fraction of the last-seen schedule (sizes snapshots).
    keep_hint: f64,
    /// Training runs performed (drives the deterministic update stream).
    runs: u64,
    /// Samples×epochs processed (diagnostics / tests).
    pub sample_epochs: u64,
}

impl HostTrainer {
    pub fn new(cfg: HostTrainerConfig, max_lineages: usize, schedule: PruneSchedule) -> Self {
        assert!(!cfg.shapes.is_empty(), "host trainer needs at least one tensor");
        let mut models = Vec::new();
        models.resize_with(max_lineages, || None);
        Self { cfg, models, keep_hint: schedule.final_keep(), runs: 0, sample_epochs: 0 }
    }

    /// Deterministic per-lineage initialization in [-1, 1).
    fn init(cfg: &HostTrainerConfig, lineage: usize) -> Vec<HostTensor> {
        let mut rng =
            Rng::new(cfg.seed ^ (lineage as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut out = Vec::with_capacity(cfg.shapes.len());
        for dims in &cfg.shapes {
            out.push(HostTensor::from_fn(dims, |_| rng.f32() * 2.0 - 1.0));
        }
        out
    }

    fn model(&mut self, lineage: usize) -> &mut Vec<HostTensor> {
        if self.models[lineage].is_none() {
            self.models[lineage] = Some(Self::init(&self.cfg, lineage));
        }
        self.models[lineage].as_mut().expect("just initialized")
    }
}

impl Trainer for HostTrainer {
    fn reset(&mut self, lineage: usize, params: Option<&[HostTensor]>) -> Result<()> {
        self.models[lineage] = Some(match params {
            Some(p) => p.to_vec(),
            None => Self::init(&self.cfg, lineage),
        });
        Ok(())
    }

    fn run(
        &mut self,
        lineage: usize,
        blocks: &[(BlockId, u64)],
        epochs: u32,
        schedule: PruneSchedule,
    ) -> Result<TrainOutcome> {
        self.keep_hint = schedule.final_keep();
        let samples: u64 = blocks.iter().map(|(_, n)| n).sum();
        let epochs = epochs.max(1);
        self.sample_epochs += samples * epochs as u64;
        let run_seed = self
            .cfg
            .seed
            .wrapping_add(self.runs.wrapping_mul(0x2545_f491_4f6c_dd1d))
            .wrapping_add((lineage as u64) << 32)
            .wrapping_add(samples);
        self.runs += 1;
        let frac = self.cfg.update_frac.clamp(0.0, 1.0);
        let keep = schedule.final_keep();
        let prune_ops = schedule.prune_ops(epochs);
        let mut rng = Rng::new(run_seed);
        let model = self.model(lineage);
        for t in model.iter_mut() {
            let n = t.len();
            if n == 0 {
                continue;
            }
            // One localized update window per tensor per run: a contiguous
            // span of update_frac * n entries starting at a seeded offset.
            let span = ((n as f64 * frac).ceil() as usize).clamp(1, n);
            let start = rng.below(n as u64) as usize;
            for k in 0..span {
                let i = (start + k) % n;
                t.data[i] += rng.f32() * 0.02 - 0.01;
            }
        }
        if prune_ops > 0 {
            // The schedule's passes collapse to the final mask here — the
            // working model keeps the target sparsity structure so masked
            // fine-tuning (regrowth refresh) is modeled without per-pass
            // cost; prune_ops still accounts every kernel invocation.
            for t in model.iter_mut() {
                t.apply_mask(keep);
            }
        }
        Ok(TrainOutcome { prune_ops })
    }

    fn snapshot(&mut self, lineage: usize) -> Result<(u64, Option<Arc<[HostTensor]>>)> {
        let keep = self.keep_hint;
        let model = self.model(lineage);
        let mut params = model.clone();
        if keep < 1.0 {
            // Prune-aware snapshot: the stored payload's sparsity is real
            // — the codec encodes what the mask actually zeroed, not what
            // a profile formula assumes.
            for t in &mut params {
                t.apply_mask(keep);
            }
        }
        // Size hint only; the engine derives the true stored size from the
        // codec's encoding. Dense bytes keep the hint an upper bound.
        let dense: u64 = params.iter().map(|p| p.size_bytes() as u64).sum();
        Ok((dense, Some(params.into())))
    }

    fn checkpoint_bytes(&self) -> u64 {
        // Slot mode must provision for the codec's worst case (dense
        // fallback): one slot = one dense payload plus headers.
        dense_upper_bound(&self.cfg.shapes).max(1)
    }

    fn evaluate(&mut self, _lineages: &[usize]) -> Result<Option<f64>> {
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blocks() -> Vec<(BlockId, u64)> {
        vec![(BlockId(0), 60), (BlockId(1), 40)]
    }

    #[test]
    fn deterministic_across_instances() {
        let mk = || {
            HostTrainer::new(
                HostTrainerConfig::default(),
                2,
                PruneSchedule::Iterative { keep: 0.3, steps: 4 },
            )
        };
        let (mut a, mut b) = (mk(), mk());
        for t in [&mut a, &mut b] {
            t.run(0, &blocks(), 3, PruneSchedule::Iterative { keep: 0.3, steps: 4 }).unwrap();
        }
        let (sa, pa) = a.snapshot(0).unwrap();
        let (sb, pb) = b.snapshot(0).unwrap();
        assert_eq!(sa, sb);
        assert_eq!(pa.unwrap().as_ref(), pb.unwrap().as_ref());
        assert_eq!(a.sample_epochs, 300);
    }

    #[test]
    fn snapshot_applies_final_mask() {
        let schedule = PruneSchedule::Iterative { keep: 0.3, steps: 4 };
        let mut t = HostTrainer::new(HostTrainerConfig::default(), 1, schedule);
        let out = t.run(0, &blocks(), 5, schedule).unwrap();
        assert_eq!(out.prune_ops, schedule.prune_ops(5));
        let (_, params) = t.snapshot(0).unwrap();
        let params = params.unwrap();
        for p in params.iter() {
            // apply_mask keeps ceil(0.3 * n) entries (plus ties).
            assert!(
                p.sparsity() > 0.6,
                "snapshot not pruned: sparsity {}",
                p.sparsity()
            );
        }
        // Dense schedule: snapshot stays dense.
        let mut dense = HostTrainer::new(HostTrainerConfig::default(), 1, PruneSchedule::None);
        dense.run(0, &blocks(), 5, PruneSchedule::None).unwrap();
        let (_, dp) = dense.snapshot(0).unwrap();
        for p in dp.unwrap().iter() {
            assert!(p.sparsity() < 0.01);
        }
    }

    #[test]
    fn reset_roundtrips_checkpoint_params() {
        let schedule = PruneSchedule::None;
        let mut t = HostTrainer::new(HostTrainerConfig::default(), 2, schedule);
        t.run(0, &blocks(), 2, schedule).unwrap();
        let (_, params) = t.snapshot(0).unwrap();
        let params = params.unwrap();
        t.run(0, &blocks(), 2, schedule).unwrap(); // drift away
        t.reset(0, Some(params.as_ref())).unwrap();
        let (_, restored) = t.snapshot(0).unwrap();
        assert_eq!(restored.unwrap().as_ref(), params.as_ref());
        // reset(None) reinitializes deterministically.
        t.reset(0, None).unwrap();
        let fresh = HostTrainer::new(HostTrainerConfig::default(), 2, schedule)
            .snapshot(0)
            .unwrap();
        assert_eq!(t.snapshot(0).unwrap().1.unwrap().as_ref(), fresh.1.unwrap().as_ref());
    }

    #[test]
    fn checkpoint_bytes_bounds_snapshot_payload() {
        let mut t = HostTrainer::new(HostTrainerConfig::default(), 1, PruneSchedule::None);
        let (dense_hint, params) = t.snapshot(0).unwrap();
        let payload_bytes: u64 =
            params.unwrap().iter().map(|p| p.size_bytes() as u64).sum();
        assert_eq!(dense_hint, payload_bytes);
        assert!(t.checkpoint_bytes() >= payload_bytes, "slot must fit a dense payload");
    }
}
