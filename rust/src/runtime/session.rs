//! Typed execution sessions over the raw [`Runtime`].
//!
//! Artifact signatures (enforced by `python/compile/aot.py` and validated
//! against the manifest here):
//!
//! * `<variant>/init`       : `[seed]                      -> [p_0 .. p_k]`
//! * `<variant>/train_step` : `[p_0 .. p_k, x, y, lr]      -> [p_0 .. p_k, loss]`
//! * `<variant>/predict`    : `[p_0 .. p_k, x]             -> [logits]`
//! * `<variant>/prune`      : `[p_0 .. p_k, keep_frac]     -> [p_0 .. p_k]`
//!
//! `x` is `[batch, features]` f32, `y` is `[batch]` f32 class indices
//! (cast to int inside the graph). All shapes are fixed at AOT time; the
//! session pads the final partial batch and masks the padding out via the
//! `y = -1` convention (the graph zero-weights negative labels).

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::client::Runtime;
use super::tensor::HostTensor;

/// Label value marking a padded (ignored) row in a train/eval batch.
pub const PAD_LABEL: f32 = -1.0;

/// A model variant's parameter state plus the handles to its artifacts.
pub struct TrainSession {
    rt: Rc<Runtime>,
    variant: String,
    params: Vec<HostTensor>,
    batch: usize,
    features: usize,
    /// Cumulative examples processed by `step` (padding excluded).
    pub examples_seen: u64,
    /// Cumulative train steps.
    pub steps: u64,
}

impl TrainSession {
    /// Initialize parameters from the `<variant>/init` artifact.
    pub fn init(rt: Rc<Runtime>, variant: &str, seed: u64) -> Result<Self> {
        let name = format!("{variant}/init");
        // f32 exactly represents integers < 2^24; aot.py folds the seed into
        // a PRNG key. Keep seeds small to stay exact.
        let seed_t = HostTensor::scalar((seed % (1 << 24)) as f32);
        let params = rt.execute(&name, &[seed_t])?;
        Self::from_params(rt, variant, params)
    }

    /// Wrap existing parameters (e.g. a checkpoint restored from the store).
    pub fn from_params(rt: Rc<Runtime>, variant: &str, params: Vec<HostTensor>) -> Result<Self> {
        let spec = rt.manifest().get(&format!("{variant}/train_step"))?;
        let k = spec
            .inputs
            .len()
            .checked_sub(3)
            .context("train_step artifact must have params + x,y,lr inputs")?;
        if params.len() != k {
            bail!("variant '{variant}' expects {k} param tensors, got {}", params.len());
        }
        let x_spec = &spec.inputs[k];
        if x_spec.dims.len() != 2 {
            bail!("train_step x input must be rank 2, got {:?}", x_spec.dims);
        }
        Ok(Self {
            batch: x_spec.dims[0],
            features: x_spec.dims[1],
            rt,
            variant: variant.to_string(),
            params,
            examples_seen: 0,
            steps: 0,
        })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    /// AOT batch size of this variant.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Feature dimension of this variant.
    pub fn feature_dim(&self) -> usize {
        self.features
    }

    /// Borrow the current parameters.
    pub fn params(&self) -> &[HostTensor] {
        &self.params
    }

    /// Take ownership of the parameters (consumes the session).
    pub fn into_params(self) -> Vec<HostTensor> {
        self.params
    }

    /// Total bytes of the current (dense) parameter state.
    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|p| p.size_bytes()).sum()
    }

    /// Run one SGD step on a batch; returns the mean loss.
    ///
    /// `xs` is `examples x features` row-major and may contain fewer rows
    /// than the AOT batch; the remainder is padded and masked.
    pub fn step(&mut self, xs: &[f32], ys: &[f32], lr: f32) -> Result<f32> {
        let rows = ys.len();
        if rows == 0 || rows > self.batch {
            bail!("step wants 1..={} rows, got {rows}", self.batch);
        }
        if xs.len() != rows * self.features {
            bail!("xs len {} != rows {} * features {}", xs.len(), rows, self.features);
        }
        let mut xbuf = vec![0.0f32; self.batch * self.features];
        xbuf[..xs.len()].copy_from_slice(xs);
        let mut ybuf = vec![PAD_LABEL; self.batch];
        ybuf[..rows].copy_from_slice(ys);

        let mut inputs = self.params.clone();
        inputs.push(HostTensor::new(xbuf, vec![self.batch, self.features])?);
        inputs.push(HostTensor::new(ybuf, vec![self.batch])?);
        inputs.push(HostTensor::scalar(lr));

        let mut outs = self.rt.execute(&format!("{}/train_step", self.variant), &inputs)?;
        let loss = outs
            .pop()
            .context("train_step returned no outputs")?
            .as_scalar()
            .context("train_step loss")?;
        self.params = outs;
        self.examples_seen += rows as u64;
        self.steps += 1;
        Ok(loss)
    }

    /// Magnitude-prune the weight matrices, keeping `keep_frac` of entries.
    pub fn prune(&mut self, keep_frac: f32) -> Result<()> {
        if !(0.0..=1.0).contains(&keep_frac) {
            bail!("keep_frac must be in [0,1], got {keep_frac}");
        }
        let mut inputs = self.params.clone();
        inputs.push(HostTensor::scalar(keep_frac));
        self.params = self.rt.execute(&format!("{}/prune", self.variant), &inputs)?;
        Ok(())
    }

    /// Logits for up to one AOT batch of examples.
    pub fn logits(&self, xs: &[f32], rows: usize) -> Result<Vec<Vec<f32>>> {
        PredictSession { rt: self.rt.clone(), variant: self.variant.clone() }
            .logits(&self.params, xs, rows, self.batch, self.features)
    }
}

/// Stateless prediction over explicit parameters.
pub struct PredictSession {
    pub rt: Rc<Runtime>,
    pub variant: String,
}

impl PredictSession {
    /// Compute logits for `rows` examples (padded to the AOT batch).
    pub fn logits(
        &self,
        params: &[HostTensor],
        xs: &[f32],
        rows: usize,
        batch: usize,
        features: usize,
    ) -> Result<Vec<Vec<f32>>> {
        if rows == 0 || rows > batch {
            bail!("logits wants 1..={batch} rows, got {rows}");
        }
        let mut xbuf = vec![0.0f32; batch * features];
        xbuf[..rows * features].copy_from_slice(&xs[..rows * features]);
        let mut inputs = params.to_vec();
        inputs.push(HostTensor::new(xbuf, vec![batch, features])?);
        let outs = self.rt.execute(&format!("{}/predict", self.variant), &inputs)?;
        let logits = &outs[0];
        if logits.dims.len() != 2 || logits.dims[0] != batch {
            bail!("predict returned unexpected shape {:?}", logits.dims);
        }
        let classes = logits.dims[1];
        Ok((0..rows).map(|r| logits.data[r * classes..(r + 1) * classes].to_vec()).collect())
    }
}

/// Stateless pruning over explicit parameters (used by the checkpoint store
/// when compressing a sub-model after training).
pub struct PruneSession {
    pub rt: Rc<Runtime>,
    pub variant: String,
}

impl PruneSession {
    pub fn prune(&self, params: &[HostTensor], keep_frac: f32) -> Result<Vec<HostTensor>> {
        let mut inputs = params.to_vec();
        inputs.push(HostTensor::scalar(keep_frac));
        self.rt.execute(&format!("{}/prune", self.variant), &inputs)
    }
}
