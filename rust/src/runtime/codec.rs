//! Sparse/delta tensor codec for checkpoint payloads.
//!
//! The paper's memory claim (§4.2, Table 2) is that pruning lets more
//! sub-models fit in the device budget C_m. Before this codec the claim was
//! only *accounted*: `Checkpoint` held dense `f32` tensors, so a keep=0.3
//! pruned model occupied exactly as many real bytes as a dense one. Here
//! the stored payload is an [`EncodedParams`] and the checkpoint's
//! `size_bytes` is derived from the encoding, not from a profile formula —
//! bytes become the store's actual currency.
//!
//! Per tensor the codec picks the cheapest representation:
//!
//! * **dense** — the raw row-major f32 payload. Always available; the
//!   fallback when sparsity doesn't pay.
//! * **sparse** — one bitmask bit per element (64 elements per `u64` word)
//!   plus the non-zero values in index order. Pays once the tensor is
//!   roughly 1/32 + ε sparse ([`CodecMode::Sparse`] and up).
//! * **delta** — changed-entries-only against the lineage's previous
//!   stored payload ([`CodecMode::Delta`] only): a bitmask of positions
//!   whose f32 *bits* differ from the parent plus the new values. The
//!   parent payload is pinned alive through an `Arc`; chain depth is
//!   bounded by [`MAX_DELTA_DEPTH`] so decode cost and parent retention
//!   stay O(1) per checkpoint no matter how long a lineage trains.
//!
//! ## Exactness
//!
//! Decode is bit-exact for dense and delta blocks. Sparse blocks
//! canonicalize `-0.0` to `+0.0` (IEEE-equal: `-0.0 == 0.0`, so round
//! trips satisfy `PartialEq` — see [`HostTensor::nonzero_count`]). NaN
//! values round-trip bit-exactly through every block kind but fail
//! `PartialEq` by IEEE definition; model parameters are finite.
//!
//! ## Accounting caveat (delta)
//!
//! A delta payload's [`EncodedParams::size_bytes`] charges only the bytes
//! it *owns*; the pinned parent is accounted to the parent's own
//! checkpoint. When the parent checkpoint is evicted from the store while
//! deltas still reference it, its payload stays resident until the deltas
//! die — bounded by [`MAX_DELTA_DEPTH`], and measurable through
//! [`EncodedParams::retained_bytes`]. The default mode is
//! [`CodecMode::Sparse`], which has no such retention.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::tensor::HostTensor;

/// Process-wide payload identity source. Every [`EncodedParams`] gets a
/// unique id at encode time; the durability layer serializes it so
/// recovery can re-establish `Arc` sharing across a spilled delta chain
/// (two checkpoints that shared a parent payload in memory share it again
/// after replay — which keeps identity-based byte accounting stable).
static NEXT_PAYLOAD_UID: AtomicU64 = AtomicU64::new(1);

fn fresh_uid() -> u64 {
    NEXT_PAYLOAD_UID.fetch_add(1, Ordering::Relaxed)
}

/// Fixed bytes charged per encoded payload (tensor count, parent link,
/// chain depth).
pub const PARAMS_HEADER_BYTES: u64 = 16;

/// Fixed bytes charged per encoded tensor (representation tag plus
/// element/value counts), on top of 8 bytes per dimension.
pub const TENSOR_HEADER_BYTES: u64 = 16;

/// Bound on delta chain length: a payload at this depth encodes
/// self-contained (sparse/dense), so decoding any checkpoint touches at
/// most `MAX_DELTA_DEPTH + 1` payloads.
pub const MAX_DELTA_DEPTH: u32 = 3;

/// Header bytes for a tensor with the given shape.
fn header_bytes(dims: &[usize]) -> u64 {
    TENSOR_HEADER_BYTES + 8 * dims.len() as u64
}

/// One tensor's encoded block.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorBlock {
    /// Raw row-major payload.
    Dense { data: Vec<f32> },
    /// Bit i set ⇔ element i is non-zero; `values` holds the non-zero
    /// entries in index order.
    Sparse { mask: Vec<u64>, values: Vec<f32> },
    /// Bit i set ⇔ element i's f32 bits differ from the parent tensor;
    /// `values` holds the changed entries in index order.
    Delta { mask: Vec<u64>, values: Vec<f32> },
}

/// An encoded tensor: shape plus payload block.
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedTensor {
    pub dims: Vec<usize>,
    pub block: TensorBlock,
}

/// Write `values` into `out` at the positions whose mask bit is set.
fn scatter(mask: &[u64], values: &[f32], out: &mut [f32]) {
    let mut vi = 0;
    for (w, word) in mask.iter().enumerate() {
        let mut bits = *word;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            out[w * 64 + b] = values[vi];
            vi += 1;
            bits &= bits - 1;
        }
    }
    debug_assert_eq!(vi, values.len(), "mask popcount must equal value count");
}

/// Bitmask + values of the non-zero entries (`-0.0` counts as zero and
/// therefore canonicalizes to `+0.0` on decode).
fn sparse_block(t: &HostTensor) -> (Vec<u64>, Vec<f32>) {
    let mut mask = vec![0u64; t.len().div_ceil(64)];
    let mut values = Vec::new();
    for (i, v) in t.data.iter().enumerate() {
        if *v != 0.0 {
            mask[i / 64] |= 1u64 << (i % 64);
            values.push(*v);
        }
    }
    (mask, values)
}

/// Bitmask + values of the entries whose f32 bits differ from `parent`
/// (bit-exact, so `-0.0` vs `0.0` counts as a change). `None` when the
/// shapes disagree.
fn delta_block(t: &HostTensor, parent: &HostTensor) -> Option<(Vec<u64>, Vec<f32>)> {
    if t.dims != parent.dims {
        return None;
    }
    let mut mask = vec![0u64; t.len().div_ceil(64)];
    let mut values = Vec::new();
    for (i, (v, p)) in t.data.iter().zip(&parent.data).enumerate() {
        if v.to_bits() != p.to_bits() {
            mask[i / 64] |= 1u64 << (i % 64);
            values.push(*v);
        }
    }
    Some((mask, values))
}

impl EncodedTensor {
    /// Encoded size: header plus payload.
    pub fn size_bytes(&self) -> u64 {
        let payload = match &self.block {
            TensorBlock::Dense { data } => 4 * data.len() as u64,
            TensorBlock::Sparse { mask, values } | TensorBlock::Delta { mask, values } => {
                8 * mask.len() as u64 + 4 * values.len() as u64
            }
        };
        header_bytes(&self.dims) + payload
    }

    /// Size the same tensor would take encoded dense — the codec's
    /// worst-case bound (`size_bytes() <= dense_size_bytes()` always).
    pub fn dense_size_bytes(&self) -> u64 {
        header_bytes(&self.dims) + 4 * self.dims.iter().product::<usize>() as u64
    }

    pub fn is_delta(&self) -> bool {
        matches!(self.block, TensorBlock::Delta { .. })
    }

    /// Decode to a host tensor. `parent` is required iff the block is a
    /// delta.
    fn decode(&self, parent: Option<&HostTensor>) -> HostTensor {
        let n: usize = self.dims.iter().product();
        let data = match &self.block {
            TensorBlock::Dense { data } => data.clone(),
            TensorBlock::Sparse { mask, values } => {
                let mut data = vec![0.0f32; n];
                scatter(mask, values, &mut data);
                data
            }
            TensorBlock::Delta { mask, values } => {
                let p = parent.expect("delta block decoded without its parent");
                debug_assert_eq!(p.dims, self.dims, "delta parent shape mismatch");
                let mut data = p.data.clone();
                scatter(mask, values, &mut data);
                data
            }
        };
        HostTensor { data, dims: self.dims.clone() }
    }
}

/// A checkpoint's full encoded parameter payload.
#[derive(Clone, Debug)]
pub struct EncodedParams {
    pub tensors: Vec<EncodedTensor>,
    /// Delta base the `Delta` blocks diff against; `None` for
    /// self-contained payloads.
    parent: Option<Arc<EncodedParams>>,
    /// Length of the parent chain under this payload (0 = self-contained).
    depth: u32,
    /// Process-unique payload identity (see [`NEXT_PAYLOAD_UID`]).
    uid: u64,
}

/// Payload equality is structural; the identity `uid` is deliberately
/// excluded (a recovered payload equals the payload it was spilled from).
impl PartialEq for EncodedParams {
    fn eq(&self, other: &Self) -> bool {
        self.tensors == other.tensors
            && self.depth == other.depth
            && self.parent == other.parent
    }
}

impl EncodedParams {
    /// Bytes this payload owns (headers + blocks). A delta's pinned parent
    /// is accounted to the parent's own checkpoint — see the module docs.
    pub fn size_bytes(&self) -> u64 {
        PARAMS_HEADER_BYTES + self.tensors.iter().map(|t| t.size_bytes()).sum::<u64>()
    }

    /// Bytes the same payload would take encoded dense (compression-ratio
    /// denominator).
    pub fn dense_size_bytes(&self) -> u64 {
        PARAMS_HEADER_BYTES + self.tensors.iter().map(|t| t.dense_size_bytes()).sum::<u64>()
    }

    /// Bytes kept resident by this payload including pinned delta parents.
    pub fn retained_bytes(&self) -> u64 {
        self.size_bytes() + self.parent.as_ref().map_or(0, |p| p.retained_bytes())
    }

    /// Delta chain length under this payload.
    pub fn delta_depth(&self) -> u32 {
        self.depth
    }

    pub fn is_delta(&self) -> bool {
        self.parent.is_some()
    }

    /// Process-unique payload identity (stable across checkpoint spill +
    /// recovery, so identity-keyed accounting replays exactly).
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// The pinned delta base, if any (chain walking: spill serialization
    /// and pinned-parent byte accounting).
    pub fn parent(&self) -> Option<&Arc<EncodedParams>> {
        self.parent.as_ref()
    }

    /// Rebuild a payload from serialized parts (checkpoint spill
    /// recovery). `uid` is the payload's original identity; the global uid
    /// counter is bumped past it so payloads encoded after recovery can
    /// never collide with recovered ones.
    pub fn from_parts(
        tensors: Vec<EncodedTensor>,
        parent: Option<Arc<EncodedParams>>,
        uid: u64,
    ) -> EncodedParams {
        NEXT_PAYLOAD_UID.fetch_max(uid.saturating_add(1), Ordering::Relaxed);
        let depth = parent.as_ref().map_or(0, |p| p.depth + 1);
        EncodedParams { tensors, parent, depth, uid }
    }

    /// Decode the full parameter set (resolves the delta chain).
    pub fn decode(&self) -> Vec<HostTensor> {
        let parent = self.parent.as_ref().map(|p| p.decode());
        self.tensors
            .iter()
            .enumerate()
            .map(|(i, t)| t.decode(parent.as_ref().and_then(|ps| ps.get(i))))
            .collect()
    }
}

/// A payload plus every parent its delta chain pins via `Arc`, child
/// first. Shared by the store's identity-keyed byte accounting and the
/// durability layer's payload spill — one walk, one semantics. Bounded by
/// [`MAX_DELTA_DEPTH`], so it is O(1) per payload.
pub fn payload_chain(p: &Arc<EncodedParams>) -> Vec<Arc<EncodedParams>> {
    let mut cur = p.clone();
    let mut out = vec![cur.clone()];
    loop {
        let next = match cur.parent() {
            Some(n) => n.clone(),
            None => break,
        };
        out.push(next.clone());
        cur = next;
    }
    out
}

/// Which representations the codec may pick.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CodecMode {
    /// Dense blocks only — the pre-codec representation, byte for byte.
    Dense,
    /// Best of sparse/dense per tensor (the default: self-contained
    /// payloads, no cross-checkpoint retention).
    #[default]
    Sparse,
    /// Best of delta/sparse/dense per tensor; deltas chain up to
    /// [`MAX_DELTA_DEPTH`].
    Delta,
}

impl CodecMode {
    pub fn by_name(name: &str) -> Option<CodecMode> {
        match name.to_ascii_lowercase().as_str() {
            "dense" | "none" => Some(CodecMode::Dense),
            "sparse" => Some(CodecMode::Sparse),
            "delta" | "sparse-delta" | "sparse_delta" => Some(CodecMode::Delta),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecMode::Dense => "dense",
            CodecMode::Sparse => "sparse",
            CodecMode::Delta => "delta",
        }
    }
}

/// The checkpoint payload codec. Stateless; cheap to copy.
#[derive(Clone, Copy, Debug, Default)]
pub struct TensorCodec {
    pub mode: CodecMode,
}

impl TensorCodec {
    pub fn new(mode: CodecMode) -> Self {
        Self { mode }
    }

    /// Encode a parameter set. `parent` is the same lineage's previous
    /// stored payload (the delta base candidate); it is consulted only in
    /// [`CodecMode::Delta`], only when its chain is shallower than
    /// [`MAX_DELTA_DEPTH`], and only when the tensor counts line up.
    pub fn encode(
        &self,
        params: &[HostTensor],
        parent: Option<&Arc<EncodedParams>>,
    ) -> EncodedParams {
        let parent = match self.mode {
            CodecMode::Delta => parent
                .filter(|p| p.depth < MAX_DELTA_DEPTH && p.tensors.len() == params.len()),
            _ => None,
        };
        let parent_decoded = parent.map(|p| p.decode());
        let mut tensors = Vec::with_capacity(params.len());
        let mut used_delta = false;
        for (i, t) in params.iter().enumerate() {
            let enc =
                self.encode_tensor(t, parent_decoded.as_ref().and_then(|ps| ps.get(i)));
            used_delta |= enc.is_delta();
            tensors.push(enc);
        }
        if used_delta {
            let p = parent.expect("delta blocks imply a parent").clone();
            EncodedParams { tensors, depth: p.depth + 1, parent: Some(p), uid: fresh_uid() }
        } else {
            EncodedParams { tensors, parent: None, depth: 0, uid: fresh_uid() }
        }
    }

    /// Pick the cheapest block for one tensor. Ties prefer the simpler
    /// representation (dense > sparse > delta), so a fully-dense tensor
    /// always falls back to a plain payload.
    fn encode_tensor(&self, t: &HostTensor, parent: Option<&HostTensor>) -> EncodedTensor {
        let dense_payload = 4 * t.len() as u64;
        let mut best: Option<(u64, TensorBlock)> = None;
        if self.mode != CodecMode::Dense {
            let (mask, values) = sparse_block(t);
            let bytes = 8 * mask.len() as u64 + 4 * values.len() as u64;
            if bytes < dense_payload {
                best = Some((bytes, TensorBlock::Sparse { mask, values }));
            }
        }
        if self.mode == CodecMode::Delta {
            if let Some((mask, values)) = parent.and_then(|p| delta_block(t, p)) {
                let bytes = 8 * mask.len() as u64 + 4 * values.len() as u64;
                let beats_sparse = match &best {
                    Some((b, _)) => bytes < *b,
                    None => true,
                };
                if bytes < dense_payload && beats_sparse {
                    best = Some((bytes, TensorBlock::Delta { mask, values }));
                }
            }
        }
        let block = match best {
            Some((_, block)) => block,
            None => TensorBlock::Dense { data: t.data.clone() },
        };
        EncodedTensor { dims: t.dims.clone(), block }
    }
}

/// Per-plan decode cache: a checkpoint referenced several times while one
/// plan executes (multi-step chains, serving restores) decodes once; every
/// later use clones the `Arc`, never the tensors. Keyed by the caller —
/// the engine uses the checkpoint id.
#[derive(Debug, Default)]
pub struct DecodeCache {
    map: HashMap<u64, Arc<[HostTensor]>>,
    /// Payload decodes performed (cache misses).
    pub decodes: u64,
    /// Lookups served without decoding.
    pub hits: u64,
}

impl DecodeCache {
    /// Decoded tensors for `enc`, decoding at most once per key.
    pub fn decoded(&mut self, key: u64, enc: &EncodedParams) -> Arc<[HostTensor]> {
        if let Some(hit) = self.map.get(&key) {
            self.hits += 1;
            return hit.clone();
        }
        self.decodes += 1;
        let arc: Arc<[HostTensor]> = enc.decode().into();
        self.map.insert(key, arc.clone());
        arc
    }

    /// Drop the cached decodes but keep the counters — callers scope dense
    /// tensor memory (the engine releases after every retrain chain, since
    /// checkpoints are lineage-scoped and cannot be reused across chains)
    /// without losing dedup statistics.
    pub fn release(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::testkit::forall;

    fn roundtrip(mode: CodecMode, params: &[HostTensor]) {
        let codec = TensorCodec::new(mode);
        let enc = codec.encode(params, None);
        assert_eq!(enc.decode(), params.to_vec(), "round-trip under {mode:?}");
        assert!(
            enc.size_bytes() <= enc.dense_size_bytes(),
            "encoded {} > dense bound {}",
            enc.size_bytes(),
            enc.dense_size_bytes()
        );
    }

    #[test]
    fn handcrafted_shapes_roundtrip() {
        let cases: Vec<Vec<HostTensor>> = vec![
            vec![],
            vec![HostTensor::scalar(3.5)],
            vec![HostTensor::zeros(&[0])],
            vec![HostTensor::zeros(&[7, 3])],
            vec![HostTensor::from_fn(&[9], |i| i as f32 + 1.0)],
            vec![
                HostTensor::from_fn(&[65], |i| if i == 64 { 2.0 } else { 0.0 }),
                HostTensor::from_fn(&[2, 2], |i| -(i as f32)),
            ],
        ];
        for params in &cases {
            for mode in [CodecMode::Dense, CodecMode::Sparse, CodecMode::Delta] {
                roundtrip(mode, params);
            }
        }
    }

    #[test]
    fn negative_zero_canonicalizes_but_stays_equal() {
        let t = HostTensor { data: vec![-0.0, 1.0, 0.0, -0.0], dims: vec![4] };
        let enc = TensorCodec::new(CodecMode::Sparse).encode(std::slice::from_ref(&t), None);
        let dec = enc.decode();
        // IEEE: -0.0 == 0.0, so PartialEq round-trips...
        assert_eq!(dec[0], t);
        // ...even though sparse decoding canonicalized the sign bit away.
        assert_eq!(dec[0].data[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(dec[0].data[1], 1.0);
    }

    #[test]
    fn sparse_pays_only_when_sparse_enough() {
        let dense = HostTensor::from_fn(&[128], |i| i as f32 + 1.0);
        let sparse = HostTensor::from_fn(&[128], |i| if i % 16 == 0 { 1.0 } else { 0.0 });
        let codec = TensorCodec::new(CodecMode::Sparse);
        let e_dense = codec.encode(std::slice::from_ref(&dense), None);
        let e_sparse = codec.encode(std::slice::from_ref(&sparse), None);
        assert!(matches!(e_dense.tensors[0].block, TensorBlock::Dense { .. }));
        assert!(matches!(e_sparse.tensors[0].block, TensorBlock::Sparse { .. }));
        assert!(e_sparse.size_bytes() < e_dense.size_bytes() / 2);
    }

    #[test]
    fn delta_encodes_small_changes_and_decodes_bit_exact() {
        let base = vec![HostTensor::from_fn(&[256], |i| (i as f32).sin())];
        let codec = TensorCodec::new(CodecMode::Delta);
        let parent = Arc::new(codec.encode(&base, None));
        let mut child = base.clone();
        child[0].data[17] = -0.0; // sign-bit-only change must be detected
        child[0].data[200] = 9.25;
        let enc = codec.encode(&child, Some(&parent));
        assert!(enc.is_delta());
        assert_eq!(enc.delta_depth(), 1);
        match &enc.tensors[0].block {
            TensorBlock::Delta { values, .. } => assert_eq!(values.len(), 2),
            other => panic!("expected delta block, got {other:?}"),
        }
        let dec = enc.decode();
        assert_eq!(dec[0].data[17].to_bits(), (-0.0f32).to_bits(), "bit-exact delta");
        assert_eq!(dec, child);
        assert!(enc.size_bytes() < parent.size_bytes() / 2);
        assert_eq!(enc.retained_bytes(), enc.size_bytes() + parent.size_bytes());
    }

    #[test]
    fn delta_chain_depth_is_bounded() {
        let codec = TensorCodec::new(CodecMode::Delta);
        let mut params = vec![HostTensor::from_fn(&[128], |i| (i as f32).cos())];
        let mut parent = Arc::new(codec.encode(&params, None));
        for step in 0..2 * MAX_DELTA_DEPTH {
            params[0].data[(step as usize * 7) % 128] += 1.0;
            let enc = Arc::new(codec.encode(&params, Some(&parent)));
            assert_eq!(enc.decode(), params, "chain step {step}");
            assert!(
                enc.delta_depth() <= MAX_DELTA_DEPTH,
                "depth {} exceeds cap",
                enc.delta_depth()
            );
            parent = enc;
        }
        // Non-delta modes ignore the parent entirely.
        let flat = TensorCodec::new(CodecMode::Sparse).encode(&params, Some(&parent));
        assert!(!flat.is_delta());
    }

    #[test]
    fn prop_roundtrip_and_size_bound_random_tensors() {
        forall(
            0xc0dec,
            80,
            |rng, size| {
                let mode = match rng.range(0, 3) {
                    0 => CodecMode::Dense,
                    1 => CodecMode::Sparse,
                    _ => CodecMode::Delta,
                };
                let n_tensors = rng.range(0, 4);
                let params: Vec<HostTensor> = (0..n_tensors)
                    .map(|_| {
                        let dims: Vec<usize> = match rng.range(0, 4) {
                            0 => vec![],                                  // scalar
                            1 => vec![rng.range(0, 1 + (40.0 * size) as usize)],
                            2 => vec![rng.range(1, 9), rng.range(0, 9)],
                            _ => vec![rng.range(1, 5), rng.range(1, 5), rng.range(1, 5)],
                        };
                        let density = rng.f64(); // 0 = all-zero .. 1 = fully dense
                        let mut r2 = rng.fork(7);
                        HostTensor::from_fn(&dims, move |_| {
                            if r2.f64() < density {
                                r2.f32() * 4.0 - 2.0
                            } else {
                                0.0
                            }
                        })
                    })
                    .collect();
                (mode, params)
            },
            |(mode, params)| {
                let codec = TensorCodec::new(*mode);
                let enc = codec.encode(params, None);
                if enc.decode() != *params {
                    return Err("round-trip mismatch".into());
                }
                if enc.size_bytes() > enc.dense_size_bytes() {
                    return Err(format!(
                        "encoded {} exceeds dense bound {}",
                        enc.size_bytes(),
                        enc.dense_size_bytes()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_delta_roundtrip_against_perturbed_parent() {
        forall(
            0xde17a,
            60,
            |rng, size| {
                let n = 1 + (120.0 * size) as usize;
                let mut r2 = rng.fork(3);
                let base = HostTensor::from_fn(&[n], move |_| r2.f32() - 0.5);
                let mut child = base.clone();
                let changes = rng.range(0, n.min(16) + 1);
                for _ in 0..changes {
                    let i = rng.range(0, n);
                    child.data[i] = rng.f32() * 8.0 - 4.0;
                }
                (base, child)
            },
            |(base, child)| {
                let codec = TensorCodec::new(CodecMode::Delta);
                let parent = Arc::new(codec.encode(std::slice::from_ref(base), None));
                let enc = codec.encode(std::slice::from_ref(child), Some(&parent));
                if enc.decode() != vec![child.clone()] {
                    return Err("delta round-trip mismatch".into());
                }
                if enc.size_bytes() > enc.dense_size_bytes() {
                    return Err("delta exceeded dense bound".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decode_cache_decodes_once_per_key() {
        let codec = TensorCodec::new(CodecMode::Sparse);
        let enc = codec.encode(&[HostTensor::from_fn(&[64], |i| i as f32)], None);
        let mut cache = DecodeCache::default();
        let a = cache.decoded(9, &enc);
        let b = cache.decoded(9, &enc);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.decodes, cache.hits), (1, 1));
        let c = cache.decoded(10, &enc);
        assert_eq!(cache.decodes, 2);
        assert_eq!(a.as_ref(), c.as_ref());
        // release() drops the memory but keeps the statistics.
        cache.release();
        let d = cache.decoded(9, &enc);
        assert!(!Arc::ptr_eq(&a, &d), "released entries must re-decode");
        assert_eq!((cache.decodes, cache.hits), (3, 1));
    }

    #[test]
    fn uids_are_unique_and_from_parts_preserves_structure() {
        let codec = TensorCodec::new(CodecMode::Delta);
        let base = vec![HostTensor::from_fn(&[64], |i| (i as f32).sin())];
        let parent = Arc::new(codec.encode(&base, None));
        let mut child = base.clone();
        child[0].data[5] = 9.0;
        let enc = codec.encode(&child, Some(&parent));
        assert_ne!(enc.uid(), parent.uid(), "uids must be unique");
        assert!(Arc::ptr_eq(enc.parent().expect("delta has parent"), &parent));
        // Rebuild from parts (what checkpoint-spill recovery does): same
        // structure, same uid, equal payload, bit-exact decode.
        let rebuilt = EncodedParams::from_parts(
            enc.tensors.clone(),
            Some(parent.clone()),
            enc.uid(),
        );
        assert_eq!(rebuilt, enc, "structural equality ignores nothing else");
        assert_eq!(rebuilt.uid(), enc.uid());
        assert_eq!(rebuilt.delta_depth(), enc.delta_depth());
        assert_eq!(rebuilt.decode(), child);
        // The uid floor was bumped: fresh encodes stay unique even after
        // restoring a payload with a large recovered uid.
        let restored = EncodedParams::from_parts(enc.tensors.clone(), None, 1 << 40);
        let fresh = codec.encode(&base, None);
        assert!(fresh.uid() > restored.uid(), "uid floor must advance");
    }

    #[test]
    fn mode_names_roundtrip() {
        for mode in [CodecMode::Dense, CodecMode::Sparse, CodecMode::Delta] {
            assert_eq!(CodecMode::by_name(mode.name()), Some(mode));
        }
        assert_eq!(CodecMode::by_name("sparse-delta"), Some(CodecMode::Delta));
        assert!(CodecMode::by_name("gzip").is_none());
        assert_eq!(CodecMode::default(), CodecMode::Sparse);
    }

    /// Generator sanity: the property above must actually see empty,
    /// all-zero and fully-dense tensors (guard against generator drift).
    #[test]
    fn generator_covers_degenerate_shapes() {
        let mut rng = Rng::new(5);
        let (mut saw_empty, mut saw_zero, mut saw_dense) = (false, false, false);
        for _ in 0..400 {
            let n = rng.range(0, 30);
            let density = rng.f64();
            let t = HostTensor::from_fn(&[n], |_| if rng.f64() < density { 1.0 } else { 0.0 });
            saw_empty |= t.is_empty();
            saw_zero |= !t.is_empty() && t.nonzero_count() == 0;
            saw_dense |= !t.is_empty() && t.nonzero_count() == t.len();
        }
        assert!(saw_empty && saw_zero && saw_dense);
    }
}
