//! The PJRT runtime: one CPU client per process, one compiled executable per
//! artifact, and a literal-in/literal-out execute wrapper with stats.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactManifest, ArtifactSpec};
use super::tensor::HostTensor;
use crate::xla;

/// Cumulative execution statistics, used by the perf harness.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
    /// Host<->device literal conversion time.
    pub transfer_secs: f64,
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// Per-thread PJRT runtime with an executable cache.
///
/// The `xla` crate's PJRT handles are `Rc`-based (single-threaded); share a
/// `Runtime` within one thread via `Rc<Runtime>`. Interior mutability uses
/// `RefCell` accordingly.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a runtime over `artifacts/` (manifest + HLO files).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: RefCell::new(HashMap::new()), stats: RefCell::new(RuntimeStats::default()) })
    }

    /// The artifact manifest backing this runtime.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// PJRT platform (e.g. "cpu") — handy for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Snapshot of cumulative stats.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn compiled(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += dt;
        }
        let c = Rc::new(Compiled { exe, spec });
        self.cache.borrow_mut().insert(name.to_string(), c.clone());
        Ok(c)
    }

    /// Warm the executable cache for a list of artifacts (startup path).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with the given inputs; returns the flattened
    /// output tuple as host tensors.
    ///
    /// Inputs are validated against the manifest signature so shape bugs
    /// surface as readable errors instead of PJRT aborts.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let c = self.compiled(name)?;
        if inputs.len() != c.spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                name,
                c.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&c.spec.inputs).enumerate() {
            if t.dims != spec.dims {
                bail!(
                    "artifact '{}' input #{i} ('{}'): expected dims {:?}, got {:?}",
                    name,
                    spec.name,
                    spec.dims,
                    t.dims
                );
            }
        }

        let t0 = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t1 = Instant::now();
        let bufs = c
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of '{name}'"))?;
        let t2 = Instant::now();

        // aot.py lowers with return_tuple=True, so outputs are always a tuple.
        let parts = result.to_tuple().context("unpacking result tuple")?;
        if parts.len() != c.spec.outputs.len() {
            bail!(
                "artifact '{}' declared {} outputs but returned {}",
                name,
                c.spec.outputs.len(),
                parts.len()
            );
        }
        let outs: Vec<HostTensor> =
            parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        let t3 = Instant::now();
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_secs += (t2 - t1).as_secs_f64();
            s.transfer_secs += (t1 - t0).as_secs_f64() + (t3 - t2).as_secs_f64();
        }
        Ok(outs)
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}
