//! Host-side tensors and conversion to/from `xla::Literal`.

use anyhow::{bail, Context, Result};

use crate::xla;

/// A dense f32 tensor living on the host.
///
/// All model state crossing the PJRT boundary is f32 in this reproduction
/// (the paper's edge models train in fp32 on the Jetson Orin Nano; bf16 is a
/// TPU-side optimization discussed in DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions; empty means scalar.
    pub dims: Vec<usize>,
}

impl HostTensor {
    /// Create a tensor, checking that `data.len()` matches the shape.
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Result<Self> {
        let expect: usize = dims.iter().product();
        if data.len() != expect {
            bail!(
                "HostTensor shape mismatch: data len {} but dims {:?} imply {}",
                data.len(),
                dims,
                expect
            );
        }
        Ok(Self { data, dims })
    }

    /// A scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], dims: vec![] }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        Self { data: vec![0.0; n], dims: dims.to_vec() }
    }

    /// Fill with values produced by `f(flat_index)`.
    pub fn from_fn(dims: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n: usize = dims.iter().product();
        Self { data: (0..n).map(&mut f).collect(), dims: dims.to_vec() }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Scalar value (errors unless exactly one element).
    pub fn as_scalar(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("expected scalar, got {} elements (dims {:?})", self.data.len(), self.dims);
        }
        Ok(self.data[0])
    }

    /// Bytes occupied by the payload (f32).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Count of non-zero entries — used by the pruning accounting tests.
    pub fn nonzero_count(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Convert to an `xla::Literal` with this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.dims.is_empty() {
            // Scalar: reshape to rank-0.
            lit.reshape(&[]).context("reshape to scalar literal")
        } else {
            let dims: Vec<i64> = self.dims.iter().map(|d| *d as i64).collect();
            lit.reshape(&dims).context("reshape literal")
        }
    }

    /// Build from an `xla::Literal` (f32 only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.shape().context("literal shape")?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|d| *d as usize).collect(),
            other => bail!("expected array literal, got {other:?}"),
        };
        let data = lit.to_vec::<f32>().context("literal to_vec<f32>")?;
        Self::new(data, dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(HostTensor::new(vec![1.0, 2.0], vec![3]).is_err());
        let t = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t.size_bytes(), 16);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(3.5);
        assert_eq!(t.as_scalar().unwrap(), 3.5);
        assert!(HostTensor::zeros(&[2]).as_scalar().is_err());
    }

    #[test]
    fn from_fn_fills() {
        let t = HostTensor::from_fn(&[2, 3], |i| i as f32);
        assert_eq!(t.data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(t.nonzero_count(), 5);
    }
}
