//! Host-side tensors and conversion to/from `xla::Literal`.

use anyhow::{bail, Context, Result};

use crate::xla;

/// A dense f32 tensor living on the host.
///
/// All model state crossing the PJRT boundary is f32 in this reproduction
/// (the paper's edge models train in fp32 on the Jetson Orin Nano; bf16 is a
/// TPU-side optimization discussed in DESIGN.md §Hardware-Adaptation).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    /// Row-major data.
    pub data: Vec<f32>,
    /// Dimensions; empty means scalar.
    pub dims: Vec<usize>,
}

impl HostTensor {
    /// Create a tensor, checking that `data.len()` matches the shape.
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Result<Self> {
        let expect: usize = dims.iter().product();
        if data.len() != expect {
            bail!(
                "HostTensor shape mismatch: data len {} but dims {:?} imply {}",
                data.len(),
                dims,
                expect
            );
        }
        Ok(Self { data, dims })
    }

    /// A scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], dims: vec![] }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        Self { data: vec![0.0; n], dims: dims.to_vec() }
    }

    /// Fill with values produced by `f(flat_index)`.
    pub fn from_fn(dims: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n: usize = dims.iter().product();
        Self { data: (0..n).map(&mut f).collect(), dims: dims.to_vec() }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Scalar value (errors unless exactly one element).
    pub fn as_scalar(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("expected scalar, got {} elements (dims {:?})", self.data.len(), self.dims);
        }
        Ok(self.data[0])
    }

    /// Bytes occupied by the payload (f32).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Count of non-zero entries — used by the pruning accounting tests
    /// and the sparse codec. The `!= 0.0` comparison is IEEE-754: `-0.0 ==
    /// 0.0`, so negative zero deliberately counts as zero (and the sparse
    /// codec canonicalizes it to `+0.0` on decode, which stays
    /// `PartialEq`-equal to the original).
    pub fn nonzero_count(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Fraction of zero entries (0.0 for an empty tensor); `-0.0` counts
    /// as zero, mirroring [`HostTensor::nonzero_count`].
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        1.0 - self.nonzero_count() as f64 / self.data.len() as f64
    }

    /// Magnitude threshold below which pruning at `keep` zeroes an entry:
    /// the k-th largest `|v|` where `k = ceil(keep * len)`, so keeping
    /// every `|v| >= threshold` retains at least `keep * len` entries
    /// (ties at the threshold are kept). `keep >= 1` returns 0.0 (keep
    /// everything); `keep <= 0` returns +∞ (drop everything). Shared by
    /// the host prune path and the codec so threshold semantics never
    /// diverge.
    pub fn magnitude_threshold(data: &[f32], keep: f64) -> f32 {
        if data.is_empty() || keep >= 1.0 {
            return 0.0;
        }
        if keep <= 0.0 {
            return f32::INFINITY;
        }
        let k = ((keep * data.len() as f64).ceil() as usize).clamp(1, data.len());
        let mut mags: Vec<f32> = data.iter().map(|v| v.abs()).collect();
        // Selection, not a full sort: this sits on the prune-aware
        // snapshot hot path (every tensor of every training run).
        let (_, kth, _) = mags.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
        *kth
    }

    /// Apply the magnitude mask for `keep` in place (zero every entry
    /// whose `|v|` falls below [`HostTensor::magnitude_threshold`]).
    /// Returns how many entries were zeroed.
    pub fn apply_mask(&mut self, keep: f64) -> usize {
        let threshold = Self::magnitude_threshold(&self.data, keep);
        let mut zeroed = 0;
        for v in &mut self.data {
            if v.abs() < threshold && *v != 0.0 {
                *v = 0.0;
                zeroed += 1;
            }
        }
        zeroed
    }

    /// Convert to an `xla::Literal` with this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.dims.is_empty() {
            // Scalar: reshape to rank-0.
            lit.reshape(&[]).context("reshape to scalar literal")
        } else {
            let dims: Vec<i64> = self.dims.iter().map(|d| *d as i64).collect();
            lit.reshape(&dims).context("reshape literal")
        }
    }

    /// Build from an `xla::Literal` (f32 only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.shape().context("literal shape")?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|d| *d as usize).collect(),
            other => bail!("expected array literal, got {other:?}"),
        };
        let data = lit.to_vec::<f32>().context("literal to_vec<f32>")?;
        Self::new(data, dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(HostTensor::new(vec![1.0, 2.0], vec![3]).is_err());
        let t = HostTensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]).unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t.size_bytes(), 16);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(3.5);
        assert_eq!(t.as_scalar().unwrap(), 3.5);
        assert!(HostTensor::zeros(&[2]).as_scalar().is_err());
    }

    #[test]
    fn from_fn_fills() {
        let t = HostTensor::from_fn(&[2, 3], |i| i as f32);
        assert_eq!(t.data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(t.nonzero_count(), 5);
    }

    #[test]
    fn negative_zero_counts_as_zero() {
        let t = HostTensor { data: vec![-0.0, 0.0, 1.0], dims: vec![3] };
        assert_eq!(t.nonzero_count(), 1);
        assert!((t.sparsity() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(HostTensor::zeros(&[0]).sparsity(), 0.0);
    }

    #[test]
    fn apply_mask_keeps_top_magnitudes() {
        let mut t = HostTensor::new(vec![0.1, -4.0, 0.0, 2.0, -0.5, 3.0], vec![6]).unwrap();
        // keep = 0.5 over 6 entries → keep the 3 largest magnitudes.
        let zeroed = t.apply_mask(0.5);
        assert_eq!(zeroed, 2); // 0.1 and -0.5; the existing 0.0 stays free
        assert_eq!(t.data, vec![0.0, -4.0, 0.0, 2.0, 0.0, 3.0]);
        assert_eq!(t.nonzero_count(), 3);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn apply_mask_edges() {
        let mut t = HostTensor::new(vec![1.0, 2.0], vec![2]).unwrap();
        assert_eq!(t.apply_mask(1.0), 0); // keep >= 1: no-op
        assert_eq!(t.data, vec![1.0, 2.0]);
        assert_eq!(t.apply_mask(0.0), 2); // keep <= 0: drop everything
        assert_eq!(t.nonzero_count(), 0);
        // Empty tensors and ties are safe.
        assert_eq!(HostTensor::zeros(&[0]).apply_mask(0.5), 0);
        let mut ties = HostTensor::new(vec![1.0, -1.0, 1.0, 1.0], vec![4]).unwrap();
        // Threshold lands on the tie value: ties are kept, nothing zeroed.
        assert_eq!(ties.apply_mask(0.5), 0);
        assert_eq!(ties.nonzero_count(), 4);
    }

    #[test]
    fn magnitude_threshold_matches_kth_largest() {
        let data = [3.0f32, -7.0, 0.5, 2.0];
        assert_eq!(HostTensor::magnitude_threshold(&data, 0.25), 7.0);
        assert_eq!(HostTensor::magnitude_threshold(&data, 0.5), 3.0);
        assert_eq!(HostTensor::magnitude_threshold(&data, 1.0), 0.0);
        assert_eq!(HostTensor::magnitude_threshold(&data, 0.0), f32::INFINITY);
        assert_eq!(HostTensor::magnitude_threshold(&[], 0.5), 0.0);
    }
}
