//! PJRT runtime: load AOT-compiled HLO artifacts and execute them natively.
//!
//! This is the only module that touches the `xla` crate. The interchange
//! format with the build-time Python layer is **HLO text** (not serialized
//! `HloModuleProto`): jax >= 0.5 emits protos with 64-bit instruction ids
//! which xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `python/compile/aot.py`).
//!
//! Layout:
//! * [`client`] — process-wide PJRT CPU client plus an executable cache so
//!   each artifact is compiled exactly once per process.
//! * [`artifact`] — the artifact manifest (`artifacts/manifest.txt`) written
//!   by `python/compile/aot.py`: artifact name -> HLO file, input/output
//!   tensor specs.
//! * [`tensor`] — host-side tensors (`HostTensor`) and conversions to/from
//!   `xla::Literal`.
//! * [`codec`] — the sparse/delta checkpoint payload codec
//!   (`TensorCodec` / `EncodedParams`) and the per-plan `DecodeCache`;
//!   pure host code, no PJRT involvement.
//! * [`session`] — typed execution sessions: `TrainSession` (one train step
//!   per call), `PredictSession`, `PruneSession`.

pub mod artifact;
pub mod client;
pub mod codec;
pub mod session;
pub mod tensor;

pub use artifact::{ArtifactManifest, ArtifactSpec, TensorSpec};
pub use client::{Runtime, RuntimeStats};
pub use codec::{CodecMode, DecodeCache, EncodedParams, TensorCodec};
pub use session::{PredictSession, PruneSession, TrainSession};
pub use tensor::HostTensor;
