//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.txt` in a deliberately tiny
//! line format (no external parser dependencies are available offline):
//!
//! ```text
//! # comment
//! artifact <name>
//! file <relative-hlo-file>
//! input <tensor-name> f32 <d0>x<d1>x...   (scalar: "-")
//! output <tensor-name> f32 <dims>
//! meta <key> <value>
//! end
//! ```
//!
//! Rust uses the input specs to validate the literals it feeds each
//! executable and the output specs to unpack result tuples.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Shape/dtype of one tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// Dimensions; empty = scalar.
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * 4
    }
}

/// One AOT-compiled computation: an HLO text file plus its signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Fully-qualified name, e.g. `edge_mlp/train_step`.
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (param_count, flops_per_example, ...).
    pub meta: BTreeMap<String, String>,
}

impl ArtifactSpec {
    /// Metadata value parsed as f64.
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }

    /// Total bytes of all input tensors named `w*`/`b*` (the parameters).
    pub fn param_bytes(&self) -> usize {
        self.inputs
            .iter()
            .filter(|t| t.name.starts_with('w') || t.name.starts_with('b'))
            .map(|t| t.size_bytes())
            .sum()
    }
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` is where the HLO files live.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut artifacts = BTreeMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.splitn(2, ' ');
            let kw = it.next().unwrap_or("");
            let rest = it.next().unwrap_or("").trim();
            let err = |msg: &str| anyhow::anyhow!("manifest line {}: {}", lineno + 1, msg);
            match kw {
                "artifact" => {
                    if cur.is_some() {
                        bail!(err("nested artifact (missing 'end')"));
                    }
                    cur = Some(ArtifactSpec {
                        name: rest.to_string(),
                        file: PathBuf::new(),
                        inputs: vec![],
                        outputs: vec![],
                        meta: BTreeMap::new(),
                    });
                }
                "file" => {
                    cur.as_mut().ok_or_else(|| err("'file' outside artifact"))?.file =
                        PathBuf::from(rest);
                }
                "input" | "output" => {
                    let spec = parse_tensor_line(rest)
                        .ok_or_else(|| err("bad tensor line (want '<name> f32 <dims|->')"))?;
                    let a = cur.as_mut().ok_or_else(|| err("tensor outside artifact"))?;
                    if kw == "input" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "meta" => {
                    let mut kv = rest.splitn(2, ' ');
                    let k = kv.next().unwrap_or("").to_string();
                    let v = kv.next().unwrap_or("").trim().to_string();
                    cur.as_mut().ok_or_else(|| err("'meta' outside artifact"))?.meta.insert(k, v);
                }
                "end" => {
                    let a = cur.take().ok_or_else(|| err("'end' outside artifact"))?;
                    if a.file.as_os_str().is_empty() {
                        bail!(err("artifact missing 'file'"));
                    }
                    artifacts.insert(a.name.clone(), a);
                }
                other => bail!(err(&format!("unknown keyword '{other}'"))),
            }
        }
        if cur.is_some() {
            bail!("manifest ended inside an artifact block");
        }
        Ok(Self { dir, artifacts })
    }

    /// Look up an artifact, with a helpful error listing what exists.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{}' not in manifest (have: {})",
                name,
                self.artifacts.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Names of all artifacts for a given model variant (prefix match).
    pub fn variants(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .keys()
            .filter_map(|k| k.split('/').next().map(|s| s.to_string()))
            .collect();
        v.dedup();
        v
    }
}

fn parse_tensor_line(rest: &str) -> Option<TensorSpec> {
    let mut parts = rest.split_whitespace();
    let name = parts.next()?.to_string();
    let dtype = parts.next()?;
    if dtype != "f32" {
        return None;
    }
    let dims_s = parts.next()?;
    let dims = if dims_s == "-" {
        vec![]
    } else {
        dims_s.split('x').map(|d| d.parse::<usize>().ok()).collect::<Option<Vec<_>>>()?
    };
    Some(TensorSpec { name, dims })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# manifest
artifact edge_mlp/train_step
file edge_mlp_train_step.hlo.txt
input w1 f32 768x128
input b1 f32 128
input x f32 32x768
input y f32 32
output w1 f32 768x128
output loss f32 -
meta param_count 98432
end
";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let a = m.get("edge_mlp/train_step").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.outputs[1].dims, Vec::<usize>::new());
        assert_eq!(a.meta_f64("param_count"), Some(98432.0));
        assert_eq!(m.hlo_path(a), PathBuf::from("/tmp/a/edge_mlp_train_step.hlo.txt"));
        assert_eq!(a.inputs[0].size_bytes(), 768 * 128 * 4);
    }

    #[test]
    fn rejects_garbage() {
        assert!(ArtifactManifest::parse("bogus line", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("artifact a\nfile f\n", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("artifact a\nend\n", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("artifact a\ninput x f32 2y3\nend", PathBuf::new())
            .is_err());
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::new()).unwrap();
        let e = m.get("nope").unwrap_err().to_string();
        assert!(e.contains("edge_mlp/train_step"), "{e}");
    }
}
