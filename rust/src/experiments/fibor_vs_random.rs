//! §4.4 remark — FiboR vs random replacement under the default setup.
//! The paper reports 143,226 retrained samples with FiboR vs 154,193 with
//! random replacement (~7% advantage).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "§4.4 remark: FiboR vs random replacement (total RSN, default config)",
        &["replacement", "total_rsn", "warm_retrains", "scratch_retrains"],
    );
    // Average random over several seeds (its temporal sparsity is unstable —
    // exactly the paper's point). Memory is tightened and old-slot requests
    // boosted so the replacement policy actually decides outcomes.
    let seeds = scale.pick(2u64, 5u64);
    let cfg0 = ExperimentConfig {
        users: scale.pick(30, 100),
        rounds: scale.pick(5, 10),
        unlearn_prob: 0.3,
        ..Default::default()
    }
    .with_memory_gb(0.5);
    let tcfg = crate::data::trace::TraceConfig {
        age_decay: 0.35,
        ..crate::data::trace::TraceConfig::paper_default(cfg0.seed ^ 0x7ace)
    }
    .with_prob(cfg0.unlearn_prob);

    let fib = common::run_cost_with_trace(SystemVariant::Cause, &cfg0, &tcfg)?;
    t.row(vec![
        "fibor".into(),
        fib.total_rsn().to_string(),
        fib.warm_retrains.to_string(),
        fib.scratch_retrains.to_string(),
    ]);

    let mut rsn = 0u64;
    let mut warm = 0u64;
    let mut scratch = 0u64;
    for s in 0..seeds {
        let cfg = cfg0.clone().with_seed(cfg0.seed + s * 101);
        let m = common::run_cost_with_trace(SystemVariant::CauseRandomReplace, &cfg, &tcfg)?;
        rsn += m.total_rsn();
        warm += m.warm_retrains;
        scratch += m.scratch_retrains;
    }
    t.row(vec![
        format!("random (avg of {seeds})"),
        (rsn / seeds).to_string(),
        (warm / seeds).to_string(),
        (scratch / seeds).to_string(),
    ]);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports_both_policies() {
        let tables = run(Scale::Smoke).unwrap();
        assert_eq!(tables[0].rows.len(), 2);
        let fib: u64 = tables[0].rows[0][1].parse().unwrap();
        let rnd: u64 = tables[0].rows[1][1].parse().unwrap();
        assert!(fib > 0 && rnd > 0);
        // The paper reports a ~7% FiboR advantage; on our workload the two
        // jump strategies are close, with random sometimes ahead at smoke
        // scale (recorded as a deviation in EXPERIMENTS.md). This test only
        // guards against a pathological regression of either policy.
        assert!(
            (fib as f64) <= (rnd as f64) * 1.6 && (rnd as f64) <= (fib as f64) * 1.6,
            "policies diverged: FiboR {fib} vs random {rnd}"
        );
    }
}
