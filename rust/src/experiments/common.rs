//! Shared plumbing for the experiment modules.

use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::engine::EvalPolicy;
use crate::coordinator::system::SystemVariant;
use crate::data::dataset::{EdgePopulation, PopulationConfig};
use crate::data::trace::{RequestTrace, TraceConfig};
use crate::metrics::RunMetrics;
use crate::runtime::Runtime;
use crate::training::{PjrtTrainer, PjrtTrainerConfig};

/// Population matching a config (paper §5.1 defaults otherwise).
pub fn population(cfg: &ExperimentConfig) -> EdgePopulation {
    EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.clone(),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.7,
        seed: cfg.seed,
    })
}

/// Request trace matching a config.
pub fn trace(cfg: &ExperimentConfig, pop: &EdgePopulation) -> RequestTrace {
    RequestTrace::generate(
        pop,
        &TraceConfig::paper_default(cfg.seed ^ 0x7ace).with_prob(cfg.unlearn_prob),
    )
}

/// Run one system on the accounting backend; returns its metrics.
pub fn run_cost(v: SystemVariant, cfg: &ExperimentConfig) -> Result<RunMetrics> {
    let pop = population(cfg);
    let tr = trace(cfg, &pop);
    let mut engine = v.build_cost(cfg)?;
    engine.run_trace(&pop, &tr)?;
    Ok(engine.metrics.clone())
}

/// The seeded burst workload the batched-unlearning comparison is pinned
/// on: many same-round requests (users × ρ_u = 0.9) over at most `shards`
/// lineages, with memory sized so the store never evicts — replacement
/// order then cannot blur the FCFS-vs-Coalesce RSN comparison. Shared by
/// `tests/batched_unlearning.rs` and `benches/bench_coordinator.rs` so the
/// asserted and the printed numbers describe the same workload.
pub fn burst_workload() -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    let cfg = ExperimentConfig {
        users: 24,
        rounds: 3,
        shards: 4,
        unlearn_prob: 0.9,
        ..Default::default()
    }
    .with_memory_gb(8.0);
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.scaled(12_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: 21,
    });
    let trace = RequestTrace::generate(&pop, &TraceConfig::paper_default(22).with_prob(0.9));
    (cfg, pop, trace)
}

/// Cost run with an explicit trace configuration (workload ablations).
pub fn run_cost_with_trace(
    v: SystemVariant,
    cfg: &ExperimentConfig,
    tcfg: &TraceConfig,
) -> Result<RunMetrics> {
    let pop = population(cfg);
    let tr = RequestTrace::generate(&pop, tcfg);
    let mut engine = v.build_cost(cfg)?;
    engine.run_trace(&pop, &tr)?;
    Ok(engine.metrics.clone())
}

/// Artifact directory: `$CAUSE_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CAUSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

thread_local! {
    static RUNTIME: RefCell<Option<Option<Rc<Runtime>>>> = const { RefCell::new(None) };
}

/// Per-thread PJRT runtime (the `xla` handles are not `Send`); `None` when
/// artifacts are missing — real experiments then report "SKIPPED".
pub fn runtime() -> Option<Rc<Runtime>> {
    RUNTIME.with(|cell| {
        cell.borrow_mut()
            .get_or_insert_with(|| {
                let dir = artifacts_dir();
                if !dir.join("manifest.txt").exists() {
                    eprintln!(
                        "NOTE: no artifacts at {} — real-training experiments skipped \
                         (run `make artifacts`)",
                        dir.display()
                    );
                    return None;
                }
                match Runtime::new(&dir) {
                    Ok(rt) => Some(Rc::new(rt)),
                    Err(e) => {
                        eprintln!("NOTE: PJRT runtime unavailable: {e:#}");
                        None
                    }
                }
            })
            .clone()
    })
}

/// Reduced-scale config for real-training accuracy runs: the proxy corpus
/// is shrunk so a full system run finishes in seconds on the CPU client.
pub fn real_cfg(base: &ExperimentConfig, corpus: u64, users: usize, rounds: u32) -> ExperimentConfig {
    let mut cfg = base.clone();
    cfg.users = users;
    cfg.rounds = rounds;
    cfg.dataset = cfg.dataset.scaled(corpus);
    cfg
}

/// Run one system with the real PJRT backend; returns (metrics, accuracy).
pub fn run_real(
    v: SystemVariant,
    cfg: &ExperimentConfig,
    rt: Rc<Runtime>,
    variant: &str,
    max_epochs: u32,
) -> Result<(RunMetrics, Option<f64>)> {
    let pop = std::sync::Arc::new(population(cfg));
    let tr = trace(cfg, &pop);
    let trainer = PjrtTrainer::new(
        rt,
        pop.clone(),
        PjrtTrainerConfig {
            variant: variant.to_string(),
            max_epochs,
            lr: 0.05,
            test_samples: 256,
            seed: cfg.seed,
        },
        cfg.shards,
        v.schedule(cfg).final_keep(),
    )?;
    let mut engine = v.build_with_trainer(cfg, Box::new(trainer), EvalPolicy::FinalRound)?;
    engine.run_trace(&pop, &tr)?;
    let acc = engine.metrics.final_accuracy();
    Ok((engine.metrics.clone(), acc))
}

/// Render a float cell.
pub fn f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Render an integer cell.
pub fn n(v: u64) -> String {
    v.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_run_produces_rsn() {
        let cfg = ExperimentConfig {
            users: 20,
            rounds: 4,
            unlearn_prob: 0.3,
            ..Default::default()
        };
        let m = run_cost(SystemVariant::Cause, &cfg).unwrap();
        assert_eq!(m.rsn_by_round.len(), 4);
        assert!(m.total_requests() > 0);
    }

    #[test]
    fn cause_beats_sisa_on_rsn_at_default_scale() {
        // The paper's headline: CAUSE retrains far fewer samples.
        let cfg = ExperimentConfig {
            users: 40,
            rounds: 6,
            unlearn_prob: 0.3,
            ..Default::default()
        };
        let cause = run_cost(SystemVariant::Cause, &cfg).unwrap();
        let sisa = run_cost(SystemVariant::Sisa, &cfg).unwrap();
        assert!(
            cause.total_rsn() < sisa.total_rsn(),
            "CAUSE {} !< SISA {}",
            cause.total_rsn(),
            sisa.total_rsn()
        );
    }
}
