//! Table 3 — shard-controller ablation: CAUSE vs CAUSE-No-SC on accuracy
//! (real training) and retrained-sample number (accounting), S ∈ {1..16}.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const SHARDS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let mut out = Vec::new();

    // Accuracy block (real training, reduced scale).
    if let Some(rt) = common::runtime() {
        let mut acc_t = Table::new(
            "Table 3 (accuracy): CAUSE vs CAUSE-No-SC",
            &["system", "S=1", "S=2", "S=4", "S=8", "S=16"],
        );
        for v in [SystemVariant::Cause, SystemVariant::CauseNoSc] {
            let mut row = vec![v.display().to_string()];
            for s in SHARDS {
                let cfg = common::real_cfg(
                    &ExperimentConfig::default().with_shards(s),
                    scale.pick(1200, 4000),
                    scale.pick(16, 40),
                    scale.pick(2, 3),
                );
                let (_m, acc) =
                    common::run_real(v, &cfg, rt.clone(), "mobilenetv2_c10", scale.pick(1, 2))?;
                row.push(common::f(acc.unwrap_or(0.0), 4));
            }
            acc_t.row(row);
        }
        out.push(acc_t);
    }

    // RSN block — always at paper scale (the accounting backend is cheap,
    // and the controller's value only shows once checkpoint pressure is
    // real: 100 users, 10 rounds, 1 GB sub-model budget).
    let mut rsn_t = Table::new(
        "Table 3 (RSN): CAUSE vs CAUSE-No-SC",
        &["system", "S=1", "S=2", "S=4", "S=8", "S=16"],
    );
    for v in [SystemVariant::Cause, SystemVariant::CauseNoSc] {
        let mut row = vec![v.display().to_string()];
        for s in SHARDS {
            let cfg = ExperimentConfig { shards: s, ..Default::default() }
                .with_memory_gb(1.0);
            row.push(common::run_cost(v, &cfg)?.total_rsn().to_string());
        }
        rsn_t.row(row);
    }
    out.push(rsn_t);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sc_reduces_rsn_at_multi_shard_counts() {
        let tables = run(Scale::Smoke).unwrap();
        let t = tables
            .iter()
            .find(|t| t.title.contains("RSN"))
            .expect("RSN table");
        let series = |name: &str| -> Vec<u64> {
            let row = t.rows.iter().find(|r| r[0] == name).unwrap();
            row[1..].iter().map(|c| c.parse().unwrap()).collect()
        };
        let sc = series("CAUSE");
        let nosc = series("CAUSE-No-SC");
        // At S=1 the controller is inert (identical systems).
        assert_eq!(sc[0], nosc[0]);
        // SC's win comes from reduced checkpoint pressure; it is decisive
        // at the largest shard count (paper Table 3).
        assert!(
            sc[4] < nosc[4],
            "SC should win at S=16 under memory pressure: {sc:?} vs {nosc:?}"
        );
    }
}
