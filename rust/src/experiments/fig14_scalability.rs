//! Fig. 14 — scalability in more restrictive scenarios:
//! (a) RSN vs memory capacity 4.0 → 0.5 GB;
//! (b) RSN vs unlearning probability 0.1 → 0.5.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const MEMORY_GB: [f64; 4] = [4.0, 2.0, 1.0, 0.5];
pub const PROBS: [f64; 5] = [0.1, 0.2, 0.3, 0.4, 0.5];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let base = ExperimentConfig {
        users: scale.pick(30, 100),
        rounds: scale.pick(5, 10),
        ..Default::default()
    };

    let mut a = Table::new(
        "Fig 14a: total RSN vs memory capacity (GB)",
        &["system", "4.0GB", "2.0GB", "1.0GB", "0.5GB"],
    );
    for v in SystemVariant::COMPARED {
        let mut row = vec![v.display().to_string()];
        for gb in MEMORY_GB {
            let cfg = base.clone().with_memory_gb(gb);
            row.push(common::run_cost(v, &cfg)?.total_rsn().to_string());
        }
        a.row(row);
    }

    let mut b = Table::new(
        "Fig 14b: total RSN vs unlearning probability",
        &["system", "p=0.1", "p=0.2", "p=0.3", "p=0.4", "p=0.5"],
    );
    for v in SystemVariant::COMPARED {
        let mut row = vec![v.display().to_string()];
        for p in PROBS {
            let cfg = base.clone().with_unlearn_prob(p);
            row.push(common::run_cost(v, &cfg)?.total_rsn().to_string());
        }
        b.row(row);
    }
    Ok(vec![a, b])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rsn_grows_as_memory_shrinks_and_cause_wins() {
        let tables = run(Scale::Smoke).unwrap();
        let a = &tables[0];
        let series = |t: &Table, name: &str| -> Vec<u64> {
            let row = t.rows.iter().find(|r| r[0] == name).unwrap();
            row[1..].iter().map(|c| c.parse().unwrap()).collect()
        };
        for name in ["CAUSE", "SISA"] {
            let s = series(a, name);
            assert!(
                s[3] >= s[0],
                "{name}: RSN should not shrink as memory shrinks: {s:?}"
            );
        }
        // CAUSE lowest at every memory point.
        for i in 0..4 {
            let cause = series(a, "CAUSE")[i];
            for other in ["SISA", "ARCANE", "OMP-70", "OMP-95"] {
                assert!(cause <= series(a, other)[i], "{other} at memory {i}");
            }
        }
        // (b): RSN increases with probability.
        let b = &tables[1];
        for row in &b.rows {
            let s: Vec<u64> = row[1..].iter().map(|c| c.parse().unwrap()).collect();
            assert!(s[4] >= s[0], "{}: {s:?}", row[0]);
        }
    }
}
