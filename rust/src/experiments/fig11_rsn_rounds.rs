//! Fig. 11 — retrained sample number (cumulative) over 10 training rounds,
//! CAUSE vs SISA / ARCANE / OMP-70 / OMP-95, default §5.1 configuration.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let cfg = ExperimentConfig {
        users: scale.pick(30, 100),
        rounds: scale.pick(5, 10),
        ..Default::default()
    };
    let mut header = vec!["system".to_string()];
    header.extend((1..=cfg.rounds).map(|r| format!("t{r}")));
    header.push("total".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!(
            "Fig 11: cumulative RSN per round (model={}, S={}, rho_u={})",
            cfg.model.name, cfg.shards, cfg.unlearn_prob
        ),
        &header_refs,
    );
    for v in SystemVariant::COMPARED {
        let m = common::run_cost(v, &cfg)?;
        let mut row = vec![v.display().to_string()];
        row.extend(m.cumulative_rsn().iter().map(|x| x.to_string()));
        row.push(m.total_rsn().to_string());
        t.row(row);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cause_wins_and_rsn_grows_over_rounds() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        let total_of = |name: &str| -> u64 {
            t.rows
                .iter()
                .find(|r| r[0] == name)
                .unwrap()
                .last()
                .unwrap()
                .parse()
                .unwrap()
        };
        let cause = total_of("CAUSE");
        for other in ["SISA", "ARCANE", "OMP-70", "OMP-95"] {
            assert!(
                cause <= total_of(other),
                "CAUSE {cause} vs {other} {}",
                total_of(other)
            );
        }
        // Cumulative series is nondecreasing.
        let row = t.rows.iter().find(|r| r[0] == "CAUSE").unwrap();
        let series: Vec<u64> =
            row[1..row.len() - 1].iter().map(|c| c.parse().unwrap()).collect();
        assert!(series.windows(2).all(|w| w[0] <= w[1]), "{series:?}");
    }
}
