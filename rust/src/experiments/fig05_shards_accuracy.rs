//! Fig. 5 — the motivating observation behind the shard controller:
//! aggregated (majority-vote) accuracy falls as the shard count grows,
//! on CIFAR-10 and SVHN. Real sharded training on the proxy model.

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::aggregate::{argmax, ensemble_accuracy};
use crate::data::catalog::{DatasetSpec, CIFAR10, SVHN};
use crate::data::dataset::{EdgePopulation, PopulationConfig};
use crate::experiments::{common, Scale};
use crate::runtime::{Runtime, TrainSession};
use crate::util::Table;

pub const SHARDS: [usize; 5] = [1, 2, 4, 8, 16];

/// Train `s` sub-models on a uniform split and majority-vote on a test set.
pub fn sharded_accuracy(
    rt: Rc<Runtime>,
    spec: &DatasetSpec,
    corpus: u64,
    s: usize,
    epochs: u32,
    variant: &str,
    seed: u64,
) -> Result<f64> {
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: spec.clone(),
        users: 4 * s.max(2),
        rounds: 1,
        size_sigma: 0.3,
        label_alpha: 5.0, // near-IID split: this figure isolates shard size
        arrival_prob: 1.0,
        seed,
    });
    let blocks: Vec<_> = pop.blocks_at(1).to_vec();
    let (txs, tys) = pop.materialize_test(256, seed ^ 0x5eed);
    let mut per_model = Vec::with_capacity(s);
    for shard in 0..s {
        let mut sess = TrainSession::init(rt.clone(), variant, seed + shard as u64)?;
        // Round-robin block split (uniform sharding).
        for _ in 0..epochs {
            for b in blocks.iter().skip(shard).step_by(s) {
                let take = (b.samples as usize).min((corpus as usize / s).max(32));
                let (xs, ys) = pop.materialize(b, take);
                let bs = sess.batch_size();
                let fd = sess.feature_dim();
                let mut r = 0;
                while r < ys.len() {
                    let chunk = bs.min(ys.len() - r);
                    sess.step(&xs[r * fd..(r + chunk) * fd], &ys[r..r + chunk], 0.05)?;
                    r += chunk;
                }
            }
        }
        // Collect labels on the shared test set.
        let bs = sess.batch_size();
        let fd = sess.feature_dim();
        let mut labels = Vec::with_capacity(tys.len());
        let mut r = 0;
        while r < tys.len() {
            let take = bs.min(tys.len() - r);
            let logits = sess.logits(&txs[r * fd..(r + take) * fd], take)?;
            labels.extend(logits.iter().map(|row| argmax(row)));
            r += take;
        }
        per_model.push(labels);
    }
    Ok(ensemble_accuracy(&per_model, &tys, spec.classes))
}

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let Some(rt) = common::runtime() else {
        let mut t = Table::new("Fig 5: SKIPPED (no artifacts)", &["note"]);
        t.row(vec!["run `make artifacts` first".into()]);
        return Ok(vec![t]);
    };
    let corpus = scale.pick(1200u64, 4000u64);
    let epochs = scale.pick(1, 3);
    let datasets = [("cifar10", CIFAR10), ("svhn", SVHN)];
    let mut t = Table::new(
        format!("Fig 5: majority-vote accuracy vs shard count (corpus={corpus})"),
        &["dataset", "S=1", "S=2", "S=4", "S=8", "S=16"],
    );
    for (name, spec) in datasets {
        let spec = spec.scaled(corpus);
        let mut row = vec![name.to_string()];
        for s in SHARDS {
            let acc = sharded_accuracy(
                rt.clone(),
                &spec,
                corpus,
                s,
                epochs,
                "mobilenetv2_c10",
                41,
            )?;
            row.push(common::f(acc, 4));
        }
        t.row(row);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_declines_with_shards() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        if t.title.contains("SKIPPED") {
            return;
        }
        for row in &t.rows {
            let s1: f64 = row[1].parse().unwrap();
            let s16: f64 = row[5].parse().unwrap();
            assert!(
                s1 >= s16,
                "{}: accuracy should fall from S=1 ({s1}) to S=16 ({s16})",
                row[0]
            );
        }
    }
}
