//! Fig. 16 — retrained sample number vs shard count (ResNet-34/CIFAR-10):
//! CAUSE *decreases* with S while the uniform/class-partitioned systems
//! increase — the paper's signature UCDP result.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const SHARDS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 16: total RSN vs shard count (resnet34/cifar10)",
        &["system", "S=1", "S=2", "S=4", "S=8", "S=16"],
    );
    for v in SystemVariant::COMPARED {
        let mut row = vec![v.display().to_string()];
        for s in SHARDS {
            let cfg = ExperimentConfig {
                users: scale.pick(30, 100),
                rounds: scale.pick(5, 10),
                shards: s,
                ..Default::default()
            };
            row.push(common::run_cost(v, &cfg)?.total_rsn().to_string());
        }
        t.row(row);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cause_rsn_falls_with_shards_sisa_rises() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        let series = |name: &str| -> Vec<u64> {
            let row = t.rows.iter().find(|r| r[0] == name).unwrap();
            row[1..].iter().map(|c| c.parse().unwrap()).collect()
        };
        let cause = series("CAUSE");
        assert!(
            cause[4] < cause[0],
            "CAUSE RSN should fall as S grows: {cause:?}"
        );
        // SISA never improves with more shards (strictly rises once memory
        // binds — guaranteed at full scale, a tie is possible at smoke).
        let sisa = series("SISA");
        assert!(sisa[4] >= sisa[0], "SISA RSN should rise as S grows: {sisa:?}");
        // CAUSE dominates both baselines at the largest shard count.
        let arcane = series("ARCANE");
        assert!(cause[4] < sisa[4] && cause[4] < arcane[4]);
    }
}
