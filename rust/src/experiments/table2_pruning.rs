//! Table 2 — model performance across pruning rates δ ∈ {10..90}%:
//! accuracy before/after, parameter counts, stored size, prune time.
//! Real training + the Layer-1 prune kernel on the proxy backbones.

use std::time::Instant;

use anyhow::Result;

use crate::data::catalog::CIFAR10;
use crate::data::dataset::{EdgePopulation, PopulationConfig};
use crate::experiments::{common, Scale};
use crate::runtime::TrainSession;
use crate::util::Table;

pub const PRUNE_RATES: [f64; 5] = [0.1, 0.3, 0.5, 0.7, 0.9];

fn accuracy(sess: &TrainSession, xs: &[f32], ys: &[f32]) -> Result<f64> {
    let bs = sess.batch_size();
    let fd = sess.feature_dim();
    let mut correct = 0usize;
    let mut r = 0;
    while r < ys.len() {
        let take = bs.min(ys.len() - r);
        let logits = sess.logits(&xs[r * fd..(r + take) * fd], take)?;
        for (row, y) in logits.iter().zip(&ys[r..r + take]) {
            if crate::coordinator::aggregate::argmax(row) == *y as usize {
                correct += 1;
            }
        }
        r += take;
    }
    Ok(correct as f64 / ys.len() as f64)
}

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let Some(rt) = common::runtime() else {
        let mut t = Table::new("Table 2: SKIPPED (no artifacts)", &["note"]);
        t.row(vec!["run `make artifacts` first".into()]);
        return Ok(vec![t]);
    };
    let variants: &[&str] = scale.pick(
        &["mobilenetv2_c10"][..],
        &["resnet34_c10", "vgg16_c10", "mobilenetv2_c10"][..],
    );
    let corpus = scale.pick(800u64, 4000u64);
    let epochs = scale.pick(2, 4);

    let pop = EdgePopulation::generate(PopulationConfig {
        spec: CIFAR10.scaled(corpus),
        users: 10,
        rounds: 1,
        size_sigma: 0.5,
        label_alpha: 2.0,
        arrival_prob: 1.0,
        seed: 5,
    });
    let (txs, tys) = pop.materialize_test(256, 99);

    let mut t = Table::new(
        format!("Table 2: pruning-rate sweep (proxy backbones, corpus={corpus})"),
        &[
            "model", "PR(%)", "acc_orig", "acc_pruned", "acc_delta(%)", "params_orig",
            "params_pruned", "size_orig_KB", "size_pruned_KB", "prune_ms", "finetune_s",
        ],
    );

    for variant in variants {
        // Train the dense baseline once per variant.
        let mut base = TrainSession::init(rt.clone(), variant, 17)?;
        for _ in 0..epochs {
            for b in pop.blocks_at(1) {
                let (xs, ys) = pop.materialize(b, b.samples as usize);
                let bs = base.batch_size();
                let fd = base.feature_dim();
                let mut r = 0;
                while r < ys.len() {
                    let take = bs.min(ys.len() - r);
                    base.step(&xs[r * fd..(r + take) * fd], &ys[r..r + take], 0.05)?;
                    r += take;
                }
            }
        }
        let acc0 = accuracy(&base, &txs, &tys)?;
        let params0: usize = base.params().iter().map(|p| p.nonzero_count()).sum();
        let size0: usize = base.params().iter().map(|p| p.size_bytes()).sum();

        for pr in PRUNE_RATES {
            // Prune a copy of the trained model, then fine-tune briefly
            // (the paper's prune → fine-tune loop).
            let mut sess = TrainSession::from_params(
                rt.clone(),
                variant,
                base.params().to_vec(),
            )?;
            let t0 = Instant::now();
            sess.prune(1.0 - pr as f32)?;
            let prune_ms = t0.elapsed().as_secs_f64() * 1e3;

            let t1 = Instant::now();
            for b in pop.blocks_at(1) {
                let (xs, ys) = pop.materialize(b, (b.samples as usize).min(256));
                let bs = sess.batch_size();
                let fd = sess.feature_dim();
                let mut r = 0;
                while r < ys.len() {
                    let take = bs.min(ys.len() - r);
                    sess.step(&xs[r * fd..(r + take) * fd], &ys[r..r + take], 0.02)?;
                    r += take;
                }
            }
            let finetune_s = t1.elapsed().as_secs_f64();

            let acc1 = accuracy(&sess, &txs, &tys)?;
            let params1: usize = sess.params().iter().map(|p| p.nonzero_count()).sum();
            // Sparse storage: 8 bytes per surviving prunable weight.
            let size1: usize = sess
                .params()
                .iter()
                .map(|p| {
                    if p.dims.len() == 2 && p.len() >= 1024 {
                        p.nonzero_count() * 8
                    } else {
                        p.size_bytes()
                    }
                })
                .sum();
            t.row(vec![
                variant.to_string(),
                common::f(pr * 100.0, 0),
                common::f(acc0, 4),
                common::f(acc1, 4),
                common::f((acc0 - acc1) / acc0.max(1e-9) * 100.0, 2),
                params0.to_string(),
                params1.to_string(),
                common::f(size0 as f64 / 1024.0, 1),
                common::f(size1 as f64 / 1024.0, 1),
                common::f(prune_ms, 2),
                common::f(finetune_s, 2),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prune_sweep_shrinks_models_and_keeps_accuracy_until_90() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        if t.title.contains("SKIPPED") {
            eprintln!("table2 smoke skipped: no artifacts");
            return;
        }
        // Params shrink monotonically with the pruning rate.
        let pruned: Vec<f64> = t.rows.iter().map(|r| r[6].parse().unwrap()).collect();
        assert!(pruned.windows(2).all(|w| w[1] <= w[0]), "{pruned:?}");
        // At δ<=70% the accuracy drop is bounded; at 90% it may collapse
        // (paper Table 2). Check the δ=10% row specifically.
        let row10 = &t.rows[0];
        let acc0: f64 = row10[2].parse().unwrap();
        let acc1: f64 = row10[3].parse().unwrap();
        assert!(acc1 > acc0 * 0.5, "10% pruning destroyed accuracy: {acc0} -> {acc1}");
    }
}
