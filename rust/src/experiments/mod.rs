//! Experiment harness: one module per paper table/figure.
//!
//! Every experiment returns [`util::Table`]s shaped like the paper's
//! artifact and is reachable three ways: `cause repro <id>` (CLI), the
//! bench target of the same name, and the integration tests (reduced
//! parameters via [`Scale`]).

pub mod common;
pub mod fig02_retrain_ratio;
pub mod fig05_shards_accuracy;
pub mod fig10_accuracy_curves;
pub mod fig11_rsn_rounds;
pub mod fig12_energy_shards;
pub mod fig13_energy_prob;
pub mod fig14_scalability;
pub mod fig15_shard_accuracy;
pub mod fig16_shard_rsn;
pub mod fig17_partition_ablation;
pub mod fibor_vs_random;
pub mod table2_pruning;
pub mod table3_sc;

use crate::util::Table;

/// How hard to run an experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Integration-test scale: seconds.
    Smoke,
    /// Paper-shaped runs: the default for `cause repro`.
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("CAUSE_SCALE").as_deref() {
            Ok("smoke") => Scale::Smoke,
            _ => Scale::Full,
        }
    }

    /// Pick between smoke/full values.
    pub fn pick<T>(&self, smoke: T, full: T) -> T {
        match self {
            Scale::Smoke => smoke,
            Scale::Full => full,
        }
    }
}

/// Registry: experiment id -> runner. Used by the CLI and benches.
pub fn run(id: &str, scale: Scale) -> anyhow::Result<Vec<Table>> {
    match id {
        "fig2" | "fig02" => fig02_retrain_ratio::run(scale),
        "table2" => table2_pruning::run(scale),
        "fig5" | "fig05" => fig05_shards_accuracy::run(scale),
        "table3" => table3_sc::run(scale),
        "fig10" | "fig18" => fig10_accuracy_curves::run(scale),
        "fig11" => fig11_rsn_rounds::run(scale),
        "fig12" => fig12_energy_shards::run(scale),
        "fig13" => fig13_energy_prob::run(scale),
        "fig14" => fig14_scalability::run(scale),
        "fig15" => fig15_shard_accuracy::run(scale),
        "fig16" => fig16_shard_rsn::run(scale),
        "fig17" => fig17_partition_ablation::run(scale),
        "fibor" => fibor_vs_random::run(scale),
        other => anyhow::bail!(
            "unknown experiment '{other}'; have: fig2 table2 fig5 table3 fig10 fig11 \
             fig12 fig13 fig14 fig15 fig16 fig17 fibor"
        ),
    }
}

/// All experiment ids (CLI `repro all`).
pub const ALL: [&str; 13] = [
    "fig2", "table2", "fig5", "table3", "fig10", "fig11", "fig12", "fig13", "fig14",
    "fig15", "fig16", "fig17", "fibor",
];

/// Write experiment tables to `results/<id>.json` and print them.
pub fn report(id: &str, tables: &[Table]) -> anyhow::Result<()> {
    use crate::util::Json;
    let mut arr = Vec::new();
    for t in tables {
        println!("{}", t.render());
        arr.push(t.to_json());
    }
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let j = Json::obj().set("experiment", id).set("tables", Json::Arr(arr));
    std::fs::write(dir.join(format!("{id}.json")), j.to_pretty())?;
    Ok(())
}
