//! Fig. 12 — energy consumption vs shard count (S ∈ {1,2,4,8,16}) for all
//! four backbone models, ρ_u = 0.3, five systems.

use anyhow::Result;

use crate::config::profiles::ALL_MODELS;
use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const SHARDS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let models = scale.pick(&ALL_MODELS[..1], &ALL_MODELS[..]);
    let mut out = Vec::new();
    for model in models {
        let mut t = Table::new(
            format!("Fig 12: energy (J) vs shard count — {} (rho_u=0.3)", model.name),
            &["system", "S=1", "S=2", "S=4", "S=8", "S=16"],
        );
        for v in SystemVariant::COMPARED {
            let mut row = vec![v.display().to_string()];
            for s in SHARDS {
                let cfg = ExperimentConfig {
                    users: scale.pick(30, 100),
                    rounds: scale.pick(5, 10),
                    unlearn_prob: 0.3,
                    shards: s,
                    model: *model,
                    ..Default::default()
                };
                let m = common::run_cost(v, &cfg)?;
                row.push(common::f(m.energy_joules, 0));
            }
            t.row(row);
        }
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cause_energy_decreases_with_shards_others_increase() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        let series = |name: &str| -> Vec<f64> {
            let row = t.rows.iter().find(|r| r[0] == name).unwrap();
            row[1..].iter().map(|c| c.parse().unwrap()).collect()
        };
        let cause = series("CAUSE");
        let sisa = series("SISA");
        // Trend check (paper Fig. 12): CAUSE at S=16 below CAUSE at S=1;
        // SISA at S=16 above SISA at S=1.
        assert!(cause[4] < cause[0], "CAUSE energy should fall with S: {cause:?}");
        assert!(sisa[4] > sisa[0], "SISA energy should rise with S: {sisa:?}");
        // CAUSE wins at S=16 against everyone.
        for other in ["SISA", "ARCANE", "OMP-70", "OMP-95"] {
            assert!(cause[4] < series(other)[4], "{other}");
        }
    }
}
