//! Fig. 2 — the motivating pilot: retraining time and energy are linear in
//! the number of retrained samples.
//!
//! (a) is *measured* on this testbed: the proxy model retrains on
//! B × corpus samples through PJRT and we report wall seconds.
//! (b) uses the calibrated energy model (linear by the paper's own finding;
//! the figure documents the slope per backbone).

use std::time::Instant;

use anyhow::Result;

use crate::config::profiles::ALL_MODELS;
use crate::data::catalog::CIFAR10;
use crate::data::dataset::{EdgePopulation, PopulationConfig};
use crate::energy::EnergyModel;
use crate::experiments::{common, Scale};
use crate::runtime::TrainSession;
use crate::util::Table;

pub const RATIOS: [f64; 5] = [0.2, 0.4, 0.6, 0.8, 1.0];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let mut out = Vec::new();

    // (a) measured retrain seconds vs ratio on the PJRT proxy.
    if let Some(rt) = common::runtime() {
        let corpus = scale.pick(600u64, 3000u64);
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(corpus),
            users: 10,
            rounds: 1,
            size_sigma: 0.5,
            label_alpha: 1.0,
            arrival_prob: 1.0,
            seed: 2,
        });
        let mut t = Table::new(
            format!("Fig 2a (measured): retrain seconds vs ratio B (corpus={corpus})"),
            &["ratio", "samples", "seconds", "secs_per_sample"],
        );
        // Materialize the whole round once.
        let blocks: Vec<_> = pop.blocks_at(1).to_vec();
        for ratio in RATIOS {
            let mut sess = TrainSession::init(rt.clone(), "mobilenetv2_c10", 3)?;
            let budget = (corpus as f64 * ratio) as u64;
            let mut used = 0u64;
            let t0 = Instant::now();
            'outer: for b in &blocks {
                let take = (b.samples).min(budget - used);
                if take == 0 {
                    break 'outer;
                }
                let (xs, ys) = pop.materialize(b, take as usize);
                let bs = sess.batch_size();
                let fd = sess.feature_dim();
                let mut r = 0;
                while r < ys.len() {
                    let chunk = bs.min(ys.len() - r);
                    sess.step(&xs[r * fd..(r + chunk) * fd], &ys[r..r + chunk], 0.05)?;
                    r += chunk;
                }
                used += take;
                if used >= budget {
                    break;
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            t.row(vec![
                common::f(ratio, 1),
                used.to_string(),
                common::f(secs, 3),
                common::f(secs / used.max(1) as f64 * 1e3, 4) + "ms",
            ]);
        }
        out.push(t);
    }

    // (b) energy model slopes per backbone.
    let mut e = Table::new(
        "Fig 2b (model): retrain energy (J) vs ratio B, full CIFAR-10 corpus, 80 epochs",
        &["model", "B=0.2", "B=0.4", "B=0.6", "B=0.8", "B=1.0", "J_per_sample_epoch"],
    );
    for m in &ALL_MODELS {
        let em = EnergyModel::for_model(m);
        let mut row = vec![m.name.to_string()];
        for ratio in RATIOS {
            let samples = (50_000.0 * ratio) as u64;
            row.push(common::f(em.retrain_joules(samples, 80), 0));
        }
        row.push(common::f(em.joules_per_sample_epoch, 5));
        e.row(row);
    }
    out.push(e);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_table_is_linear_in_ratio() {
        let tables = run(Scale::Smoke).unwrap();
        let e = tables.last().unwrap();
        for row in &e.rows {
            let b02: f64 = row[1].parse().unwrap();
            let b10: f64 = row[5].parse().unwrap();
            assert!(
                (b10 / b02 - 5.0).abs() < 0.05,
                "{}: not linear ({b02} vs {b10})",
                row[0]
            );
        }
    }
}
