//! Fig. 15 — aggregated accuracy vs shard count for the five full systems
//! (real training through the engine, reduced-scale corpus).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const SHARDS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let Some(rt) = common::runtime() else {
        let mut t = Table::new("Fig 15: SKIPPED (no artifacts)", &["note"]);
        t.row(vec!["run `make artifacts` first".into()]);
        return Ok(vec![t]);
    };
    let combos: Vec<(&str, &str)> = match scale {
        Scale::Smoke => vec![("cifar10", "mobilenetv2_c10")],
        Scale::Full => vec![
            ("cifar10", "resnet34_c10"),
            ("svhn", "resnet34_c10"),
            ("cifar100", "vgg16_c100"),
            ("cifar10", "mobilenetv2_c10"),
        ],
    };
    let shards: &[usize] = scale.pick(&[1, 4, 16][..], &SHARDS[..]);
    let mut out = Vec::new();
    for (dataset, variant) in combos {
        let mut header = vec!["system".to_string()];
        header.extend(shards.iter().map(|s| format!("S={s}")));
        let mut t = Table::new(
            format!("Fig 15: accuracy vs shard count — {variant} on {dataset}"),
            &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for v in SystemVariant::COMPARED {
            let mut row = vec![v.display().to_string()];
            for &s in shards {
                let mut base = ExperimentConfig::default().with_shards(s);
                base.apply("dataset", dataset)?;
                let cfg = common::real_cfg(
                    &base,
                    scale.pick(1200, 4000),
                    scale.pick(16, 40),
                    scale.pick(2, 3),
                );
                let (_m, acc) =
                    common::run_real(v, &cfg, rt.clone(), variant, scale.pick(1, 2))?;
                row.push(common::f(acc.unwrap_or(0.0), 4));
            }
            t.row(row);
        }
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_declines_with_shards_and_heavy_pruning_hurts() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        if t.title.contains("SKIPPED") {
            return;
        }
        let get = |name: &str, col: usize| -> f64 {
            t.rows.iter().find(|r| r[0] == name).unwrap()[col].parse().unwrap()
        };
        let last_col = t.header.len() - 1;
        // The sharding cost: every unpruned system loses accuracy from S=1
        // to the largest S (paper Figs. 5/15).
        for sys in ["SISA", "ARCANE"] {
            assert!(
                get(sys, 1) >= get(sys, last_col),
                "{sys}: accuracy should fall with S"
            );
        }
        // CAUSE's iterative pruning beats OMP-95's one-shot at S=1.
        assert!(
            get("CAUSE", 1) >= get("OMP-95", 1),
            "CAUSE {} vs OMP-95 {}",
            get("CAUSE", 1),
            get("OMP-95", 1)
        );
    }
}
