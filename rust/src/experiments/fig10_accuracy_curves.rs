//! Fig. 10 / Fig. 18 — accuracy of a single sub-model over training
//! epochs when its data shard comes from each system's partitioner
//! (CAUSE / SISA / ARCANE / OMP-70 / OMP-95), on the proxy backbones.
//!
//! CAUSE's shard is produced by UCDP + SC (fewer, larger shards); SISA's
//! by a uniform S-way split; ARCANE's by the class grouping (a single
//! class range — the source of its collapse); OMP-x additionally one-shot
//! prunes at rate x after training.

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::aggregate::argmax;
use crate::data::catalog::{DatasetSpec, CIFAR10, CIFAR100, SVHN};
use crate::data::dataset::{EdgePopulation, PopulationConfig};
use crate::experiments::{common, Scale};
use crate::partition::{ClassBased, Partitioner, Ucdp, Uniform};
use crate::runtime::{Runtime, TrainSession};
use crate::shard_controller::ShardController;
use crate::util::Table;

struct Curve {
    system: &'static str,
    accs: Vec<f64>,
}

fn shard0_blocks(
    pop: &EdgePopulation,
    mut part: Box<dyn Partitioner>,
    s_t: usize,
) -> Vec<(crate::data::dataset::BlockId, u64)> {
    let placements = part.assign(pop.blocks_at(1), s_t);
    // Use the largest shard as "the" sub-model's shard.
    let loads = crate::partition::shard_loads(&placements, s_t);
    let shard = loads
        .iter()
        .enumerate()
        .max_by_key(|(_, l)| **l)
        .map(|(i, _)| i)
        .unwrap_or(0);
    placements
        .into_iter()
        .filter(|p| p.shard == shard)
        .map(|p| (p.block, p.samples))
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn train_curve(
    rt: Rc<Runtime>,
    pop: &EdgePopulation,
    blocks: &[(crate::data::dataset::BlockId, u64)],
    variant: &str,
    epochs: u32,
    prune_keep: Option<f32>,
    txs: &[f32],
    tys: &[f32],
) -> Result<Vec<f64>> {
    let mut sess = TrainSession::init(rt, variant, 23)?;
    let mut accs = Vec::with_capacity(epochs as usize);
    for e in 0..epochs {
        for (id, samples) in blocks {
            let Some(b) = pop.block(*id) else { continue };
            let (xs, ys) = pop.materialize(b, *samples as usize);
            let bs = sess.batch_size();
            let fd = sess.feature_dim();
            let mut r = 0;
            while r < ys.len() {
                let take = bs.min(ys.len() - r);
                sess.step(&xs[r * fd..(r + take) * fd], &ys[r..r + take], 0.05)?;
                r += take;
            }
        }
        if let (Some(keep), true) = (prune_keep, e + 1 == epochs) {
            sess.prune(keep)?; // OMP: one-shot at the end
        }
        // Accuracy after this epoch.
        let bs = sess.batch_size();
        let fd = sess.feature_dim();
        let mut correct = 0usize;
        let mut r = 0;
        while r < tys.len() {
            let take = bs.min(tys.len() - r);
            let logits = sess.logits(&txs[r * fd..(r + take) * fd], take)?;
            for (row, y) in logits.iter().zip(&tys[r..r + take]) {
                if argmax(row) == *y as usize {
                    correct += 1;
                }
            }
            r += take;
        }
        accs.push(correct as f64 / tys.len() as f64);
    }
    Ok(accs)
}

fn combo_table(
    rt: Rc<Runtime>,
    title: &str,
    spec: &DatasetSpec,
    variant: &str,
    scale: Scale,
) -> Result<Table> {
    let corpus = scale.pick(1200u64, 4000u64);
    let epochs = scale.pick(2u32, 6u32);
    let s = 4; // paper default shard count
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: spec.scaled(corpus),
        users: 24,
        rounds: 1,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 1.0,
        seed: 31,
    });
    let (txs, tys) = pop.materialize_test(256, 77);

    // Effective shard count CAUSE trains with at round 1 (SC shrinks S).
    let s_cause = ShardController::new(s, 0.5, 0.5).shards_at(1);
    let curves = [
        ("CAUSE", shard0_blocks(&pop, Box::new(Ucdp::new(s, 9)), s_cause), None),
        ("SISA", shard0_blocks(&pop, Box::new(Uniform::new(s)), s), None),
        (
            "ARCANE",
            shard0_blocks(&pop, Box::new(ClassBased::new(spec.classes)), s),
            None,
        ),
        ("OMP-70", shard0_blocks(&pop, Box::new(Uniform::new(s)), s), Some(0.3f32)),
        ("OMP-95", shard0_blocks(&pop, Box::new(Uniform::new(s)), s), Some(0.05f32)),
    ];

    let mut results: Vec<Curve> = Vec::new();
    for (system, blocks, keep) in curves {
        let accs =
            train_curve(rt.clone(), &pop, &blocks, variant, epochs, keep, &txs, &tys)?;
        results.push(Curve { system, accs });
    }

    let mut header = vec!["system".to_string()];
    header.extend((1..=epochs).map(|e| format!("ep{e}")));
    let mut t = Table::new(title, &header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for c in results {
        let mut row = vec![c.system.to_string()];
        row.extend(c.accs.iter().map(|a| common::f(*a, 4)));
        t.row(row);
    }
    Ok(t)
}

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let Some(rt) = common::runtime() else {
        let mut t = Table::new("Fig 10: SKIPPED (no artifacts)", &["note"]);
        t.row(vec!["run `make artifacts` first".into()]);
        return Ok(vec![t]);
    };
    let combos: Vec<(&str, DatasetSpec, &str)> = match scale {
        Scale::Smoke => vec![("mobilenetv2/cifar10", CIFAR10, "mobilenetv2_c10")],
        Scale::Full => vec![
            ("resnet34/cifar10", CIFAR10, "resnet34_c10"),
            ("resnet34/svhn", SVHN, "resnet34_c10"),
            ("vgg16/cifar100", CIFAR100, "vgg16_c100"),
            ("mobilenetv2/cifar10", CIFAR10, "mobilenetv2_c10"),
        ],
    };
    let mut out = Vec::new();
    for (name, spec, variant) in combos {
        out.push(combo_table(
            rt.clone(),
            &format!("Fig 10: accuracy over epochs — {name}"),
            &spec,
            variant,
            scale,
        )?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_are_sane_and_heavy_pruning_hurts() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        if t.title.contains("SKIPPED") {
            return;
        }
        let last = |name: &str| -> f64 {
            let row = t.rows.iter().find(|r| r[0] == name).unwrap();
            row.last().unwrap().parse().unwrap()
        };
        // All five systems trained to something above chance.
        for sys in ["CAUSE", "SISA", "ARCANE", "OMP-70", "OMP-95"] {
            assert!(last(sys) > 0.10, "{sys} below chance: {}", last(sys));
        }
        // 95% one-shot pruning must cost accuracy vs CAUSE's RCMP
        // (the robust smoke-scale comparison; CAUSE-vs-SISA/ARCANE margins
        // are a full-scale claim recorded in EXPERIMENTS.md).
        assert!(
            last("CAUSE") > last("OMP-95"),
            "CAUSE {} vs OMP-95 {}",
            last("CAUSE"),
            last("OMP-95")
        );
    }
}
