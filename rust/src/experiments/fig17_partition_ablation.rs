//! Fig. 17 — ablation on the data-partition method:
//! CAUSE (UCDP) vs CAUSE-U (uniform) vs CAUSE-C (class-based).
//! (a) accuracy vs S (real training), (b) RSN vs S, (c) RSN vs ρ_u.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const SHARDS: [usize; 5] = [1, 2, 4, 8, 16];
pub const PROBS: [f64; 5] = [0.1, 0.2, 0.3, 0.4, 0.5];

const VARIANTS: [SystemVariant; 3] =
    [SystemVariant::Cause, SystemVariant::CauseU, SystemVariant::CauseC];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let mut out = Vec::new();

    // (a) accuracy vs S — real PJRT training at reduced scale.
    if let Some(rt) = common::runtime() {
        let mut a = Table::new(
            "Fig 17a: accuracy vs shard count (real training, proxy model)",
            &["system", "S=1", "S=2", "S=4", "S=8", "S=16"],
        );
        let corpus = scale.pick(1200, 4000);
        for v in VARIANTS {
            let mut row = vec![v.display().to_string()];
            for s in SHARDS {
                let cfg = common::real_cfg(
                    &ExperimentConfig::default().with_shards(s),
                    corpus,
                    scale.pick(16, 40),
                    scale.pick(2, 3),
                );
                let (_m, acc) =
                    common::run_real(v, &cfg, rt.clone(), "mobilenetv2_c10", scale.pick(1, 2))?;
                row.push(common::f(acc.unwrap_or(0.0), 4));
            }
            a.row(row);
        }
        out.push(a);
    }

    // (b) RSN vs S.
    let mut b = Table::new(
        "Fig 17b: total RSN vs shard count",
        &["system", "S=1", "S=2", "S=4", "S=8", "S=16"],
    );
    for v in VARIANTS {
        let mut row = vec![v.display().to_string()];
        for s in SHARDS {
            let cfg = ExperimentConfig {
                users: scale.pick(30, 100),
                rounds: scale.pick(5, 10),
                shards: s,
                ..Default::default()
            };
            row.push(common::run_cost(v, &cfg)?.total_rsn().to_string());
        }
        b.row(row);
    }
    out.push(b);

    // (c) RSN vs unlearning probability.
    let mut c = Table::new(
        "Fig 17c: total RSN vs unlearning probability (S=4)",
        &["system", "p=0.1", "p=0.2", "p=0.3", "p=0.4", "p=0.5"],
    );
    for v in VARIANTS {
        let mut row = vec![v.display().to_string()];
        for p in PROBS {
            let cfg = ExperimentConfig {
                users: scale.pick(30, 100),
                rounds: scale.pick(5, 10),
                unlearn_prob: p,
                ..Default::default()
            };
            row.push(common::run_cost(v, &cfg)?.total_rsn().to_string());
        }
        c.row(row);
    }
    out.push(c);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ucdp_has_lowest_rsn_among_partitioners() {
        let tables = run(Scale::Smoke).unwrap();
        let b = tables
            .iter()
            .find(|t| t.title.starts_with("Fig 17b"))
            .expect("RSN table");
        let series = |name: &str| -> Vec<u64> {
            let row = b.rows.iter().find(|r| r[0] == name).unwrap();
            row[1..].iter().map(|c| c.parse().unwrap()).collect()
        };
        let cause = series("CAUSE");
        let cause_u = series("CAUSE-U");
        let cause_c = series("CAUSE-C");
        // At large S the partitioning difference dominates.
        assert!(cause[4] <= cause_u[4], "{cause:?} vs U {cause_u:?}");
        assert!(cause[4] <= cause_c[4], "{cause:?} vs C {cause_c:?}");
        // CAUSE's RSN falls with S; the uniform/class variants never
        // improve with S (they rise outright once memory binds).
        assert!(cause[4] < cause[0]);
        assert!(cause_u[4] >= cause[4] && cause_c[4] >= cause[4]);
    }
}
