//! Fig. 13 — energy consumption vs unlearning probability
//! (ρ_u ∈ {0.1..0.5}), S = 8, four models, five systems.

use anyhow::Result;

use crate::config::profiles::ALL_MODELS;
use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::experiments::{common, Scale};
use crate::util::Table;

pub const PROBS: [f64; 5] = [0.1, 0.2, 0.3, 0.4, 0.5];

pub fn run(scale: Scale) -> Result<Vec<Table>> {
    let models = scale.pick(&ALL_MODELS[..1], &ALL_MODELS[..]);
    let mut out = Vec::new();
    for model in models {
        let mut t = Table::new(
            format!("Fig 13: energy (J) vs unlearning probability — {} (S=8)", model.name),
            &["system", "p=0.1", "p=0.2", "p=0.3", "p=0.4", "p=0.5"],
        );
        for v in SystemVariant::COMPARED {
            let mut row = vec![v.display().to_string()];
            for p in PROBS {
                let cfg = ExperimentConfig {
                    users: scale.pick(30, 100),
                    rounds: scale.pick(5, 10),
                    unlearn_prob: p,
                    shards: 8,
                    model: *model,
                    ..Default::default()
                };
                let m = common::run_cost(v, &cfg)?;
                row.push(common::f(m.energy_joules, 0));
            }
            t.row(row);
        }
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_rises_with_probability_and_cause_wins() {
        let tables = run(Scale::Smoke).unwrap();
        let t = &tables[0];
        for row in &t.rows {
            let series: Vec<f64> = row[1..].iter().map(|c| c.parse().unwrap()).collect();
            assert!(
                series[4] > series[0],
                "{}: energy should rise with rho_u: {series:?}",
                row[0]
            );
        }
        let get = |name: &str, i: usize| -> f64 {
            t.rows.iter().find(|r| r[0] == name).unwrap()[1 + i].parse().unwrap()
        };
        for i in 0..5 {
            for other in ["SISA", "ARCANE", "OMP-70", "OMP-95"] {
                assert!(get("CAUSE", i) < get(other, i), "{other} at p index {i}");
            }
        }
    }
}
