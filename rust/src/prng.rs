//! Deterministic PRNG for the coordinator and the experiment harness.
//!
//! `rand` is not available in the offline registry, so this is a small,
//! well-known generator: SplitMix64 for seeding and xoshiro256** for the
//! stream (Blackman & Vigna). Every experiment takes an explicit seed so
//! all tables/figures are exactly reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = move || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Derive an independent child stream (for per-user / per-shard rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// The generator's raw state words (durability snapshots).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`] so the stream continues
    /// exactly where it left off (crash recovery of stateful components).
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), bias-free via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample from an (unnormalized) discrete weight vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Symmetric Dirichlet(alpha) over k categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(k);
        let mut total = 0.0;
        for _ in 0..k {
            let g = self.gamma(alpha);
            total += g;
            out.push(g);
        }
        if total <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut out {
            *v /= total;
        }
        out
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang (boosted for alpha < 1).
    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u: f64 = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from 0..n (k <= n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose({k}) from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for alpha in [0.1, 0.5, 1.0, 5.0] {
            let d = r.dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|v| *v >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(8);
        let picks = r.choose(10, 4);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }
}
