//! Data substrate: dataset catalog, synthetic per-user edge populations,
//! and learn/unlearn request traces.
//!
//! The paper evaluates on synthetic *imbalanced user datasets* derived from
//! CIFAR-10 / CIFAR-100 / SVHN ("randomly shuffling data categories and
//! quantities to model heterogeneous user data"). This module rebuilds that
//! generator: users with log-normal sizes and Dirichlet label skew, data
//! arriving over training rounds, plus Bernoulli(ρ_u) unlearning requests.
//!
//! Blocks can be *materialized* into actual feature tensors (class-prototype
//! Gaussians shaped like 32×32×3 images) for the real-training experiments;
//! the RSN/energy sweeps only need the counts.

pub mod catalog;
pub mod dataset;
pub mod trace;

pub use catalog::{DatasetSpec, CIFAR10, CIFAR100, SVHN};
pub use dataset::{BlockId, DataBlock, EdgePopulation, PopulationConfig, UserId};
pub use trace::{RequestTrace, TraceConfig, UnlearnRequest};
