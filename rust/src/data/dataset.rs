//! Synthetic edge population: users, their non-IID data, arrival over rounds.

use std::collections::BTreeMap;

use crate::data::catalog::DatasetSpec;
use crate::prng::Rng;

/// A user contributing data to the edge device.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct UserId(pub u32);

/// A block of samples from one user arriving at one round — the unit of
/// partition placement and unlearning bookkeeping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BlockId(pub u64);

/// One data block: `samples` examples from `user` arriving at `round`,
/// with a per-class composition (needed by the class-based partitioner).
#[derive(Clone, Debug)]
pub struct DataBlock {
    pub id: BlockId,
    pub user: UserId,
    pub round: u32,
    pub samples: u64,
    /// Per-class sample counts; sums to `samples`.
    pub class_counts: Vec<u64>,
    /// Seed for deterministic materialization into tensors.
    pub seed: u64,
}

/// Generator parameters for an [`EdgePopulation`].
#[derive(Clone, Debug)]
pub struct PopulationConfig {
    pub spec: DatasetSpec,
    pub users: usize,
    pub rounds: u32,
    /// Log-normal sigma of user sizes (0 = equal users).
    pub size_sigma: f64,
    /// Dirichlet alpha of per-user label skew (smaller = more skew).
    pub label_alpha: f64,
    /// Probability a user contributes data in a given round.
    pub arrival_prob: f64,
    pub seed: u64,
}

impl PopulationConfig {
    /// The paper's default: 100 non-IID users, T=10 rounds.
    pub fn paper_default(spec: DatasetSpec, seed: u64) -> Self {
        Self {
            spec,
            users: 100,
            rounds: 10,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed,
        }
    }
}

/// The synthetic population: every user's blocks across all rounds.
#[derive(Clone, Debug)]
pub struct EdgePopulation {
    pub cfg: PopulationConfig,
    /// blocks[r] = blocks arriving at round r+1 (rounds are 1-based).
    rounds: Vec<Vec<DataBlock>>,
    by_id: BTreeMap<BlockId, (u32, usize)>,
    /// Per-user class mixture (probabilities), used by materialization.
    user_mix: Vec<Vec<f64>>,
    /// Class prototype seed (shared across users so classes are learnable).
    proto_seed: u64,
}

impl EdgePopulation {
    /// Generate deterministically from the config.
    pub fn generate(cfg: PopulationConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let proto_seed = rng.next_u64();

        // User sizes: log-normal, normalized to the corpus size.
        let mut weights: Vec<f64> =
            (0..cfg.users).map(|_| rng.log_normal(0.0, cfg.size_sigma)).collect();
        let wsum: f64 = weights.iter().sum();
        for w in &mut weights {
            *w /= wsum;
        }

        // Per-user label mixtures (non-IID Dirichlet skew).
        let user_mix: Vec<Vec<f64>> =
            (0..cfg.users).map(|_| rng.dirichlet(cfg.label_alpha, cfg.spec.classes)).collect();

        // Spread each user's total across rounds they are active in.
        let mut rounds: Vec<Vec<DataBlock>> = vec![vec![]; cfg.rounds as usize];
        let mut by_id = BTreeMap::new();
        let mut next_block = 0u64;
        for u in 0..cfg.users {
            let total = (weights[u] * cfg.spec.train_size as f64).round().max(1.0) as u64;
            let active: Vec<u32> = (1..=cfg.rounds)
                .filter(|_| rng.chance(cfg.arrival_prob))
                .collect();
            let active = if active.is_empty() {
                vec![rng.range(1, cfg.rounds as usize + 1) as u32]
            } else {
                active
            };
            // Uneven split across active rounds.
            let cuts: Vec<f64> = (0..active.len()).map(|_| rng.f64() + 0.2).collect();
            let csum: f64 = cuts.iter().sum();
            let mut assigned = 0u64;
            for (i, &r) in active.iter().enumerate() {
                let mut samples = if i + 1 == active.len() {
                    total - assigned
                } else {
                    ((cuts[i] / csum) * total as f64).round() as u64
                };
                samples = samples.min(total - assigned);
                assigned += samples;
                if samples == 0 {
                    continue;
                }
                let class_counts =
                    multinomial_counts(&mut rng, samples, &user_mix[u]);
                let id = BlockId(next_block);
                next_block += 1;
                let idx = rounds[(r - 1) as usize].len();
                by_id.insert(id, (r, idx));
                rounds[(r - 1) as usize].push(DataBlock {
                    id,
                    user: UserId(u as u32),
                    round: r,
                    samples,
                    class_counts,
                    seed: rng.next_u64(),
                });
            }
        }
        Self { cfg, rounds, by_id, user_mix, proto_seed }
    }

    /// Blocks arriving at `round` (1-based).
    pub fn blocks_at(&self, round: u32) -> &[DataBlock] {
        &self.rounds[(round - 1) as usize]
    }

    pub fn block(&self, id: BlockId) -> Option<&DataBlock> {
        let (r, idx) = self.by_id.get(&id)?;
        Some(&self.rounds[(*r - 1) as usize][*idx])
    }

    /// All blocks of one user up to and including `round`.
    pub fn user_blocks(&self, user: UserId, up_to_round: u32) -> Vec<&DataBlock> {
        (1..=up_to_round.min(self.cfg.rounds))
            .flat_map(|r| self.blocks_at(r).iter().filter(move |b| b.user == user))
            .collect()
    }

    pub fn total_samples(&self) -> u64 {
        self.rounds.iter().flatten().map(|b| b.samples).sum()
    }

    /// Restrict to blocks owned by users satisfying `keep` (fleet
    /// sharding: each worker ingests only its shard's slice of the
    /// population). Block ids, round numbers, and per-round ordering are
    /// preserved, so an all-true predicate is the identity and the union
    /// of disjoint filters replays the full population exactly.
    pub fn filter_users(&self, keep: impl Fn(UserId) -> bool) -> EdgePopulation {
        let rounds: Vec<Vec<DataBlock>> = self
            .rounds
            .iter()
            .map(|blocks| blocks.iter().filter(|b| keep(b.user)).cloned().collect())
            .collect();
        let mut by_id = BTreeMap::new();
        for (ri, blocks) in rounds.iter().enumerate() {
            for (idx, b) in blocks.iter().enumerate() {
                by_id.insert(b.id, (ri as u32 + 1, idx));
            }
        }
        EdgePopulation {
            cfg: self.cfg.clone(),
            rounds,
            by_id,
            user_mix: self.user_mix.clone(),
            proto_seed: self.proto_seed,
        }
    }

    pub fn rounds(&self) -> u32 {
        self.cfg.rounds
    }

    /// Materialize `n` samples of a block into (features, labels) suitable
    /// for the PJRT train step: class prototypes + Gaussian noise, scaled by
    /// the dataset's `separability`.
    pub fn materialize(&self, block: &DataBlock, n: usize) -> (Vec<f32>, Vec<f32>) {
        let spec = &self.cfg.spec;
        let mut rng = Rng::new(block.seed);
        let n = n.min(block.samples as usize);
        let mut xs = vec![0.0f32; n * spec.features];
        let mut ys = vec![0.0f32; n];
        // Expand class counts into a label sequence (deterministic order,
        // then shuffled so truncation keeps the mixture).
        let mut labels: Vec<usize> = block
            .class_counts
            .iter()
            .enumerate()
            .flat_map(|(c, k)| std::iter::repeat(c).take(*k as usize))
            .collect();
        rng.shuffle(&mut labels);
        for (row, &class) in labels.iter().take(n).enumerate() {
            ys[row] = class as f32;
            write_example(
                &mut xs[row * spec.features..(row + 1) * spec.features],
                self.proto_seed,
                class,
                spec.separability,
                &mut rng,
            );
        }
        (xs, ys)
    }

    /// Materialize a held-out test set with the population's class mixture.
    pub fn materialize_test(&self, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let spec = &self.cfg.spec;
        let mut rng = Rng::new(seed ^ 0xdead_beef);
        let mut xs = vec![0.0f32; n * spec.features];
        let mut ys = vec![0.0f32; n];
        for row in 0..n {
            let class = rng.range(0, spec.classes);
            ys[row] = class as f32;
            write_example(
                &mut xs[row * spec.features..(row + 1) * spec.features],
                self.proto_seed,
                class,
                spec.separability,
                &mut rng,
            );
        }
        (xs, ys)
    }
}

/// One synthetic example: a *sparse* class prototype buried in noise.
///
/// Two properties are calibrated deliberately:
/// * the signal-to-noise ratio puts the proxy models in the paper's
///   accuracy regime and makes accuracy depend on training-set size
///   (undertrained at fixed epoch budgets) — what the shard-count
///   experiments measure;
/// * the class signal lives in a ~15% subset of feature dimensions
///   (per class), mirroring the redundancy of natural images that makes
///   magnitude pruning cheap (Table 2): trained weights concentrate on the
///   informative dimensions, which is exactly what magnitude pruning keeps.
fn write_example(out: &mut [f32], proto_seed: u64, class: usize, separability: f64, rng: &mut Rng) {
    let mut proto = Rng::new(proto_seed ^ (class as u64).wrapping_mul(0x9e3779b97f4a7c15));
    // Sparse support boosts amplitude to preserve the overall class SNR.
    let signal = 0.5 * separability as f32;
    for v in out.iter_mut() {
        let gate = proto.f32();
        let p = (proto.f32() - 0.5) * 2.0;
        let s = if gate < 0.15 { signal * p } else { 0.0 };
        *v = s + 1.0 * rng.normal() as f32;
    }
}

/// Draw multinomial counts summing exactly to `n`.
fn multinomial_counts(rng: &mut Rng, n: u64, probs: &[f64]) -> Vec<u64> {
    let mut counts = vec![0u64; probs.len()];
    for _ in 0..n {
        counts[rng.weighted(probs)] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog::CIFAR10;

    fn small_cfg(seed: u64) -> PopulationConfig {
        PopulationConfig {
            spec: CIFAR10.scaled(5_000),
            users: 20,
            rounds: 5,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed,
        }
    }

    #[test]
    fn totals_are_conserved() {
        let pop = EdgePopulation::generate(small_cfg(1));
        let total = pop.total_samples();
        // Rounding can drift by at most one sample per user.
        assert!((total as i64 - 5_000i64).unsigned_abs() <= 20, "total {total}");
        for r in 1..=5 {
            for b in pop.blocks_at(r) {
                assert_eq!(b.round, r);
                assert_eq!(b.class_counts.iter().sum::<u64>(), b.samples);
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = EdgePopulation::generate(small_cfg(2));
        let b = EdgePopulation::generate(small_cfg(2));
        assert_eq!(a.total_samples(), b.total_samples());
        assert_eq!(a.blocks_at(1).len(), b.blocks_at(1).len());
        let (xa, ya) = a.materialize(&a.blocks_at(1)[0], 8);
        let (xb, yb) = b.materialize(&b.blocks_at(1)[0], 8);
        assert_eq!(ya, yb);
        assert_eq!(xa, xb);
    }

    #[test]
    fn block_lookup_and_user_blocks() {
        let pop = EdgePopulation::generate(small_cfg(3));
        let b0 = &pop.blocks_at(1)[0];
        assert_eq!(pop.block(b0.id).unwrap().id, b0.id);
        let ub = pop.user_blocks(b0.user, 5);
        assert!(ub.iter().any(|b| b.id == b0.id));
        assert!(ub.iter().all(|b| b.user == b0.user));
    }

    #[test]
    fn users_are_non_iid() {
        let pop = EdgePopulation::generate(small_cfg(4));
        // At least one pair of users should have very different majority class.
        let majority = |u: UserId| {
            let mut counts = vec![0u64; 10];
            for b in pop.user_blocks(u, 5) {
                for (c, k) in b.class_counts.iter().enumerate() {
                    counts[c] += k;
                }
            }
            counts.iter().enumerate().max_by_key(|(_, k)| **k).unwrap().0
        };
        let m: Vec<usize> = (0..20).map(|u| majority(UserId(u))).collect();
        assert!(m.iter().any(|c| *c != m[0]), "all users share majority class {m:?}");
    }

    #[test]
    fn materialized_features_are_class_separable() {
        let pop = EdgePopulation::generate(small_cfg(5));
        let (xs, ys) = pop.materialize_test(64, 9);
        // Same-class rows correlate more than cross-class rows on average.
        let f = pop.cfg.spec.features;
        let dot = |a: usize, b: usize| -> f32 {
            (0..f).map(|i| xs[a * f + i] * xs[b * f + i]).sum::<f32>() / f as f32
        };
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for a in 0..24 {
            for b in (a + 1)..24 {
                if ys[a] == ys[b] {
                    same = (same.0 + dot(a, b), same.1 + 1);
                } else {
                    diff = (diff.0 + dot(a, b), diff.1 + 1);
                }
            }
        }
        if same.1 > 0 && diff.1 > 0 {
            assert!(same.0 / same.1 as f32 > diff.0 / diff.1 as f32);
        }
    }
}
