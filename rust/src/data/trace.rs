//! Unlearning request traces: who asks to forget what, and when.
//!
//! The paper's workload: each round, each user raises an unlearning request
//! with probability ρ_u, asking to remove "a randomly generated subset of
//! their data"; the device serves requests first-come-first-served. A
//! request spans the user's *history* (several past blocks) — this is
//! exactly the case where UCDP's user-keyed placement confines the retrain
//! to one shard while uniform/class partitions scatter it.

use crate::data::dataset::{BlockId, EdgePopulation, UserId};
use crate::prng::Rng;

/// One unlearning request: remove `samples` from each listed block.
#[derive(Clone, Debug)]
pub struct UnlearnRequest {
    /// Round *after* which the request arrives (1-based).
    pub round: u32,
    pub user: UserId,
    /// Logical arrival time on the service clock (ticks). Trace generation
    /// stamps the arrival round; [`UnlearningService::submit`] re-stamps
    /// with its own clock so queueing-delay receipts are measured against
    /// one consistent timeline. The deadline-aware batch planner closes a
    /// window before `arrival_tick + slo_ticks` passes.
    ///
    /// [`UnlearningService::submit`]: crate::unlearning::UnlearningService::submit
    pub arrival_tick: u64,
    /// (block, samples to remove) — already clamped to remaining samples.
    pub parts: Vec<(BlockId, u64)>,
}

impl UnlearnRequest {
    pub fn total_samples(&self) -> u64 {
        self.parts.iter().map(|(_, n)| n).sum()
    }
}

/// Trace generation knobs.
///
/// Requests are *recency-biased*: the paper's time-slot semantics ("users
/// can specify requests to delete data from certain periods or specific
/// time slots", each training round being one slot). A request targets the
/// user's current-round capture with probability `block_incl_prob`, and
/// with probability `age_decay` additionally reaches one random older slot
/// — the expensive case on which the replacement policies differ.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Per-user per-round probability of raising a request (ρ_u).
    pub unlearn_prob: f64,
    /// Probability the user's current-round block is included.
    pub block_incl_prob: f64,
    /// Probability the request also reaches one random older time slot.
    pub age_decay: f64,
    /// Fraction of a block's samples removed, drawn uniform in this range.
    pub frac_range: (f64, f64),
    pub seed: u64,
}

impl TraceConfig {
    pub fn paper_default(seed: u64) -> Self {
        Self {
            unlearn_prob: 0.1,
            block_incl_prob: 0.9,
            age_decay: 0.05,
            frac_range: (0.1, 0.5),
            seed,
        }
    }

    pub fn with_prob(mut self, p: f64) -> Self {
        self.unlearn_prob = p;
        self
    }
}

/// The full FCFS request trace over a population.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// requests[r] = requests arriving after round r+1 finished training.
    rounds: Vec<Vec<UnlearnRequest>>,
}

impl RequestTrace {
    /// Generate deterministically. Removal amounts are tracked so repeated
    /// requests never remove more than a block holds.
    pub fn generate(pop: &EdgePopulation, cfg: &TraceConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut remaining: std::collections::BTreeMap<BlockId, u64> = Default::default();
        let mut rounds = Vec::with_capacity(pop.rounds() as usize);
        for r in 1..=pop.rounds() {
            for b in pop.blocks_at(r) {
                remaining.insert(b.id, b.samples);
            }
            let mut reqs = Vec::new();
            for u in 0..pop.cfg.users {
                let user = UserId(u as u32);
                if !rng.chance(cfg.unlearn_prob) {
                    continue;
                }
                let blocks = pop.user_blocks(user, r);
                let mut parts = Vec::new();
                let include = |b: &crate::data::dataset::DataBlock,
                                   rng: &mut Rng,
                                   remaining: &mut std::collections::BTreeMap<BlockId, u64>,
                                   parts: &mut Vec<(BlockId, u64)>| {
                    let left = *remaining.get(&b.id).unwrap_or(&0);
                    if left == 0 {
                        return;
                    }
                    let (lo, hi) = cfg.frac_range;
                    let frac = lo + (hi - lo) * rng.f64();
                    let take = ((b.samples as f64 * frac).round() as u64).clamp(1, left);
                    *remaining.get_mut(&b.id).unwrap() -= take;
                    parts.push((b.id, take));
                };
                // Primary target: the current time slot's capture.
                for b in blocks.iter().filter(|b| b.round == r) {
                    if rng.chance(cfg.block_incl_prob) {
                        include(b, &mut rng, &mut remaining, &mut parts);
                    }
                }
                // Occasionally (age_decay) the request reaches one random
                // older time slot — the expensive case the replacement
                // policies differ on.
                let old: Vec<_> = blocks.iter().filter(|b| b.round < r).collect();
                if !old.is_empty() && rng.chance(cfg.age_decay) {
                    let pick = rng.range(0, old.len());
                    include(old[pick], &mut rng, &mut remaining, &mut parts);
                }
                if !parts.is_empty() {
                    reqs.push(UnlearnRequest {
                        round: r,
                        user,
                        arrival_tick: r as u64,
                        parts,
                    });
                }
            }
            rounds.push(reqs);
        }
        Self { rounds }
    }

    /// Requests arriving after `round` (1-based), FCFS order.
    pub fn at(&self, round: u32) -> &[UnlearnRequest] {
        &self.rounds[(round - 1) as usize]
    }

    pub fn total_requests(&self) -> usize {
        self.rounds.iter().map(|r| r.len()).sum()
    }

    pub fn total_unlearned_samples(&self) -> u64 {
        self.rounds.iter().flatten().map(|r| r.total_samples()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::{EdgePopulation, PopulationConfig};

    fn pop(seed: u64) -> EdgePopulation {
        EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(10_000),
            users: 30,
            rounds: 6,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.7,
            seed,
        })
    }

    #[test]
    fn never_removes_more_than_block_holds() {
        let p = pop(1);
        // High probabilities to force repeated removals from the same block.
        let t = RequestTrace::generate(
            &p,
            &TraceConfig {
                unlearn_prob: 0.9,
                block_incl_prob: 0.9,
                age_decay: 0.8,
                frac_range: (0.3, 0.9),
                seed: 2,
            },
        );
        let mut removed: std::collections::BTreeMap<BlockId, u64> = Default::default();
        for r in 1..=6 {
            for req in t.at(r) {
                assert!(req.round == r);
                for (b, n) in &req.parts {
                    *removed.entry(*b).or_default() += n;
                    let block = p.block(*b).unwrap();
                    assert!(block.round <= r, "request references future block");
                    assert!(
                        removed[b] <= block.samples,
                        "block {b:?} over-removed {} > {}",
                        removed[b],
                        block.samples
                    );
                }
            }
        }
    }

    #[test]
    fn request_rate_tracks_probability() {
        let p = pop(3);
        let lo = RequestTrace::generate(&p, &TraceConfig::paper_default(4));
        let hi =
            RequestTrace::generate(&p, &TraceConfig::paper_default(4).with_prob(0.5));
        assert!(hi.total_requests() > lo.total_requests() * 2);
    }

    #[test]
    fn deterministic() {
        let p = pop(5);
        let a = RequestTrace::generate(&p, &TraceConfig::paper_default(6));
        let b = RequestTrace::generate(&p, &TraceConfig::paper_default(6));
        assert_eq!(a.total_requests(), b.total_requests());
        assert_eq!(a.total_unlearned_samples(), b.total_unlearned_samples());
    }

    #[test]
    fn requests_span_multiple_blocks() {
        let p = pop(7);
        let t = RequestTrace::generate(
            &p,
            &TraceConfig { unlearn_prob: 1.0, block_incl_prob: 0.9, age_decay: 0.9, frac_range: (0.1, 0.5), seed: 8 },
        );
        let multi = (1..=6)
            .flat_map(|r| t.at(r))
            .filter(|req| req.parts.len() > 1)
            .count();
        assert!(multi > 0, "no multi-block requests generated");
    }
}
