//! Dataset descriptors shaped like the paper's corpora.

/// Static description of a dataset family.
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub classes: usize,
    /// Total training samples in the paper's corpus.
    pub train_size: u64,
    /// Test samples (paper's split).
    pub test_size: u64,
    /// Flattened feature dimension (32x32x3 for all three corpora).
    pub features: usize,
    /// Class separability of the synthetic stand-in (higher = easier).
    /// Calibrated so relative accuracy across corpora matches the paper
    /// (SVHN easiest, CIFAR-100 hardest — Fig. 10).
    pub separability: f64,
}

pub const CIFAR10: DatasetSpec = DatasetSpec {
    name: "cifar10",
    classes: 10,
    train_size: 50_000,
    test_size: 10_000,
    features: 3072,
    separability: 1.0,
};

pub const SVHN: DatasetSpec = DatasetSpec {
    name: "svhn",
    classes: 10,
    train_size: 604_388,
    test_size: 26_032,
    features: 3072,
    separability: 1.6,
};

pub const CIFAR100: DatasetSpec = DatasetSpec {
    name: "cifar100",
    classes: 100,
    train_size: 50_000,
    test_size: 10_000,
    features: 3072,
    separability: 0.6,
};

impl DatasetSpec {
    pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
        match name {
            "cifar10" => Some(&CIFAR10),
            "svhn" => Some(&SVHN),
            "cifar100" => Some(&CIFAR100),
            _ => None,
        }
    }

    /// A copy scaled to `total` training samples (used by the real-training
    /// experiments, which run at reduced scale on the CPU PJRT client).
    pub fn scaled(&self, total: u64) -> DatasetSpec {
        DatasetSpec { train_size: total, test_size: (total / 5).max(64), ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_works() {
        assert_eq!(DatasetSpec::by_name("cifar10").unwrap().classes, 10);
        assert_eq!(DatasetSpec::by_name("cifar100").unwrap().classes, 100);
        assert_eq!(DatasetSpec::by_name("svhn").unwrap().train_size, 604_388);
        assert!(DatasetSpec::by_name("mnist").is_none());
    }

    #[test]
    fn scaled_preserves_shape() {
        let s = CIFAR10.scaled(4000);
        assert_eq!(s.train_size, 4000);
        assert_eq!(s.classes, 10);
        assert_eq!(s.features, 3072);
    }
}
