//! # CAUSE — Constraint-aware Adaptive Exact Unlearning System at the network Edge
//!
//! A production-grade reproduction of *"Edge Unlearning is Not 'on Edge'! An
//! Adaptive Exact Unlearning System on Resource-Constrained Devices"*
//! (Xia et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the Rust coordinator: user-centered data
//!   partitioning (UCDP), Fibonacci-based sub-model replacement (FiboR), the
//!   EWMA shard controller (SC), pruning-aware memory accounting (RCMP),
//!   the exact-unlearning engine, baselines (SISA / ARCANE / OMP), an edge
//!   device simulator (memory + energy), and the experiment harness that
//!   regenerates every table and figure in the paper.
//! * **Layer 2 (build-time Python, `python/compile/model.py`)** — JAX
//!   forward/backward for the edge models (MLP / CNN proxies), lowered once
//!   to HLO text by `python/compile/aot.py`.
//! * **Layer 1 (build-time Python, `python/compile/kernels/`)** — Pallas
//!   kernels for the fused dense layers and magnitude pruning, invoked from
//!   the Layer-2 graph so they lower into the same HLO artifact.
//!
//! Python never runs on the request path: the Rust binary loads
//! `artifacts/*.hlo.txt` through PJRT (`runtime` module) and drives training,
//! pruning and inference natively.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod experiments;
pub mod fleet;
pub mod load;
pub mod memory;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod persist;
pub mod prng;
pub mod pruning;
pub mod replacement;
pub mod runtime;
pub mod shard_controller;
pub mod sim;
pub mod testkit;
pub mod training;
pub mod unlearning;
pub mod util;
pub mod xla;

pub use config::ExperimentConfig;
pub use coordinator::system::{CauseSystem, SystemVariant};
pub use fleet::FleetService;
pub use persist::{Durability, DurabilityMode};
pub use unlearning::{BatchPlanner, BatchPolicy, UnlearningService};
