//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! The real deployment links the `xla` crate for PJRT execution of the
//! AOT-compiled HLO artifacts. That crate is not in the offline registry,
//! so this module mirrors the exact API surface `runtime/` consumes:
//! everything compiles and the pure-host pieces ([`Literal`] payloads)
//! behave faithfully, while the device-side entry points
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) fail with a
//! clear message. The accounting backend — every RSN / energy /
//! scalability experiment and the whole batched-unlearning service — never
//! touches PJRT and is fully functional; to light up the accuracy
//! experiments, replace this module with `use xla;` re-exports once the
//! real crate is linkable (see DESIGN.md §Runtime).

use std::path::Path;

use anyhow::{bail, Result};

/// Error message for every device-side entry point.
const UNAVAILABLE: &str = "PJRT backend unavailable: this build uses the offline `xla` stub \
     (the xla_extension bindings are not in the offline registry)";

/// Host-side literal: an f32 payload with a shape, mirroring `xla::Literal`
/// closely enough for the `HostTensor` conversions to round-trip.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Self {
        Self { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape without moving data (element counts must match; an empty
    /// `dims` is a rank-0 scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let expect: i64 = dims.iter().product();
        if expect != self.data.len() as i64 {
            bail!(
                "reshape to {:?} incompatible with {} elements",
                dims,
                self.data.len()
            );
        }
        Ok(Self { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// The literal's shape.
    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone() }))
    }

    /// Copy out the payload (f32 only in this reproduction).
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|v| T::from(*v)).collect())
    }

    /// Flatten a tuple literal. Stub literals are never tuples — tuples
    /// only arise from device execution, which the stub cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        bail!(UNAVAILABLE)
    }
}

/// Mirror of `xla::Shape` (only the array case is constructed host-side).
#[derive(Clone, Debug)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Dimensions of an array shape.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (device-side only; the stub cannot parse).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, loaded PJRT executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

/// A device buffer handle returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

/// The process-wide PJRT client. Construction fails in the stub, so no
/// downstream method is ever reached at runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        match r.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            other => panic!("expected array shape, got {other:?}"),
        }
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        // Empty dims = rank-0 scalar.
        assert!(Literal::vec1(&[5.0]).reshape(&[]).is_ok());
    }

    #[test]
    fn device_entry_points_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        assert!(Literal::vec1(&[0.0]).to_tuple().is_err());
    }
}
