//! Shard controller (SC): EWMA-style exponential decay of the shard count.
//!
//! Paper §4.5, equation (1):  S_t = γ·S + (1 − γ)·S·e^(−p·t)
//!
//! γ ∈ [0, 1] sets the floor (S_t → γ·S as t → ∞), p sets the decay rate;
//! γ = 1 disables the controller (S_t ≡ S). The controller trades per-shard
//! retrain cost (favors many shards) against replacement pressure and
//! ensemble accuracy (favor few shards) as memory fills over time.

/// The shard controller; rounds are 1-based as in the paper.
#[derive(Clone, Copy, Debug)]
pub struct ShardController {
    /// Original shard count S.
    pub s0: usize,
    /// Floor fraction γ.
    pub gamma: f64,
    /// Decay rate p.
    pub p: f64,
    /// When false, S_t = S for all t (the CAUSE-No-SC ablation).
    pub enabled: bool,
}

impl ShardController {
    pub fn new(s0: usize, gamma: f64, p: f64) -> Self {
        assert!(s0 >= 1, "shard count must be >= 1");
        assert!((0.0..=1.0).contains(&gamma), "gamma in [0,1]");
        assert!(p >= 0.0, "p >= 0");
        Self { s0, gamma, p, enabled: true }
    }

    pub fn disabled(s0: usize) -> Self {
        Self { s0, gamma: 1.0, p: 0.0, enabled: false }
    }

    /// Continuous S_t before rounding (useful for plots / tests).
    pub fn value(&self, t: u32) -> f64 {
        if !self.enabled {
            return self.s0 as f64;
        }
        let s = self.s0 as f64;
        self.gamma * s + (1.0 - self.gamma) * s * (-self.p * t as f64).exp()
    }

    /// Shard count for round `t` (1-based): rounded, clamped to [max(1,γS), S].
    pub fn shards_at(&self, t: u32) -> usize {
        let floor = ((self.gamma * self.s0 as f64).round() as usize).max(1);
        (self.value(t).round() as usize).clamp(floor, self.s0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn matches_formula() {
        let sc = ShardController::new(16, 0.5, 0.5);
        // S_1 = 0.5*16 + 0.5*16*e^-0.5 = 8 + 8*0.6065 = 12.85
        assert!((sc.value(1) - 12.852).abs() < 0.01);
        assert_eq!(sc.shards_at(1), 13);
    }

    #[test]
    fn monotonically_decreasing_to_gamma_floor() {
        let sc = ShardController::new(16, 0.5, 0.5);
        let mut prev = usize::MAX;
        for t in 1..=30 {
            let s = sc.shards_at(t);
            assert!(s <= prev, "not decreasing at t={t}");
            prev = s;
        }
        assert_eq!(sc.shards_at(30), 8); // γ·S
    }

    #[test]
    fn gamma_one_is_constant() {
        let sc = ShardController::new(8, 1.0, 0.7);
        for t in 1..=20 {
            assert_eq!(sc.shards_at(t), 8);
        }
    }

    #[test]
    fn disabled_is_constant() {
        let sc = ShardController::disabled(4);
        for t in 1..=20 {
            assert_eq!(sc.shards_at(t), 4);
        }
    }

    #[test]
    fn never_below_one_even_with_tiny_gamma() {
        let sc = ShardController::new(4, 0.0, 2.0);
        for t in 1..=50 {
            assert!(sc.shards_at(t) >= 1);
        }
    }

    #[test]
    fn prop_bounds_hold_for_random_params() {
        forall(
            0xCA05E,
            300,
            |rng, _| {
                (
                    rng.range(1, 64),
                    rng.f64(),
                    rng.f64() * 3.0,
                    rng.range(1, 40) as u32,
                )
            },
            |(s0, gamma, p, t)| {
                let sc = ShardController::new(*s0, *gamma, *p);
                let st = sc.shards_at(*t);
                if st < 1 || st > *s0 {
                    return Err(format!("S_t={st} outside [1, {s0}]"));
                }
                if sc.shards_at(t + 1) > st {
                    return Err("S_t increased over time".into());
                }
                Ok(())
            },
        );
    }
}
