//! `obs` — summarize a Chrome trace export (`*_trace.json`, written by
//! `cause run obs_dir=...`, `bench_load`, or the soak harness) into a
//! per-phase tick-budget table: for every span name, how many times it
//! ran, its total traced microseconds, and its *self* time (duration
//! minus same-lane children), with self shares summing to 100% of
//! in-span time. Marker counts (scenario phases, injected fault
//! classes) print underneath.
//!
//! Usage: `obs <trace.json> [more traces...]`

use std::process::ExitCode;

use cause::obs::budget;
use cause::util::Json;

fn summarize(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let (spans, markers) = budget::spans_from_chrome(&doc).map_err(|e| format!("{path}: {e}"))?;
    println!("{path}: {} spans", spans.len());
    print!("{}", budget::render(&budget::compute(&spans), &markers));
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("usage: obs <trace.json> [more traces...]");
        eprintln!("summarize a Chrome trace export into a per-phase tick-budget table");
        return if args.is_empty() { ExitCode::FAILURE } else { ExitCode::SUCCESS };
    }
    let mut code = ExitCode::SUCCESS;
    for (i, path) in args.iter().enumerate() {
        if i > 0 {
            println!();
        }
        if let Err(e) = summarize(path) {
            eprintln!("error: {e}");
            code = ExitCode::FAILURE;
        }
    }
    code
}
