//! Seeded chaos soak over the scenario corpus: every run drives one
//! scenario open-loop while a [`ChaosPlan`] injects worker kills with
//! failover, transport fault bursts, fsync failures, battery collapse,
//! and full crash-restart cycles, with the invariant checker auditing
//! durability after every tick-window (see `cause::load::chaos`).
//!
//! Knobs (environment):
//!
//! * `CAUSE_SOAK_TICKS`  — arrival ticks per run (default 48; CI's
//!   time-boxed job sets 32).
//! * `CAUSE_SOAK_SEEDS`  — seeds per scenario (default 8).
//! * `CAUSE_SOAK_FULL=1` — soak the whole corpus instead of the default
//!   three-scenario mix (main-branch pushes set this).
//! * `CAUSE_SOAK_JSON`   — report path (default `SOAK_report.json`).
//! * `CAUSE_SOAK_TRACE`  — when set, trace the first run (spans + fault
//!   markers) and write its Chrome trace export to this path; summarize
//!   it with the `obs` binary.
//!
//! Odd seeds ship over the file-backed [`FileSpool`] transport, even
//! seeds over the in-process replica store, so both shipping paths soak
//! in every sweep. Exit status is non-zero if any run reports an
//! invariant violation — CI fails loudly, with the report uploaded as
//! an artifact.

use cause::load::chaos::{run_chaos, ChaosCfg, ChaosPlan, FaultClass};
use cause::load::corpus;
use cause::util::Json;

/// Default scenario mix: a bursty mains-powered queue, a harvest-limited
/// eclipse orbit, and an elastically resharded fleet — the three load
/// shapes that stress durability differently.
const DEFAULT_MIX: [&str; 3] = ["gdpr_storm", "satellite_windows", "iot_fleet_churn"];

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let ticks = env_u64("CAUSE_SOAK_TICKS", 48);
    let seeds = env_u64("CAUSE_SOAK_SEEDS", 8);
    let full = std::env::var("CAUSE_SOAK_FULL").as_deref() == Ok("1");
    let out = std::env::var("CAUSE_SOAK_JSON").unwrap_or_else(|_| "SOAK_report.json".into());
    let trace_out = std::env::var("CAUSE_SOAK_TRACE").ok();
    let mut trace: Option<Json> = None;

    let corpus = corpus();
    let scenarios: Vec<_> = corpus
        .iter()
        .filter(|s| full || DEFAULT_MIX.contains(&s.name()))
        .collect();

    let mut reports = Vec::new();
    let mut violations = 0usize;
    for scenario in &scenarios {
        for i in 0..seeds {
            let seed = 0x50a0_0000 ^ (i << 8) ^ ticks;
            let plan = ChaosPlan::seeded(seed, ticks, &FaultClass::ALL);
            let cfg = ChaosCfg {
                ticks,
                seed,
                // Odd seeds take the file-backed spool path.
                spool: i % 2 == 1,
                // Trace the first run only: one artifact is plenty and
                // keeps the soak's runtime budget for the faults.
                obs: trace_out.is_some() && trace.is_none(),
                ..ChaosCfg::default()
            };
            let label = format!(
                "{} seed={seed:#x} {}",
                scenario.name(),
                if cfg.spool { "spool" } else { "store" }
            );
            match run_chaos(scenario.as_ref(), &plan, &cfg) {
                Ok(report) => {
                    let ok = report.ok();
                    violations += report.violations.len();
                    eprintln!(
                        "soak: {label}: {} ({} faults, {} barriers, {} served)",
                        if ok { "ok" } else { "VIOLATIONS" },
                        report.faults.len(),
                        report.barriers,
                        report.served
                    );
                    for v in &report.violations {
                        eprintln!("soak:   violation: {v}");
                    }
                    let g = |k: &str| {
                        report.telemetry.get(k).and_then(Json::as_u64).unwrap_or(0)
                    };
                    eprintln!(
                        "soak:   ship attempts {} faults {} failed {} | journal appended {} \
                         fsyncs {} | latency dropped {} slo_miss {}",
                        g("ship_attempts"),
                        g("ship_faults"),
                        g("ship_failed"),
                        g("journal_appended"),
                        g("journal_fsyncs"),
                        g("latency_dropped"),
                        g("latency_slo_miss")
                    );
                    if report.trace.is_some() {
                        trace = report.trace.clone();
                    }
                    reports.push(report.to_json());
                }
                Err(e) => {
                    violations += 1;
                    eprintln!("soak: {label}: harness error: {e:#}");
                    reports.push(
                        Json::obj()
                            .set("scenario", scenario.name())
                            .set("seed", format!("{seed:#x}"))
                            .set("ok", false)
                            .set("error", format!("{e:#}")),
                    );
                }
            }
        }
    }

    let doc = Json::obj()
        .set("ticks", ticks)
        .set("seeds_per_scenario", seeds)
        .set("scenarios", Json::Arr(scenarios.iter().map(|s| Json::Str(s.name().into())).collect()))
        .set("runs", reports.len())
        .set("violations", violations as u64)
        .set("ok", violations == 0)
        .set("reports", Json::Arr(reports));
    if let Err(e) = std::fs::write(&out, doc.to_pretty()) {
        eprintln!("soak: failed to write {out}: {e}");
        std::process::exit(2);
    }
    if let Some(path) = &trace_out {
        match &trace {
            Some(t) => {
                if let Err(e) = std::fs::write(path, t.to_pretty()) {
                    eprintln!("soak: failed to write trace {path}: {e}");
                    std::process::exit(2);
                }
                eprintln!("soak: trace -> {path}");
            }
            None => eprintln!("soak: no traced run completed; {path} not written"),
        }
    }
    eprintln!(
        "soak: {} runs, {} violations -> {out}",
        scenarios.len() as u64 * seeds,
        violations
    );
    if violations > 0 {
        std::process::exit(1);
    }
}
