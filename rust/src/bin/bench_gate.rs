//! `bench_gate` — CI bench-regression gate.
//!
//! Compares the machine-readable summary `bench_coordinator` wrote
//! (`BENCH_coordinator.json`) against the committed `BENCH_baseline.json`
//! and fails (exit 1) when the scheduler regresses:
//!
//! * `gate.retrains_coalesced` drops below the baseline (the coalescing
//!   win shrank), or
//! * `gate.p99_queue_delay` grows more than 20% over the baseline (the
//!   latency SLO frontier moved the wrong way).
//!
//! Both values are deterministic workload counters (never wall-clock), so
//! the gate is stable across runner hardware.
//!
//! A baseline with `"bootstrap": true` passes unconditionally and prints
//! the block to commit as the pinned baseline — used to seed the gate on a
//! branch whose workload changed intentionally.
//!
//! ```bash
//! cargo run --release --bin bench_gate -- BENCH_baseline.json BENCH_coordinator.json
//! ```

use std::process::ExitCode;

use cause::util::Json;

/// Allowed relative growth of p99 queueing delay before the gate fails.
const P99_TOLERANCE: f64 = 0.20;

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn gate_value(doc: &Json, path: &str, key: &str) -> Result<f64, String> {
    doc.at(&["gate", key])
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing numeric field gate.{key}"))
}

fn run(baseline_path: &str, current_path: &str) -> Result<(), String> {
    let baseline = load(baseline_path)?;
    let current = load(current_path)?;

    let cur_coalesced = gate_value(&current, current_path, "retrains_coalesced")?;
    let cur_p99 = gate_value(&current, current_path, "p99_queue_delay")?;

    if baseline.get("bootstrap").and_then(Json::as_bool) == Some(true) {
        println!(
            "bench_gate: baseline {baseline_path} is in bootstrap mode — \
             pin it by committing:\n{}",
            Json::obj()
                .set(
                    "gate",
                    Json::obj()
                        .set("retrains_coalesced", cur_coalesced)
                        .set("p99_queue_delay", cur_p99),
                )
                .to_pretty()
        );
        return Ok(());
    }

    let base_coalesced = gate_value(&baseline, baseline_path, "retrains_coalesced")?;
    let base_p99 = gate_value(&baseline, baseline_path, "p99_queue_delay")?;

    println!(
        "bench_gate: retrains_coalesced {base_coalesced} -> {cur_coalesced}, \
         p99_queue_delay {base_p99} -> {cur_p99}"
    );

    let mut failures = Vec::new();
    if cur_coalesced < base_coalesced {
        failures.push(format!(
            "retrains_coalesced dropped: {cur_coalesced} < baseline {base_coalesced}"
        ));
    }
    let p99_limit = base_p99 * (1.0 + P99_TOLERANCE);
    if cur_p99 > p99_limit + 1e-9 {
        failures.push(format!(
            "p99 queueing delay grew >{:.0}%: {cur_p99} > {p99_limit:.3} \
             (baseline {base_p99})",
            P99_TOLERANCE * 100.0
        ));
    }
    if failures.is_empty() {
        println!("bench_gate: OK");
        Ok(())
    } else {
        Err(failures.join("; "))
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (baseline, current) = match args.as_slice() {
        [b, c] => (b.as_str(), c.as_str()),
        _ => {
            eprintln!("usage: bench_gate <BENCH_baseline.json> <BENCH_coordinator.json>");
            return ExitCode::FAILURE;
        }
    };
    match run(baseline, current) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("bench_gate: FAIL: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, text: &str) -> String {
        let dir = std::env::temp_dir().join("cause_bench_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p.to_string_lossy().into_owned()
    }

    fn doc(coalesced: f64, p99: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj()
                    .set("retrains_coalesced", coalesced)
                    .set("p99_queue_delay", p99),
            )
            .to_pretty()
    }

    #[test]
    fn passes_on_equal_and_improved() {
        let base = write_tmp("base.json", &doc(40.0, 4.0));
        let same = write_tmp("same.json", &doc(40.0, 4.0));
        let better = write_tmp("better.json", &doc(55.0, 3.0));
        assert!(run(&base, &same).is_ok());
        assert!(run(&base, &better).is_ok());
        // Within the 20% latency tolerance.
        let near = write_tmp("near.json", &doc(40.0, 4.8));
        assert!(run(&base, &near).is_ok());
    }

    #[test]
    fn fails_on_regressions() {
        let base = write_tmp("base2.json", &doc(40.0, 4.0));
        let fewer = write_tmp("fewer.json", &doc(39.0, 4.0));
        let slower = write_tmp("slower.json", &doc(40.0, 4.81));
        assert!(run(&base, &fewer).is_err());
        assert!(run(&base, &slower).is_err());
        assert!(run("/nonexistent.json", &base).is_err());
        let junk = write_tmp("junk.json", "not json");
        assert!(run(&junk, &base).is_err());
    }

    #[test]
    fn bootstrap_baseline_always_passes() {
        let boot = write_tmp(
            "boot.json",
            &Json::obj().set("bootstrap", true).to_pretty(),
        );
        let cur = write_tmp("cur.json", &doc(12.0, 2.0));
        assert!(run(&boot, &cur).is_ok());
        // Bootstrap still requires a well-formed current summary.
        let junk = write_tmp("junk2.json", "{}");
        assert!(run(&boot, &junk).is_err());
    }
}
