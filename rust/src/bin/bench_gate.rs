//! `bench_gate` — CI bench-regression gate.
//!
//! Compares the machine-readable summaries the benches wrote against the
//! committed `BENCH_baseline.json` and fails (exit 1) when the scheduler,
//! the planner, the checkpoint codec, the durability layer, the sharded
//! fleet, or the open-loop load harness regresses:
//!
//! * `gate.retrains_coalesced` (from `BENCH_coordinator.json`) drops below
//!   the baseline (the coalescing win shrank), or
//! * `gate.p99_queue_delay` grows more than 20% over the baseline (the
//!   latency SLO frontier moved the wrong way), or
//! * `gate.probe_speedup` (from `BENCH_scale.json`) drops more than 20%
//!   below `scale.probe_speedup` in the baseline (the indexed planner lost
//!   throughput against the compiled-in naive-scan oracle), or
//! * `gate.ratio` / `gate.decode_mbps` (from `BENCH_compress.json`) fall
//!   below the `compress.*` floors, or
//! * `gate.append_mbps` / `gate.append_mbps_fsync` /
//!   `gate.group_commit_amortization` / `gate.recovery_events_per_s` /
//!   `gate.replica_compaction_ratio`
//!   (from `BENCH_persist.json`) fall below the `persist.*` floors — the
//!   write-ahead log appends (flush-only or with per-append fsync
//!   barriers) or crash recovery replays slower than the committed
//!   floor, group commit stopped amortizing barriers across the
//!   batched window, or the shipped peer replica stopped being bounded
//!   by the source's compacted live WAL (ratio <= 1 means the replica
//!   accretes the full history). Floors are conservative
//!   invariant-derived values and are checked directly, without an
//!   extra tolerance. Or
//! * `gate.scaling_2w` (from `BENCH_fleet.json`) falls below the
//!   `fleet.scaling_2w` floor, or `gate.merge_overhead` grows above the
//!   `fleet.merge_overhead` ceiling, or
//! * any `load.<scenario>_rps_at_slo` floor (from `BENCH_load.json`) is
//!   missed — the open-loop harness measured a lower sustainable
//!   deletion throughput at SLO for that scenario — or the
//!   `load.p999_over_p50` histogram-sanity ceiling is exceeded (the
//!   latency tail at the certified rate blew out relative to the
//!   median). The load numbers are deterministic logical-tick counters,
//!   so the floors are checked exactly and ratchet like
//!   `retrains_coalesced` — but only within one bench mode:
//!   `CAUSE_BENCH_FAST` changes bench_load's swept rate grid and tick
//!   counts, so when the baseline's `load` section pins a `mode`
//!   (`"fast"`/`"full"`), an artifact measured in the other mode fails
//!   the gate with a re-pin instruction instead of comparing
//!   incomparable numbers. Or
//! * `gate.overhead_pct` (from `BENCH_obs.json`) grows above the
//!   `obs.overhead_pct` ceiling — span tracing stopped being cheap
//!   enough to leave on. The ceiling is wall-clock-shaped (smaller is
//!   better) and, like `merge_overhead`, is never auto-tightened by the
//!   ratchet.
//!
//! **Every pinned baseline section must have a matching artifact.** If the
//! baseline pins `scale`/`compress`/`persist`/`fleet`/`load` floors and
//! the corresponding bench file is not supplied (or not discovered), the
//! gate fails loudly instead of silently skipping the section — a
//! forgotten CLI arg or a bench step that stopped producing its artifact
//! must never turn a gate off.
//!
//! Two invocation forms:
//!
//! ```bash
//! # Auto-discovery (what CI uses): scan the baseline's directory for
//! # BENCH_*.json files and classify each by its top-level "bench" field.
//! cargo run --release --bin bench_gate -- BENCH_baseline.json
//!
//! # Positional (back-compatible): explicit artifact paths.
//! cargo run --release --bin bench_gate -- \
//!     BENCH_baseline.json BENCH_coordinator.json \
//!     [BENCH_scale.json [BENCH_compress.json [BENCH_persist.json \
//!     [BENCH_fleet.json [BENCH_load.json]]]]]
//! ```
//!
//! The coordinator values are deterministic workload counters, the scale
//! value is a same-machine ratio (indexed vs naive on identical state),
//! the compression ratio is a deterministic function of the bench's
//! seeded tensors, and the load section is fully deterministic — so those
//! gates are stable across runner hardware; only the decode-throughput,
//! append-throughput, and recovery-rate floors are wall-clock, and they
//! are pinned far below any plausible machine. The fleet scaling value is
//! a same-machine ratio too, but it additionally depends on the runner
//! having ≥2 usable cores, so (like the wall-clock floors) it is never
//! auto-raised by the ratchet; the merge-overhead ceiling is likewise
//! never auto-lowered.
//!
//! A baseline with `"bootstrap": true` passes unconditionally. On every
//! pass — bootstrap or green — the gate prints **one** ready-to-commit
//! baseline document covering every measured section: a tighten-only
//! merge of the committed values with the run's artifacts (a run that
//! merely passed within tolerance cannot loosen a floor, and wall-clock
//! floors are never auto-raised), so green main runs ratchet the floors
//! by committing it verbatim — no per-file fragments to stitch together.

use std::collections::BTreeMap;
use std::path::Path;
use std::process::ExitCode;

use cause::util::Json;

/// Allowed relative growth of p99 queueing delay before the gate fails.
const P99_TOLERANCE: f64 = 0.20;

/// Allowed relative drop of the planner probe speedup before the gate
/// fails.
const SPEEDUP_TOLERANCE: f64 = 0.20;

/// Artifact kinds the gate understands, in positional-argument order.
/// Each is both the value of an artifact's top-level `"bench"` field
/// (for auto-discovery) and — except `coordinator`, whose floors live
/// under `gate` — the baseline section name holding its floors.
const KINDS: [&str; 7] =
    ["coordinator", "scale", "compress", "persist", "fleet", "load", "obs"];

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn gate_value(doc: &Json, path: &str, key: &str) -> Result<f64, String> {
    doc.at(&["gate", key])
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing numeric field gate.{key}"))
}

/// The whole `gate` object of an artifact as a name → value map (the
/// load artifact carries one dynamic key per scenario).
fn gate_map(doc: &Json, path: &str) -> Result<BTreeMap<String, f64>, String> {
    let Some(Json::Obj(m)) = doc.get("gate") else {
        return Err(format!("{path}: missing gate object"));
    };
    m.iter()
        .map(|(k, v)| {
            v.as_f64()
                .map(|x| (k.clone(), x))
                .ok_or_else(|| format!("{path}: gate.{k} is not numeric"))
        })
        .collect()
}

/// The load artifact's gate payload. `mode` is bench_load's top-level
/// `"mode"` field (`"fast"`/`"full"`): the swept rate grid and tick
/// counts differ between modes, so the deterministic counters are only
/// comparable to floors pinned in the same mode.
#[derive(Clone)]
struct LoadArtifact {
    mode: Option<String>,
    gate: BTreeMap<String, f64>, // <scenario>_rps_at_slo + p999_over_p50
}

/// Current gate values measured by this run's artifacts.
#[derive(Clone)]
struct Current {
    coalesced: f64,
    p99: f64,
    speedup: Option<f64>,
    compress: Option<(f64, f64)>, // (ratio, decode_mbps)
    // (append_mbps, append_mbps_fsync, group_commit_amortization,
    // recovery_events_per_s, replica_compaction_ratio)
    persist: Option<(f64, f64, f64, f64, f64)>,
    fleet: Option<(f64, f64)>,    // (scaling_2w, merge_overhead)
    load: Option<LoadArtifact>,
    obs: Option<f64>,             // tracing overhead_pct
}

impl Current {
    /// The single baseline document these artifacts support — printed on
    /// every pass (bootstrap included), covering every measured section.
    /// A true ratchet: counters/ratios take the better of committed vs
    /// measured, p99 the smaller, and wall-clock floors (decode MB/s,
    /// append MB/s, recovery events/s) are never raised automatically — a
    /// fast runner must not pin a floor slower machines would fail; when
    /// no floor is committed they get 10x headroom under the measured
    /// rate. The load section is deterministic in both directions:
    /// `*_rps_at_slo` floors take the max of committed and measured, the
    /// `p999_over_p50` ceiling the min, and committed keys the run did
    /// not measure are kept so they cannot silently un-pin. The printed
    /// section also stamps the `mode` the numbers were measured in, so
    /// future gate runs refuse cross-mode comparison.
    fn pin_block(&self, baseline: &Json) -> Json {
        let base = |path: &[&str]| baseline.at(path).and_then(Json::as_f64);
        let coalesced = self
            .coalesced
            .max(base(&["gate", "retrains_coalesced"]).unwrap_or(self.coalesced));
        let p99 = self.p99.min(base(&["gate", "p99_queue_delay"]).unwrap_or(self.p99));
        let mut pin = Json::obj().set(
            "gate",
            Json::obj()
                .set("retrains_coalesced", coalesced)
                .set("p99_queue_delay", p99),
        );
        if let Some(s) = self.speedup {
            let s = s.max(base(&["scale", "probe_speedup"]).unwrap_or(s));
            pin = pin.set("scale", Json::obj().set("probe_speedup", s));
        }
        if let Some((ratio, mbps)) = self.compress {
            let ratio = ratio.max(base(&["compress", "ratio"]).unwrap_or(ratio));
            let mbps = base(&["compress", "decode_mbps"]).unwrap_or(mbps / 10.0);
            pin = pin.set(
                "compress",
                Json::obj().set("ratio", ratio).set("decode_mbps", mbps),
            );
        }
        if let Some((append, fsync, amort, recovery, replica)) = self.persist {
            let append = base(&["persist", "append_mbps"]).unwrap_or(append / 10.0);
            let fsync = base(&["persist", "append_mbps_fsync"]).unwrap_or(fsync / 10.0);
            // The amortization ratio is a deterministic counter ratio,
            // but the fast/full bench modes run different workloads, so
            // it is pinned with headroom and never auto-raised. The same
            // holds for the replica compaction ratio: the regression it
            // gates (a peer replica accreting unbounded history) drives
            // it to <= 1, so a conservative floor is enough.
            let amort =
                base(&["persist", "group_commit_amortization"]).unwrap_or(amort / 2.0);
            let recovery =
                base(&["persist", "recovery_events_per_s"]).unwrap_or(recovery / 10.0);
            let replica =
                base(&["persist", "replica_compaction_ratio"]).unwrap_or(replica / 2.0);
            pin = pin.set(
                "persist",
                Json::obj()
                    .set("append_mbps", append)
                    .set("append_mbps_fsync", fsync)
                    .set("group_commit_amortization", amort)
                    .set("recovery_events_per_s", recovery)
                    .set("replica_compaction_ratio", replica),
            );
        }
        if let Some((scaling, merge)) = self.fleet {
            // Parallel scaling depends on the runner's free cores, so a
            // many-core machine must not ratchet the floor to a ratio a
            // 2-core runner cannot hit; a 1.25x headroom applies when no
            // floor is committed. The merge ceiling is wall-clock-shaped
            // (smaller is better) and is likewise never auto-tightened.
            let scaling = base(&["fleet", "scaling_2w"]).unwrap_or(scaling / 1.25);
            let merge = base(&["fleet", "merge_overhead"]).unwrap_or(merge * 10.0);
            pin = pin.set(
                "fleet",
                Json::obj().set("scaling_2w", scaling).set("merge_overhead", merge),
            );
        }
        if let Some(measured) = &self.load {
            let mut merged: BTreeMap<String, f64> = BTreeMap::new();
            if let Some(Json::Obj(committed)) = baseline.get("load") {
                for (k, v) in committed {
                    if let Some(x) = v.as_f64() {
                        merged.insert(k.clone(), x);
                    }
                }
            }
            for (k, &x) in &measured.gate {
                merged
                    .entry(k.clone())
                    .and_modify(|c| {
                        // Ceiling ratchets down, floors ratchet up — all
                        // deterministic logical-tick numbers.
                        let ceiling = k == "p999_over_p50";
                        *c = if ceiling { c.min(x) } else { c.max(x) };
                    })
                    .or_insert(x);
            }
            let mut section = Json::obj();
            // Stamp the mode the floors were measured in, so the next
            // gate run refuses cross-mode comparison. pin_block only
            // prints on a pass, where any pinned mode already matched.
            if let Some(mode) = measured
                .mode
                .as_deref()
                .or_else(|| baseline.at(&["load", "mode"]).and_then(Json::as_str))
            {
                section = section.set("mode", mode);
            }
            for (k, x) in merged {
                section = section.set(&k, x);
            }
            pin = pin.set("load", section);
        }
        if let Some(overhead) = self.obs {
            // The tracing-overhead ceiling is wall-clock-shaped (smaller
            // is better): a quiet runner must not tighten it to a value
            // loaded machines would fail, so the committed ceiling always
            // wins. With nothing committed it pins at the 5% budget (or
            // 2x the measured overhead if a slow bootstrap run exceeds
            // even that).
            let ceiling =
                base(&["obs", "overhead_pct"]).unwrap_or((overhead * 2.0).max(5.0));
            pin = pin.set("obs", Json::obj().set("overhead_pct", ceiling));
        }
        pin
    }
}

/// True when the baseline pins a non-empty numeric section under `name`.
fn baseline_pins(baseline: &Json, name: &str) -> bool {
    matches!(baseline.get(name), Some(Json::Obj(m)) if !m.is_empty())
}

#[allow(clippy::too_many_arguments)]
fn run(
    baseline_path: &str,
    current_path: &str,
    scale_path: Option<&str>,
    compress_path: Option<&str>,
    persist_path: Option<&str>,
    fleet_path: Option<&str>,
    load_path: Option<&str>,
    obs_path: Option<&str>,
) -> Result<(), String> {
    let baseline = load(baseline_path)?;
    let current = load(current_path)?;

    let cur = Current {
        coalesced: gate_value(&current, current_path, "retrains_coalesced")?,
        p99: gate_value(&current, current_path, "p99_queue_delay")?,
        speedup: match scale_path {
            Some(p) => Some(gate_value(&load(p)?, p, "probe_speedup")?),
            None => None,
        },
        compress: match compress_path {
            Some(p) => {
                let doc = load(p)?;
                Some((gate_value(&doc, p, "ratio")?, gate_value(&doc, p, "decode_mbps")?))
            }
            None => None,
        },
        persist: match persist_path {
            Some(p) => {
                let doc = load(p)?;
                Some((
                    gate_value(&doc, p, "append_mbps")?,
                    gate_value(&doc, p, "append_mbps_fsync")?,
                    gate_value(&doc, p, "group_commit_amortization")?,
                    gate_value(&doc, p, "recovery_events_per_s")?,
                    gate_value(&doc, p, "replica_compaction_ratio")?,
                ))
            }
            None => None,
        },
        fleet: match fleet_path {
            Some(p) => {
                let doc = load(p)?;
                Some((
                    gate_value(&doc, p, "scaling_2w")?,
                    gate_value(&doc, p, "merge_overhead")?,
                ))
            }
            None => None,
        },
        load: match load_path {
            Some(p) => {
                let doc = load(p)?;
                Some(LoadArtifact {
                    mode: doc.get("mode").and_then(Json::as_str).map(str::to_owned),
                    gate: gate_map(&doc, p)?,
                })
            }
            None => None,
        },
        obs: match obs_path {
            Some(p) => Some(gate_value(&load(p)?, p, "overhead_pct")?),
            None => None,
        },
    };

    if baseline.get("bootstrap").and_then(Json::as_bool) == Some(true) {
        println!(
            "bench_gate: baseline {baseline_path} is in bootstrap mode — \
             pin it by committing:\n{}",
            cur.pin_block(&baseline).to_pretty()
        );
        return Ok(());
    }

    let base_coalesced = gate_value(&baseline, baseline_path, "retrains_coalesced")?;
    let base_p99 = gate_value(&baseline, baseline_path, "p99_queue_delay")?;

    println!(
        "bench_gate: retrains_coalesced {base_coalesced} -> {}, \
         p99_queue_delay {base_p99} -> {}",
        cur.coalesced, cur.p99
    );

    let mut failures = Vec::new();

    // A pinned baseline section with no matching artifact is a hard
    // failure: silently skipping a gate is exactly the brittleness this
    // check exists to remove.
    for (section, present) in [
        ("scale", cur.speedup.is_some()),
        ("compress", cur.compress.is_some()),
        ("persist", cur.persist.is_some()),
        ("fleet", cur.fleet.is_some()),
        ("load", cur.load.is_some()),
        ("obs", cur.obs.is_some()),
    ] {
        if baseline_pins(&baseline, section) && !present {
            failures.push(format!(
                "baseline pins `{section}` floors but no matching bench artifact was \
                 supplied or discovered — refusing to silently skip that gate"
            ));
        }
    }

    if cur.coalesced < base_coalesced {
        failures.push(format!(
            "retrains_coalesced dropped: {} < baseline {base_coalesced}",
            cur.coalesced
        ));
    }
    let p99_limit = base_p99 * (1.0 + P99_TOLERANCE);
    if cur.p99 > p99_limit + 1e-9 {
        failures.push(format!(
            "p99 queueing delay grew >{:.0}%: {} > {p99_limit:.3} \
             (baseline {base_p99})",
            P99_TOLERANCE * 100.0,
            cur.p99
        ));
    }

    if let Some(cur_speedup) = cur.speedup {
        match baseline.at(&["scale", "probe_speedup"]).and_then(Json::as_f64) {
            Some(base_speedup) => {
                println!(
                    "bench_gate: probe_speedup {base_speedup:.2} -> {cur_speedup:.2}"
                );
                let floor = base_speedup * (1.0 - SPEEDUP_TOLERANCE);
                if cur_speedup < floor - 1e-9 {
                    failures.push(format!(
                        "planner probe speedup dropped >{:.0}%: {cur_speedup:.2} < \
                         {floor:.2} (baseline {base_speedup:.2})",
                        SPEEDUP_TOLERANCE * 100.0
                    ));
                }
            }
            None => println!(
                "bench_gate: {baseline_path} has no scale.probe_speedup — the \
                 merged baseline below pins it"
            ),
        }
    }

    if let Some((cur_ratio, cur_mbps)) = cur.compress {
        let base_ratio = baseline.at(&["compress", "ratio"]).and_then(Json::as_f64);
        let base_mbps = baseline.at(&["compress", "decode_mbps"]).and_then(Json::as_f64);
        match (base_ratio, base_mbps) {
            (Some(ratio_floor), Some(mbps_floor)) => {
                println!(
                    "bench_gate: compress ratio floor {ratio_floor:.2} -> {cur_ratio:.2}, \
                     decode floor {mbps_floor:.0} MB/s -> {cur_mbps:.0} MB/s"
                );
                if cur_ratio < ratio_floor - 1e-9 {
                    failures.push(format!(
                        "compression ratio fell below floor: {cur_ratio:.2} < {ratio_floor:.2}"
                    ));
                }
                if cur_mbps < mbps_floor - 1e-9 {
                    failures.push(format!(
                        "decode throughput fell below floor: {cur_mbps:.0} < \
                         {mbps_floor:.0} MB/s"
                    ));
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no compress floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if let Some((cur_append, cur_fsync, cur_amort, cur_recovery, cur_replica)) = cur.persist
    {
        let base_append = baseline.at(&["persist", "append_mbps"]).and_then(Json::as_f64);
        let base_recovery = baseline
            .at(&["persist", "recovery_events_per_s"])
            .and_then(Json::as_f64);
        match (base_append, base_recovery) {
            (Some(append_floor), Some(recovery_floor)) => {
                println!(
                    "bench_gate: persist append floor {append_floor:.1} -> \
                     {cur_append:.1} MB/s, recovery floor {recovery_floor:.0} -> \
                     {cur_recovery:.0} events/s"
                );
                if cur_append < append_floor - 1e-9 {
                    failures.push(format!(
                        "log append throughput fell below floor: {cur_append:.1} < \
                         {append_floor:.1} MB/s"
                    ));
                }
                if cur_recovery < recovery_floor - 1e-9 {
                    failures.push(format!(
                        "recovery replay rate fell below floor: {cur_recovery:.0} < \
                         {recovery_floor:.0} events/s"
                    ));
                }
                // The fsync-mode floors rode in later; a baseline that
                // pins them gates them, one that doesn't gets them pinned
                // by the merged document below.
                if let Some(floor) = baseline
                    .at(&["persist", "append_mbps_fsync"])
                    .and_then(Json::as_f64)
                {
                    println!(
                        "bench_gate: persist fsync-append floor {floor:.2} -> \
                         {cur_fsync:.2} MB/s"
                    );
                    if cur_fsync < floor - 1e-9 {
                        failures.push(format!(
                            "fsync-mode append throughput fell below floor: \
                             {cur_fsync:.2} < {floor:.2} MB/s"
                        ));
                    }
                }
                if let Some(floor) = baseline
                    .at(&["persist", "group_commit_amortization"])
                    .and_then(Json::as_f64)
                {
                    println!(
                        "bench_gate: persist group-commit amortization floor \
                         {floor:.1}x -> {cur_amort:.1}x"
                    );
                    if cur_amort < floor - 1e-9 {
                        failures.push(format!(
                            "group-commit amortization fell below floor: \
                             {cur_amort:.1}x < {floor:.1}x events per barrier"
                        ));
                    }
                }
                if let Some(floor) = baseline
                    .at(&["persist", "replica_compaction_ratio"])
                    .and_then(Json::as_f64)
                {
                    println!(
                        "bench_gate: persist replica compaction floor \
                         {floor:.2}x -> {cur_replica:.2}x"
                    );
                    if cur_replica < floor - 1e-9 {
                        failures.push(format!(
                            "replica compaction ratio fell below floor: \
                             {cur_replica:.2}x < {floor:.2}x (peer replica no \
                             longer bounded by the source's live WAL)"
                        ));
                    }
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no persist floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if let Some((cur_scaling, cur_merge)) = cur.fleet {
        let base_scaling = baseline.at(&["fleet", "scaling_2w"]).and_then(Json::as_f64);
        let base_merge = baseline.at(&["fleet", "merge_overhead"]).and_then(Json::as_f64);
        match (base_scaling, base_merge) {
            (Some(scaling_floor), Some(merge_ceiling)) => {
                println!(
                    "bench_gate: fleet scaling floor {scaling_floor:.2}x -> \
                     {cur_scaling:.2}x, merge ceiling {merge_ceiling:.2} -> \
                     {cur_merge:.3}"
                );
                if cur_scaling < scaling_floor - 1e-9 {
                    failures.push(format!(
                        "2-worker fleet scaling fell below floor: {cur_scaling:.2}x < \
                         {scaling_floor:.2}x"
                    ));
                }
                if cur_merge > merge_ceiling + 1e-9 {
                    failures.push(format!(
                        "fleet receipt-merge overhead grew above ceiling: \
                         {cur_merge:.3} > {merge_ceiling:.3}"
                    ));
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no fleet floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if let Some(cur_load) = &cur.load {
        match baseline.get("load") {
            Some(Json::Obj(committed)) => {
                // Fast and full mode sweep different rate grids and tick
                // counts, so cross-mode comparison is meaningless: fail
                // loudly (never gate incomparable numbers, never skip
                // silently) and don't bother with the per-key checks.
                let pinned_mode = baseline.at(&["load", "mode"]).and_then(Json::as_str);
                let mode_ok = match (pinned_mode, cur_load.mode.as_deref()) {
                    (None, _) => true,
                    (Some(pinned), Some(measured)) if pinned == measured => {
                        println!("bench_gate: load mode `{measured}` matches baseline");
                        true
                    }
                    (Some(pinned), Some(measured)) => {
                        failures.push(format!(
                            "load floors were pinned in `{pinned}` mode but the load \
                             artifact was measured in `{measured}` mode — the swept \
                             rate grid and tick counts differ across modes, so the \
                             numbers are not comparable; re-run bench_load in \
                             `{pinned}` mode (CI sets CAUSE_BENCH_FAST=1 → fast) or \
                             re-pin load.* from a `{measured}`-mode merged baseline"
                        ));
                        false
                    }
                    (Some(pinned), None) => {
                        failures.push(format!(
                            "baseline pins load.mode = `{pinned}` but the load \
                             artifact records no mode — re-run bench_load (its \
                             summary carries a top-level \"mode\" field)"
                        ));
                        false
                    }
                };
                for (key, v) in committed {
                    if !mode_ok || key == "mode" {
                        continue;
                    }
                    let Some(pinned) = v.as_f64() else {
                        failures.push(format!(
                            "baseline load.{key} is not numeric — fix the baseline"
                        ));
                        continue;
                    };
                    let Some(&measured) = cur_load.gate.get(key) else {
                        failures.push(format!(
                            "baseline pins load.{key} but the load artifact's gate \
                             has no such key — a scenario disappeared from the corpus"
                        ));
                        continue;
                    };
                    if let Some(scenario) = key.strip_suffix("_rps_at_slo") {
                        println!(
                            "bench_gate: load {scenario} rps_at_slo floor {pinned} -> \
                             {measured}"
                        );
                        if measured < pinned - 1e-9 {
                            failures.push(format!(
                                "open-loop throughput-at-SLO regressed for \
                                 `{scenario}`: {measured} < floor {pinned} req/tick"
                            ));
                        }
                    } else if key == "p999_over_p50" {
                        println!(
                            "bench_gate: load p999/p50 ceiling {pinned} -> {measured}"
                        );
                        if measured > pinned + 1e-9 {
                            failures.push(format!(
                                "latency-histogram tail ratio grew above ceiling: \
                                 p999/p50 {measured} > {pinned}"
                            ));
                        }
                    } else {
                        failures.push(format!(
                            "baseline load.{key} is neither a `*_rps_at_slo` floor \
                             nor the `p999_over_p50` ceiling — unknown gate key"
                        ));
                    }
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no load floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if let Some(cur_obs) = cur.obs {
        match baseline.at(&["obs", "overhead_pct"]).and_then(Json::as_f64) {
            Some(ceiling) => {
                println!(
                    "bench_gate: obs overhead ceiling {ceiling:.1}% -> {cur_obs:.2}%"
                );
                if cur_obs > ceiling + 1e-9 {
                    failures.push(format!(
                        "span-tracing overhead grew above ceiling: {cur_obs:.2}% > \
                         {ceiling:.1}% (observability must stay cheap enough to \
                         leave on)"
                    ));
                }
            }
            None => println!(
                "bench_gate: {baseline_path} has no obs ceiling — the merged \
                 baseline below pins it"
            ),
        }
    }

    if failures.is_empty() {
        println!("bench_gate: OK");
        // One ready-to-commit document covering every measured section
        // (tighten-only merge against the committed values) — commit it
        // verbatim to ratchet the floors.
        println!(
            "bench_gate: tightened baseline from this run (commit to ratchet):\n{}",
            cur.pin_block(&baseline).to_pretty()
        );
        Ok(())
    } else {
        Err(failures.join("; "))
    }
}

/// Auto-discovery: scan the baseline's directory for `BENCH_*.json`
/// files (excluding the baseline itself), classify each by its top-level
/// `"bench"` field, and return artifact paths in [`KINDS`] order. Two
/// files claiming the same kind is an error (stale artifacts must not
/// race); files without a recognized `"bench"` field — including files
/// that fail to parse at all, like a truncated figure/table output — are
/// skipped with a warning (they are not gate artifacts, and a broken
/// *gate* artifact still fails loudly via the pinned-section check). A
/// missing coordinator artifact is an error — the core gate can never be
/// skipped.
fn discover(baseline_path: &str) -> Result<[Option<String>; 7], String> {
    let base = Path::new(baseline_path);
    let dir = match base.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let base_name = base.file_name().map(|n| n.to_string_lossy().into_owned());

    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .map_err(|e| format!("scanning {}: {e}", dir.display()))?
        .filter_map(|entry| {
            let name = entry.ok()?.file_name().to_string_lossy().into_owned();
            let is_artifact = name.starts_with("BENCH_")
                && name.ends_with(".json")
                && Some(&name) != base_name.as_ref();
            is_artifact.then_some(name)
        })
        .collect();
    names.sort(); // deterministic scan order

    let mut slots: [Option<String>; 7] = Default::default();
    for name in names {
        let path = dir.join(&name).to_string_lossy().into_owned();
        // An unreadable/unparsable sibling (e.g. a truncated figure or
        // table output) cannot claim a bench kind, so it is skipped with
        // a warning like any other non-gate artifact. If the broken file
        // *was* a gate artifact, its baseline section fails loudly below
        // via the pinned-section-without-artifact check — nothing is
        // silently skipped.
        let doc = match load(&path) {
            Ok(doc) => doc,
            Err(e) => {
                println!("bench_gate: skipping {path} (unparsable — not a gate artifact): {e}");
                continue;
            }
        };
        match doc.get("bench").and_then(Json::as_str) {
            Some(kind) => match KINDS.iter().position(|k| *k == kind) {
                Some(i) => {
                    if let Some(prev) = &slots[i] {
                        return Err(format!(
                            "both {prev} and {path} claim bench kind `{kind}` — \
                             remove the stale artifact"
                        ));
                    }
                    slots[i] = Some(path);
                }
                None => println!(
                    "bench_gate: skipping {path} (unrecognized bench kind `{kind}`)"
                ),
            },
            None => println!(
                "bench_gate: skipping {path} (no top-level \"bench\" field — not a \
                 gate artifact)"
            ),
        }
    }

    if slots[0].is_none() {
        return Err(format!(
            "no BENCH_*.json next to {baseline_path} identifies itself as the \
             coordinator artifact (\"bench\": \"coordinator\") — run \
             bench_coordinator first"
        ));
    }
    Ok(slots)
}

fn run_discovered(baseline_path: &str) -> Result<(), String> {
    let slots = discover(baseline_path)?;
    let opt = |i: usize| slots[i].as_deref();
    println!(
        "bench_gate: discovered artifacts: {}",
        KINDS
            .iter()
            .enumerate()
            .map(|(i, k)| format!("{k}={}", opt(i).unwrap_or("-")))
            .collect::<Vec<_>>()
            .join(" ")
    );
    run(
        baseline_path,
        slots[0].as_deref().expect("discover guarantees a coordinator artifact"),
        opt(1),
        opt(2),
        opt(3),
        opt(4),
        opt(5),
        opt(6),
    )
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.as_slice() {
        [baseline] => run_discovered(baseline),
        [baseline, current, rest @ ..] if rest.len() <= 6 => {
            let opt = |i: usize| rest.get(i).map(String::as_str);
            run(baseline, current, opt(0), opt(1), opt(2), opt(3), opt(4), opt(5))
        }
        _ => {
            eprintln!(
                "usage: bench_gate <BENCH_baseline.json>   (auto-discover BENCH_*.json \
                 siblings)\n   or: bench_gate <BENCH_baseline.json> \
                 <BENCH_coordinator.json> [<BENCH_scale.json> [<BENCH_compress.json> \
                 [<BENCH_persist.json> [<BENCH_fleet.json> [<BENCH_load.json> \
                 [<BENCH_obs.json>]]]]]]"
            );
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("bench_gate: FAIL: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_in(dir_name: &str, name: &str, text: &str) -> String {
        let dir = std::env::temp_dir().join("cause_bench_gate_test").join(dir_name);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p.to_string_lossy().into_owned()
    }

    fn write_tmp(name: &str, text: &str) -> String {
        write_in("flat", name, text)
    }

    fn doc(coalesced: f64, p99: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj()
                    .set("retrains_coalesced", coalesced)
                    .set("p99_queue_delay", p99),
            )
            .to_pretty()
    }

    /// Baseline with `gate` plus one named floor section.
    fn doc_with(section: &str, body: Json) -> String {
        Json::parse(&doc(40.0, 4.0)).unwrap().set(section, body).to_pretty()
    }

    fn scale_section() -> Json {
        Json::obj().set("probe_speedup", 10.0)
    }

    fn compress_section() -> Json {
        Json::obj().set("ratio", 2.0).set("decode_mbps", 25.0)
    }

    fn persist_section() -> Json {
        Json::obj()
            .set("append_mbps", 20.0)
            .set("append_mbps_fsync", 0.05)
            .set("group_commit_amortization", 2.0)
            .set("recovery_events_per_s", 5000.0)
            .set("replica_compaction_ratio", 1.05)
    }

    fn fleet_section() -> Json {
        Json::obj().set("scaling_2w", 1.5).set("merge_overhead", 0.5)
    }

    fn load_section() -> Json {
        Json::obj()
            .set("gdpr_storm_rps_at_slo", 0.5)
            .set("heavy_tail_rps_at_slo", 0.5)
            .set("p999_over_p50", 64.0)
    }

    fn obs_section() -> Json {
        Json::obj().set("overhead_pct", 5.0)
    }

    /// Baseline pinning every section.
    fn doc_everything() -> String {
        Json::parse(&doc(40.0, 4.0))
            .unwrap()
            .set("scale", scale_section())
            .set("compress", compress_section())
            .set("persist", persist_section())
            .set("fleet", fleet_section())
            .set("load", load_section())
            .set("obs", obs_section())
            .to_pretty()
    }

    fn scale_doc(speedup: f64) -> String {
        Json::obj()
            .set("bench", "scale")
            .set("gate", Json::obj().set("probe_speedup", speedup))
            .to_pretty()
    }

    fn compress_doc(ratio: f64, mbps: f64) -> String {
        Json::obj()
            .set("bench", "compress")
            .set("gate", Json::obj().set("ratio", ratio).set("decode_mbps", mbps))
            .to_pretty()
    }

    fn persist_doc5(
        append: f64,
        fsync: f64,
        amort: f64,
        recovery: f64,
        replica: f64,
    ) -> String {
        Json::obj()
            .set("bench", "persist")
            .set(
                "gate",
                Json::obj()
                    .set("append_mbps", append)
                    .set("append_mbps_fsync", fsync)
                    .set("group_commit_amortization", amort)
                    .set("recovery_events_per_s", recovery)
                    .set("replica_compaction_ratio", replica),
            )
            .to_pretty()
    }

    fn persist_doc4(append: f64, fsync: f64, amort: f64, recovery: f64) -> String {
        persist_doc5(append, fsync, amort, recovery, 3.0)
    }

    fn persist_doc(append: f64, recovery: f64) -> String {
        persist_doc4(append, 5.0, 8.0, recovery)
    }

    fn fleet_doc(scaling: f64, merge: f64) -> String {
        Json::obj()
            .set("bench", "fleet")
            .set(
                "gate",
                Json::obj().set("scaling_2w", scaling).set("merge_overhead", merge),
            )
            .to_pretty()
    }

    fn load_doc(gdpr: f64, heavy: f64, tail_ratio: f64) -> String {
        Json::obj()
            .set("bench", "load")
            .set(
                "gate",
                Json::obj()
                    .set("gdpr_storm_rps_at_slo", gdpr)
                    .set("heavy_tail_rps_at_slo", heavy)
                    .set("p999_over_p50", tail_ratio),
            )
            .to_pretty()
    }

    /// A load artifact stamped with the bench mode it was measured in.
    fn load_doc_mode(mode: &str, gdpr: f64, heavy: f64, tail_ratio: f64) -> String {
        Json::parse(&load_doc(gdpr, heavy, tail_ratio))
            .unwrap()
            .set("mode", mode)
            .to_pretty()
    }

    fn obs_doc(pct: f64) -> String {
        Json::obj()
            .set("bench", "obs")
            .set("gate", Json::obj().set("overhead_pct", pct))
            .to_pretty()
    }

    fn coordinator_doc(coalesced: f64, p99: f64) -> String {
        Json::parse(&doc(coalesced, p99))
            .unwrap()
            .set("bench", "coordinator")
            .to_pretty()
    }

    #[test]
    fn passes_on_equal_and_improved() {
        let base = write_tmp("base.json", &doc(40.0, 4.0));
        let same = write_tmp("same.json", &doc(40.0, 4.0));
        let better = write_tmp("better.json", &doc(55.0, 3.0));
        assert!(run(&base, &same, None, None, None, None, None, None).is_ok());
        assert!(run(&base, &better, None, None, None, None, None, None).is_ok());
        // Within the 20% latency tolerance.
        let near = write_tmp("near.json", &doc(40.0, 4.8));
        assert!(run(&base, &near, None, None, None, None, None, None).is_ok());
    }

    #[test]
    fn fails_on_regressions() {
        let base = write_tmp("base2.json", &doc(40.0, 4.0));
        let fewer = write_tmp("fewer.json", &doc(39.0, 4.0));
        let slower = write_tmp("slower.json", &doc(40.0, 4.81));
        assert!(run(&base, &fewer, None, None, None, None, None, None).is_err());
        assert!(run(&base, &slower, None, None, None, None, None, None).is_err());
        assert!(run("/nonexistent.json", &base, None, None, None, None, None, None).is_err());
        let junk = write_tmp("junk.json", "not json");
        assert!(run(&junk, &base, None, None, None, None, None, None).is_err());
    }

    #[test]
    fn scale_gate_checks_probe_speedup() {
        let base = write_tmp("base3.json", &doc_with("scale", scale_section()));
        let cur = write_tmp("cur3.json", &doc(40.0, 4.0));
        // Within tolerance (20% of 10.0 → floor 8.0) and above.
        let ok = write_tmp("scale_ok.json", &scale_doc(8.5));
        let better = write_tmp("scale_better.json", &scale_doc(30.0));
        assert!(run(&base, &cur, Some(&ok), None, None, None, None, None).is_ok());
        assert!(run(&base, &cur, Some(&better), None, None, None, None, None).is_ok());
        // Below the floor: fail.
        let bad = write_tmp("scale_bad.json", &scale_doc(7.9));
        assert!(run(&base, &cur, Some(&bad), None, None, None, None, None).is_err());
        // Malformed scale summary: fail even though coordinator gates pass.
        let junk = write_tmp("scale_junk.json", "{}");
        assert!(run(&base, &cur, Some(&junk), None, None, None, None, None).is_err());
        // Baseline without a pinned scale value: informational pass.
        let base_unpinned = write_tmp("base4.json", &doc(40.0, 4.0));
        assert!(run(&base_unpinned, &cur, Some(&ok), None, None, None, None, None).is_ok());
    }

    #[test]
    fn compress_gate_checks_floors() {
        let base = write_tmp("base5.json", &doc_with("compress", compress_section()));
        let cur = write_tmp("cur5.json", &doc(40.0, 4.0));
        // At or above both floors: pass.
        let ok = write_tmp("comp_ok.json", &compress_doc(2.9, 400.0));
        let exact = write_tmp("comp_exact.json", &compress_doc(2.0, 25.0));
        assert!(run(&base, &cur, None, Some(&ok), None, None, None, None).is_ok());
        assert!(run(&base, &cur, None, Some(&exact), None, None, None, None).is_ok());
        // Ratio below the floor: fail (no extra tolerance on floors).
        let thin = write_tmp("comp_thin.json", &compress_doc(1.9, 400.0));
        assert!(run(&base, &cur, None, Some(&thin), None, None, None, None).is_err());
        // Decode throughput below the floor: fail.
        let slow = write_tmp("comp_slow.json", &compress_doc(2.9, 20.0));
        assert!(run(&base, &cur, None, Some(&slow), None, None, None, None).is_err());
        // Malformed compress summary: fail.
        let junk = write_tmp("comp_junk.json", "{}");
        assert!(run(&base, &cur, None, Some(&junk), None, None, None, None).is_err());
        // Baseline without compress floors: informational pass.
        let base_nofloor = write_tmp("base6.json", &doc(40.0, 4.0));
        assert!(run(&base_nofloor, &cur, None, Some(&ok), None, None, None, None).is_ok());
    }

    #[test]
    fn persist_gate_checks_floors() {
        let base = write_tmp("base7.json", &doc_with("persist", persist_section()));
        let cur = write_tmp("cur7.json", &doc(40.0, 4.0));
        // At/above both floors: pass.
        let ok = write_tmp("pers_ok.json", &persist_doc(120.0, 90_000.0));
        let exact = write_tmp("pers_exact.json", &persist_doc(20.0, 5000.0));
        assert!(run(&base, &cur, None, None, Some(&ok), None, None, None).is_ok());
        assert!(run(&base, &cur, None, None, Some(&exact), None, None, None).is_ok());
        // Append below floor: fail.
        let slow_append = write_tmp("pers_slow_a.json", &persist_doc(19.0, 90_000.0));
        assert!(run(&base, &cur, None, None, Some(&slow_append), None, None, None).is_err());
        // Recovery below floor: fail.
        let slow_rec = write_tmp("pers_slow_r.json", &persist_doc(120.0, 4000.0));
        assert!(run(&base, &cur, None, None, Some(&slow_rec), None, None, None).is_err());
        // Fsync-mode append below its floor: fail.
        let slow_fsync =
            write_tmp("pers_slow_f.json", &persist_doc4(120.0, 0.01, 8.0, 90_000.0));
        assert!(run(&base, &cur, None, None, Some(&slow_fsync), None, None, None).is_err());
        // Group commit stopped amortizing: fail.
        let no_amort =
            write_tmp("pers_no_amort.json", &persist_doc4(120.0, 5.0, 1.0, 90_000.0));
        assert!(run(&base, &cur, None, None, Some(&no_amort), None, None, None).is_err());
        // Replica accreting unbounded history (ratio <= 1): fail.
        let no_compact = write_tmp(
            "pers_no_compact.json",
            &persist_doc5(120.0, 5.0, 8.0, 90_000.0, 0.9),
        );
        assert!(run(&base, &cur, None, None, Some(&no_compact), None, None, None).is_err());
        // A legacy baseline without the fsync floors still gates the two
        // classic floors and passes (the merged document pins the rest).
        let base_legacy = write_tmp(
            "base7_legacy.json",
            &doc_with(
                "persist",
                Json::obj().set("append_mbps", 20.0).set("recovery_events_per_s", 5000.0),
            ),
        );
        assert!(run(&base_legacy, &cur, None, None, Some(&slow_fsync), None, None, None).is_ok());
        // Malformed persist summary: fail.
        let junk = write_tmp("pers_junk.json", "{}");
        assert!(run(&base, &cur, None, None, Some(&junk), None, None, None).is_err());
        // Baseline without persist floors: informational pass.
        let base_nofloor = write_tmp("base8.json", &doc(40.0, 4.0));
        assert!(run(&base_nofloor, &cur, None, None, Some(&ok), None, None, None).is_ok());
    }

    #[test]
    fn fleet_gate_checks_scaling_and_merge() {
        let base = write_tmp("base9.json", &doc_with("fleet", fleet_section()));
        let cur = write_tmp("cur9.json", &doc(40.0, 4.0));
        // At/above the scaling floor and under the merge ceiling: pass.
        let ok = write_tmp("fleet_ok.json", &fleet_doc(1.8, 0.02));
        let exact = write_tmp("fleet_exact.json", &fleet_doc(1.5, 0.5));
        assert!(run(&base, &cur, None, None, None, Some(&ok), None, None).is_ok());
        assert!(run(&base, &cur, None, None, None, Some(&exact), None, None).is_ok());
        // Scaling below the floor: fail (no extra tolerance on floors).
        let flat = write_tmp("fleet_flat.json", &fleet_doc(1.4, 0.02));
        assert!(run(&base, &cur, None, None, None, Some(&flat), None, None).is_err());
        // Merge overhead above the ceiling: fail.
        let heavy = write_tmp("fleet_heavy.json", &fleet_doc(1.8, 0.6));
        assert!(run(&base, &cur, None, None, None, Some(&heavy), None, None).is_err());
        // Malformed fleet summary: fail even though the rest passes.
        let junk = write_tmp("fleet_junk.json", "{}");
        assert!(run(&base, &cur, None, None, None, Some(&junk), None, None).is_err());
        // Baseline without fleet floors: informational pass.
        let base_nofloor = write_tmp("base10.json", &doc(40.0, 4.0));
        assert!(run(&base_nofloor, &cur, None, None, None, Some(&ok), None, None).is_ok());
    }

    #[test]
    fn load_gate_checks_floors_and_ceiling() {
        let base = write_tmp("base11.json", &doc_with("load", load_section()));
        let cur = write_tmp("cur11.json", &doc(40.0, 4.0));
        // At/above every floor and under the ceiling: pass.
        let ok = write_tmp("load_ok.json", &load_doc(2.0, 0.5, 9.0));
        let exact = write_tmp("load_exact.json", &load_doc(0.5, 0.5, 64.0));
        assert!(run(&base, &cur, None, None, None, None, Some(&ok), None).is_ok());
        assert!(run(&base, &cur, None, None, None, None, Some(&exact), None).is_ok());
        // One scenario's throughput-at-SLO below its floor: fail.
        let slow = write_tmp("load_slow.json", &load_doc(0.0, 2.0, 9.0));
        assert!(run(&base, &cur, None, None, None, None, Some(&slow), None).is_err());
        // Tail ratio above the histogram-sanity ceiling: fail.
        let tail = write_tmp("load_tail.json", &load_doc(2.0, 2.0, 65.0));
        assert!(run(&base, &cur, None, None, None, None, Some(&tail), None).is_err());
        // A pinned scenario missing from the artifact's gate: fail loudly.
        let missing = write_tmp(
            "load_missing.json",
            &Json::obj()
                .set("bench", "load")
                .set(
                    "gate",
                    Json::obj()
                        .set("gdpr_storm_rps_at_slo", 2.0)
                        .set("p999_over_p50", 9.0),
                )
                .to_pretty(),
        );
        assert!(run(&base, &cur, None, None, None, None, Some(&missing), None).is_err());
        // An unknown key pinned in the baseline's load section: fail.
        let base_bogus = write_tmp(
            "base12.json",
            &doc_with("load", load_section().set("bogus_knob", 1.0)),
        );
        let full = write_tmp(
            "load_full.json",
            &Json::obj()
                .set("bench", "load")
                .set(
                    "gate",
                    Json::obj()
                        .set("gdpr_storm_rps_at_slo", 2.0)
                        .set("heavy_tail_rps_at_slo", 2.0)
                        .set("p999_over_p50", 9.0)
                        .set("bogus_knob", 1.0),
                )
                .to_pretty(),
        );
        assert!(run(&base_bogus, &cur, None, None, None, None, Some(&full), None).is_err());
        // Malformed load summary: fail.
        let junk = write_tmp("load_junk.json", "{}");
        assert!(run(&base, &cur, None, None, None, None, Some(&junk), None).is_err());
        // Baseline without load floors: informational pass.
        let base_nofloor = write_tmp("base13.json", &doc(40.0, 4.0));
        assert!(run(&base_nofloor, &cur, None, None, None, None, Some(&ok), None).is_ok());
    }

    #[test]
    fn load_gate_refuses_cross_mode_artifacts() {
        let base = write_tmp(
            "base_mode.json",
            &doc_with("load", load_section().set("mode", "fast")),
        );
        let cur = write_tmp("cur_mode.json", &doc(40.0, 4.0));
        // Same mode: gates normally — floors still fail on regressions.
        let fast_ok =
            write_tmp("load_fast_ok.json", &load_doc_mode("fast", 2.0, 0.5, 9.0));
        assert!(run(&base, &cur, None, None, None, None, Some(&fast_ok), None).is_ok());
        let fast_bad =
            write_tmp("load_fast_bad.json", &load_doc_mode("fast", 0.0, 2.0, 9.0));
        assert!(run(&base, &cur, None, None, None, None, Some(&fast_bad), None).is_err());
        // Other mode: fails loudly even though every number beats its
        // floor — fast and full sweep different rate grids.
        let full =
            write_tmp("load_full_mode.json", &load_doc_mode("full", 8.0, 8.0, 2.0));
        let err = run(&base, &cur, None, None, None, None, Some(&full), None).unwrap_err();
        assert!(err.contains("`fast` mode"), "{err}");
        // Artifact without a mode against a pinned mode: stale artifact,
        // fail.
        let unmoded = write_tmp("load_unmoded.json", &load_doc(8.0, 8.0, 2.0));
        let err = run(&base, &cur, None, None, None, None, Some(&unmoded), None).unwrap_err();
        assert!(err.contains("records no mode"), "{err}");
        // Baseline without a pinned mode gates any artifact (back-compat
        // with pre-mode baselines).
        let base_unmoded =
            write_tmp("base_unmoded.json", &doc_with("load", load_section()));
        assert!(run(&base_unmoded, &cur, None, None, None, None, Some(&full), None).is_ok());
    }

    #[test]
    fn discovery_skips_unparsable_siblings() {
        // A truncated non-gate sibling (e.g. a half-written figure
        // output) is skipped with a warning, not a hard error.
        let base = write_in("disc6", "BENCH_baseline.json", &doc(40.0, 4.0));
        write_in("disc6", "BENCH_coordinator.json", &coordinator_doc(41.0, 3.9));
        write_in("disc6", "BENCH_fig_truncated.json", "{\"rows\": [");
        assert!(run_discovered(&base).is_ok());

        // But a broken *gate* artifact still fails loudly: the baseline
        // pins load floors and no parsable artifact claims the load kind.
        let base =
            write_in("disc7", "BENCH_baseline.json", &doc_with("load", load_section()));
        write_in("disc7", "BENCH_coordinator.json", &coordinator_doc(41.0, 3.9));
        write_in("disc7", "BENCH_load.json", "{\"bench\": \"load\", ");
        let err = run_discovered(&base).unwrap_err();
        assert!(err.contains("`load`"), "{err}");
    }

    #[test]
    fn pinned_sections_without_artifacts_fail_loudly() {
        // The brittleness fix: a baseline that pins floors must receive
        // the matching artifact or the gate fails — no silent skips.
        let base = write_tmp("base14.json", &doc_everything());
        let cur = write_tmp("cur14.json", &doc(40.0, 4.0));
        let err = run(&base, &cur, None, None, None, None, None, None).unwrap_err();
        for section in ["scale", "compress", "persist", "fleet", "load", "obs"] {
            assert!(err.contains(&format!("`{section}`")), "{section} not in: {err}");
        }
        // Supplying all artifacts clears it.
        let scale = write_tmp("all_scale.json", &scale_doc(12.0));
        let comp = write_tmp("all_comp.json", &compress_doc(2.9, 400.0));
        let pers = write_tmp("all_pers.json", &persist_doc(120.0, 90_000.0));
        let fleet = write_tmp("all_fleet.json", &fleet_doc(1.8, 0.02));
        let load_a = write_tmp("all_load.json", &load_doc(2.0, 0.5, 9.0));
        let obs_a = write_tmp("all_obs.json", &obs_doc(0.7));
        assert!(run(
            &base,
            &cur,
            Some(&scale),
            Some(&comp),
            Some(&pers),
            Some(&fleet),
            Some(&load_a),
            Some(&obs_a)
        )
        .is_ok());
        // Dropping exactly one (e.g. the fleet artifact) fails again.
        let err = run(
            &base,
            &cur,
            Some(&scale),
            Some(&comp),
            Some(&pers),
            None,
            Some(&load_a),
            Some(&obs_a),
        )
        .unwrap_err();
        assert!(err.contains("`fleet`"), "{err}");
        assert!(!err.contains("`scale`"), "{err}");
    }

    #[test]
    fn discovery_classifies_by_bench_field() {
        let base = write_in("disc1", "BENCH_baseline.json", &doc_everything());
        write_in("disc1", "BENCH_coordinator.json", &coordinator_doc(41.0, 3.9));
        write_in("disc1", "BENCH_scale.json", &scale_doc(12.0));
        write_in("disc1", "BENCH_compress.json", &compress_doc(2.9, 400.0));
        write_in("disc1", "BENCH_persist.json", &persist_doc(120.0, 90_000.0));
        write_in("disc1", "BENCH_fleet.json", &fleet_doc(1.8, 0.02));
        write_in("disc1", "BENCH_load.json", &load_doc(2.0, 0.5, 9.0));
        write_in("disc1", "BENCH_obs.json", &obs_doc(0.7));
        // A figure output without a "bench" field is skipped, not fatal.
        write_in("disc1", "BENCH_fig99.json", "{\"rows\": []}");
        assert!(run_discovered(&base).is_ok());

        // File names don't matter — classification is by the field.
        let base = write_in("disc2", "BENCH_baseline.json", &doc(40.0, 4.0));
        write_in("disc2", "BENCH_weird_name.json", &coordinator_doc(41.0, 3.9));
        assert!(run_discovered(&base).is_ok());
    }

    #[test]
    fn discovery_fails_without_coordinator_or_on_duplicates() {
        // No artifact claims "coordinator": hard error.
        let base = write_in("disc3", "BENCH_baseline.json", &doc(40.0, 4.0));
        write_in("disc3", "BENCH_scale.json", &scale_doc(12.0));
        let err = run_discovered(&base).unwrap_err();
        assert!(err.contains("coordinator"), "{err}");

        // Two files claiming the same kind: hard error naming both.
        let base = write_in("disc4", "BENCH_baseline.json", &doc(40.0, 4.0));
        write_in("disc4", "BENCH_coordinator.json", &coordinator_doc(41.0, 3.9));
        write_in("disc4", "BENCH_scale.json", &scale_doc(12.0));
        write_in("disc4", "BENCH_scale_stale.json", &scale_doc(11.0));
        let err = run_discovered(&base).unwrap_err();
        assert!(err.contains("claim bench kind `scale`"), "{err}");
    }

    #[test]
    fn discovery_gates_regressions_like_positional_mode() {
        // A regressing artifact discovered from disk must fail the same
        // way it would when passed positionally.
        let base = write_in(
            "disc5",
            "BENCH_baseline.json",
            &doc_with("load", load_section()),
        );
        write_in("disc5", "BENCH_coordinator.json", &coordinator_doc(41.0, 3.9));
        write_in("disc5", "BENCH_load.json", &load_doc(0.0, 2.0, 9.0));
        let err = run_discovered(&base).unwrap_err();
        assert!(err.contains("gdpr_storm"), "{err}");
    }

    #[test]
    fn bootstrap_baseline_always_passes() {
        let boot = write_tmp("boot.json", &Json::obj().set("bootstrap", true).to_pretty());
        let cur = write_tmp("cur.json", &doc(12.0, 2.0));
        assert!(run(&boot, &cur, None, None, None, None, None, None).is_ok());
        // Bootstrap still requires well-formed current summaries.
        let junk = write_tmp("junk2.json", "{}");
        assert!(run(&boot, &junk, None, None, None, None, None, None).is_err());
        let scale = write_tmp("boot_scale.json", &scale_doc(12.5));
        assert!(run(&boot, &cur, Some(&scale), None, None, None, None, None).is_ok());
        assert!(run(&boot, &cur, Some(&junk), None, None, None, None, None).is_err());
        let load_a = write_tmp("boot_load.json", &load_doc(2.0, 0.5, 9.0));
        assert!(run(&boot, &cur, None, None, None, None, Some(&load_a), None).is_ok());
        assert!(run(&boot, &cur, None, None, None, None, Some(&junk), None).is_err());
    }

    #[test]
    fn pin_block_only_tightens_and_never_pins_wall_clock() {
        let at = |j: &Json, p: &[&str]| j.at(p).and_then(Json::as_f64);
        let baseline = Json::parse(&doc_everything()).expect("baseline doc");
        // A run that passed within tolerance (worse p99, lower speedup)
        // must not loosen anything; genuine improvements do tighten.
        let mut load_measured = BTreeMap::new();
        load_measured.insert("gdpr_storm_rps_at_slo".to_string(), 2.0); // better → up
        load_measured.insert("heavy_tail_rps_at_slo".to_string(), 0.5); // equal → stays
        load_measured.insert("p999_over_p50".to_string(), 9.0); // better → down
        load_measured.insert("diurnal_burst_rps_at_slo".to_string(), 1.0); // new key
        let cur = Current {
            coalesced: 55.0,          // better than 40 → ratchets up
            p99: 4.8,                 // worse than 4.0 (within 20%) → stays 4.0
            speedup: Some(8.5),       // worse than 10.0 (within 20%) → stays 10.0
            compress: Some((2.8, 310.0)), // ratio better; mbps is wall-clock
            // Wall-clock / mode-dependent → committed floors stay.
            persist: Some((500.0, 80.0, 30.0, 1_000_000.0, 12.0)),
            fleet: Some((1.9, 0.01)), // core-count dependent → floors stay
            load: Some(LoadArtifact {
                mode: Some("fast".to_string()),
                gate: load_measured,
            }),
            obs: Some(0.8), // far under the 5% ceiling → ceiling stays
        };
        let pin = cur.pin_block(&baseline);
        assert_eq!(at(&pin, &["gate", "retrains_coalesced"]), Some(55.0));
        assert_eq!(at(&pin, &["gate", "p99_queue_delay"]), Some(4.0));
        assert_eq!(at(&pin, &["scale", "probe_speedup"]), Some(10.0));
        assert_eq!(at(&pin, &["compress", "ratio"]), Some(2.8));
        // Wall-clock floors are never raised from a measured rate.
        assert_eq!(at(&pin, &["compress", "decode_mbps"]), Some(25.0));
        assert_eq!(at(&pin, &["persist", "append_mbps"]), Some(20.0));
        assert_eq!(at(&pin, &["persist", "append_mbps_fsync"]), Some(0.05));
        assert_eq!(at(&pin, &["persist", "group_commit_amortization"]), Some(2.0));
        assert_eq!(at(&pin, &["persist", "recovery_events_per_s"]), Some(5000.0));
        assert_eq!(at(&pin, &["persist", "replica_compaction_ratio"]), Some(1.05));
        // Fleet scaling floor / merge ceiling keep their committed values
        // even when this (possibly many-core, lightly loaded) run beat
        // them.
        assert_eq!(at(&pin, &["fleet", "scaling_2w"]), Some(1.5));
        assert_eq!(at(&pin, &["fleet", "merge_overhead"]), Some(0.5));
        // Load floors are deterministic: improvements ratchet up, the
        // tail ceiling ratchets down, new scenarios pin as measured.
        assert_eq!(at(&pin, &["load", "gdpr_storm_rps_at_slo"]), Some(2.0));
        assert_eq!(at(&pin, &["load", "heavy_tail_rps_at_slo"]), Some(0.5));
        assert_eq!(at(&pin, &["load", "p999_over_p50"]), Some(9.0));
        assert_eq!(at(&pin, &["load", "diurnal_burst_rps_at_slo"]), Some(1.0));
        // The measured mode is stamped so future runs refuse cross-mode
        // comparison.
        assert_eq!(pin.at(&["load", "mode"]).and_then(Json::as_str), Some("fast"));
        // The tracing-overhead ceiling is wall-clock-shaped: a quiet
        // runner beating it must not tighten it.
        assert_eq!(at(&pin, &["obs", "overhead_pct"]), Some(5.0));
        // A worse load run cannot loosen the committed floors/ceiling.
        let mut worse = BTreeMap::new();
        worse.insert("gdpr_storm_rps_at_slo".to_string(), 0.0);
        worse.insert("p999_over_p50".to_string(), 100.0);
        let worse = LoadArtifact { mode: None, gate: worse };
        let pin = Current { load: Some(worse), ..cur.clone() }.pin_block(&baseline);
        assert_eq!(at(&pin, &["load", "gdpr_storm_rps_at_slo"]), Some(0.5));
        assert_eq!(at(&pin, &["load", "p999_over_p50"]), Some(64.0));
        // Committed keys the run didn't measure are kept (can't un-pin).
        assert_eq!(at(&pin, &["load", "heavy_tail_rps_at_slo"]), Some(0.5));
        // Improvements in the latency/speedup direction do ratchet.
        let better = Current {
            coalesced: 40.0,
            p99: 3.0,
            speedup: Some(30.0),
            compress: Some((1.5, 310.0)), // worse ratio → keeps the 2.0 floor
            persist: None,
            fleet: None,
            load: None,
            obs: None,
        };
        let pin = better.pin_block(&baseline);
        assert_eq!(at(&pin, &["gate", "p99_queue_delay"]), Some(3.0));
        assert_eq!(at(&pin, &["scale", "probe_speedup"]), Some(30.0));
        assert_eq!(at(&pin, &["compress", "ratio"]), Some(2.0));
        // Sections not measured stay absent so they can't un-pin floors.
        assert_eq!(pin.get("persist"), None);
        assert_eq!(pin.get("fleet"), None);
        assert_eq!(pin.get("load"), None);
        // No committed floors (bootstrap-style baseline): counters pin
        // as measured, wall-clock floors get 10x headroom, the fleet
        // scaling floor 1.25x headroom, the merge ceiling 10x headroom.
        let boot = Json::obj().set("bootstrap", true);
        let mut load_measured = BTreeMap::new();
        load_measured.insert("gdpr_storm_rps_at_slo".to_string(), 2.0);
        load_measured.insert("p999_over_p50".to_string(), 9.0);
        let load_measured =
            LoadArtifact { mode: Some("full".to_string()), gate: load_measured };
        let cur = Current { load: Some(load_measured), ..cur };
        let pin = cur.pin_block(&boot);
        assert_eq!(at(&pin, &["gate", "retrains_coalesced"]), Some(55.0));
        assert_eq!(at(&pin, &["gate", "p99_queue_delay"]), Some(4.8));
        assert_eq!(at(&pin, &["scale", "probe_speedup"]), Some(8.5));
        assert_eq!(at(&pin, &["compress", "decode_mbps"]), Some(31.0));
        assert_eq!(at(&pin, &["persist", "append_mbps"]), Some(50.0));
        assert_eq!(at(&pin, &["persist", "append_mbps_fsync"]), Some(8.0));
        assert_eq!(at(&pin, &["persist", "group_commit_amortization"]), Some(15.0));
        assert_eq!(at(&pin, &["persist", "recovery_events_per_s"]), Some(100_000.0));
        assert_eq!(at(&pin, &["persist", "replica_compaction_ratio"]), Some(6.0));
        assert_eq!(at(&pin, &["fleet", "scaling_2w"]), Some(1.9 / 1.25));
        assert_eq!(at(&pin, &["fleet", "merge_overhead"]), Some(0.01 * 10.0));
        // Load keys (and the measured mode) pin as measured when nothing
        // is committed.
        assert_eq!(at(&pin, &["load", "gdpr_storm_rps_at_slo"]), Some(2.0));
        assert_eq!(at(&pin, &["load", "p999_over_p50"]), Some(9.0));
        assert_eq!(pin.at(&["load", "mode"]).and_then(Json::as_str), Some("full"));
        // With nothing committed the obs ceiling pins at the 5% budget
        // (the measured 0.8% is noise-shaped, not a ceiling).
        assert_eq!(at(&pin, &["obs", "overhead_pct"]), Some(5.0));
        let sparse = Current {
            coalesced: 1.0,
            p99: 1.0,
            speedup: None,
            compress: None,
            persist: None,
            fleet: None,
            load: None,
            obs: None,
        };
        assert_eq!(sparse.pin_block(&boot).get("scale"), None);
        assert_eq!(sparse.pin_block(&boot).get("compress"), None);
        assert_eq!(sparse.pin_block(&boot).get("persist"), None);
        assert_eq!(sparse.pin_block(&boot).get("fleet"), None);
        assert_eq!(sparse.pin_block(&boot).get("load"), None);
        assert_eq!(sparse.pin_block(&boot).get("obs"), None);
    }

    #[test]
    fn obs_gate_checks_overhead_ceiling() {
        let base = write_tmp("base_obs.json", &doc_with("obs", obs_section()));
        let cur = write_tmp("cur_obs.json", &doc(40.0, 4.0));
        // Under or exactly at the ceiling: pass.
        let ok = write_tmp("obs_ok.json", &obs_doc(0.7));
        let exact = write_tmp("obs_exact.json", &obs_doc(5.0));
        assert!(run(&base, &cur, None, None, None, None, None, Some(&ok)).is_ok());
        assert!(run(&base, &cur, None, None, None, None, None, Some(&exact)).is_ok());
        // Above the ceiling: fail (tracing stopped being cheap).
        let heavy = write_tmp("obs_heavy.json", &obs_doc(5.1));
        assert!(run(&base, &cur, None, None, None, None, None, Some(&heavy)).is_err());
        // Malformed obs summary: fail.
        let junk = write_tmp("obs_junk.json", "{}");
        assert!(run(&base, &cur, None, None, None, None, None, Some(&junk)).is_err());
        // Baseline without an obs ceiling: informational pass.
        let base_nofloor = write_tmp("base_obs_nofloor.json", &doc(40.0, 4.0));
        assert!(
            run(&base_nofloor, &cur, None, None, None, None, None, Some(&ok)).is_ok()
        );
        // Baseline pinning the ceiling with no artifact: hard failure.
        let err = run(&base, &cur, None, None, None, None, None, None).unwrap_err();
        assert!(err.contains("`obs`"), "{err}");
    }
}
