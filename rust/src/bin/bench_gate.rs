//! `bench_gate` — CI bench-regression gate.
//!
//! Compares the machine-readable summaries the benches wrote against the
//! committed `BENCH_baseline.json` and fails (exit 1) when the scheduler,
//! the planner, the checkpoint codec, or the durability layer regresses:
//!
//! * `gate.retrains_coalesced` (from `BENCH_coordinator.json`) drops below
//!   the baseline (the coalescing win shrank), or
//! * `gate.p99_queue_delay` grows more than 20% over the baseline (the
//!   latency SLO frontier moved the wrong way), or
//! * `gate.probe_speedup` (from `BENCH_scale.json`, when given) drops more
//!   than 20% below `scale.probe_speedup` in the baseline (the indexed
//!   planner lost throughput against the compiled-in naive-scan oracle), or
//! * `gate.ratio` / `gate.decode_mbps` (from `BENCH_compress.json`, when
//!   given) fall below the `compress.ratio` / `compress.decode_mbps`
//!   floors in the baseline, or
//! * `gate.append_mbps` / `gate.recovery_events_per_s` (from
//!   `BENCH_persist.json`, when given) fall below the `persist.*` floors —
//!   the write-ahead log appends or crash recovery replays slower than the
//!   committed floor. Floors are conservative invariant-derived values and
//!   are checked directly, without an extra tolerance. Or
//! * `gate.scaling_2w` (from `BENCH_fleet.json`, when given) falls below
//!   the `fleet.scaling_2w` floor (the 2-worker sharded fleet stopped
//!   beating the single-worker service on the same machine), or
//!   `gate.merge_overhead` grows above the `fleet.merge_overhead` ceiling
//!   (merging per-shard receipts/metrics became comparable to re-running
//!   the workload).
//!
//! The coordinator values are deterministic workload counters, the scale
//! value is a same-machine ratio (indexed vs naive on identical state),
//! and the compression ratio is a deterministic function of the bench's
//! seeded tensors — so those gates are stable across runner hardware; only
//! the decode-throughput, append-throughput, and recovery-rate floors are
//! wall-clock, and they are pinned far below any plausible machine. The
//! fleet scaling value is a same-machine ratio too, but it additionally
//! depends on the runner having ≥2 usable cores, so (like the wall-clock
//! floors) it is never auto-raised by the ratchet; the merge-overhead
//! ceiling is likewise never auto-lowered.
//!
//! A baseline with `"bootstrap": true` passes unconditionally. On every
//! pass — bootstrap or green — the gate prints **one** ready-to-commit
//! baseline document covering all four bench files
//! (coordinator/scale/compress/persist): a tighten-only merge of the
//! committed values with the run's artifacts (a run that merely passed
//! within tolerance cannot loosen a floor, and wall-clock floors are never
//! auto-raised), so green main runs ratchet the floors by committing it
//! verbatim — no per-file fragments to stitch together.
//!
//! ```bash
//! cargo run --release --bin bench_gate -- \
//!     BENCH_baseline.json BENCH_coordinator.json \
//!     [BENCH_scale.json [BENCH_compress.json [BENCH_persist.json \
//!     [BENCH_fleet.json]]]]
//! ```

use std::process::ExitCode;

use cause::util::Json;

/// Allowed relative growth of p99 queueing delay before the gate fails.
const P99_TOLERANCE: f64 = 0.20;

/// Allowed relative drop of the planner probe speedup before the gate
/// fails.
const SPEEDUP_TOLERANCE: f64 = 0.20;

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn gate_value(doc: &Json, path: &str, key: &str) -> Result<f64, String> {
    doc.at(&["gate", key])
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing numeric field gate.{key}"))
}

/// Current gate values measured by this run's artifacts.
struct Current {
    coalesced: f64,
    p99: f64,
    speedup: Option<f64>,
    compress: Option<(f64, f64)>, // (ratio, decode_mbps)
    persist: Option<(f64, f64)>,  // (append_mbps, recovery_events_per_s)
    fleet: Option<(f64, f64)>,    // (scaling_2w, merge_overhead)
}

impl Current {
    /// The single baseline document these artifacts support — printed on
    /// every pass (bootstrap included), covering every measured section.
    /// A true ratchet: counters/ratios take the better of committed vs
    /// measured, p99 the smaller, and wall-clock floors (decode MB/s,
    /// append MB/s, recovery events/s) are never raised automatically — a
    /// fast runner must not pin a floor slower machines would fail; when
    /// no floor is committed they get 10x headroom under the measured
    /// rate.
    fn pin_block(&self, baseline: &Json) -> Json {
        let base = |path: &[&str]| baseline.at(path).and_then(Json::as_f64);
        let coalesced = self
            .coalesced
            .max(base(&["gate", "retrains_coalesced"]).unwrap_or(self.coalesced));
        let p99 = self.p99.min(base(&["gate", "p99_queue_delay"]).unwrap_or(self.p99));
        let mut pin = Json::obj().set(
            "gate",
            Json::obj()
                .set("retrains_coalesced", coalesced)
                .set("p99_queue_delay", p99),
        );
        if let Some(s) = self.speedup {
            let s = s.max(base(&["scale", "probe_speedup"]).unwrap_or(s));
            pin = pin.set("scale", Json::obj().set("probe_speedup", s));
        }
        if let Some((ratio, mbps)) = self.compress {
            let ratio = ratio.max(base(&["compress", "ratio"]).unwrap_or(ratio));
            let mbps = base(&["compress", "decode_mbps"]).unwrap_or(mbps / 10.0);
            pin = pin.set(
                "compress",
                Json::obj().set("ratio", ratio).set("decode_mbps", mbps),
            );
        }
        if let Some((append, recovery)) = self.persist {
            let append = base(&["persist", "append_mbps"]).unwrap_or(append / 10.0);
            let recovery =
                base(&["persist", "recovery_events_per_s"]).unwrap_or(recovery / 10.0);
            pin = pin.set(
                "persist",
                Json::obj()
                    .set("append_mbps", append)
                    .set("recovery_events_per_s", recovery),
            );
        }
        if let Some((scaling, merge)) = self.fleet {
            // Parallel scaling depends on the runner's free cores, so a
            // many-core machine must not ratchet the floor to a ratio a
            // 2-core runner cannot hit; a 1.25x headroom applies when no
            // floor is committed. The merge ceiling is wall-clock-shaped
            // (smaller is better) and is likewise never auto-tightened.
            let scaling = base(&["fleet", "scaling_2w"]).unwrap_or(scaling / 1.25);
            let merge = base(&["fleet", "merge_overhead"]).unwrap_or(merge * 10.0);
            pin = pin.set(
                "fleet",
                Json::obj().set("scaling_2w", scaling).set("merge_overhead", merge),
            );
        }
        pin
    }
}

fn run(
    baseline_path: &str,
    current_path: &str,
    scale_path: Option<&str>,
    compress_path: Option<&str>,
    persist_path: Option<&str>,
    fleet_path: Option<&str>,
) -> Result<(), String> {
    let baseline = load(baseline_path)?;
    let current = load(current_path)?;

    let cur = Current {
        coalesced: gate_value(&current, current_path, "retrains_coalesced")?,
        p99: gate_value(&current, current_path, "p99_queue_delay")?,
        speedup: match scale_path {
            Some(p) => Some(gate_value(&load(p)?, p, "probe_speedup")?),
            None => None,
        },
        compress: match compress_path {
            Some(p) => {
                let doc = load(p)?;
                Some((gate_value(&doc, p, "ratio")?, gate_value(&doc, p, "decode_mbps")?))
            }
            None => None,
        },
        persist: match persist_path {
            Some(p) => {
                let doc = load(p)?;
                Some((
                    gate_value(&doc, p, "append_mbps")?,
                    gate_value(&doc, p, "recovery_events_per_s")?,
                ))
            }
            None => None,
        },
        fleet: match fleet_path {
            Some(p) => {
                let doc = load(p)?;
                Some((
                    gate_value(&doc, p, "scaling_2w")?,
                    gate_value(&doc, p, "merge_overhead")?,
                ))
            }
            None => None,
        },
    };

    if baseline.get("bootstrap").and_then(Json::as_bool) == Some(true) {
        println!(
            "bench_gate: baseline {baseline_path} is in bootstrap mode — \
             pin it by committing:\n{}",
            cur.pin_block(&baseline).to_pretty()
        );
        return Ok(());
    }

    let base_coalesced = gate_value(&baseline, baseline_path, "retrains_coalesced")?;
    let base_p99 = gate_value(&baseline, baseline_path, "p99_queue_delay")?;

    println!(
        "bench_gate: retrains_coalesced {base_coalesced} -> {}, \
         p99_queue_delay {base_p99} -> {}",
        cur.coalesced, cur.p99
    );

    let mut failures = Vec::new();
    if cur.coalesced < base_coalesced {
        failures.push(format!(
            "retrains_coalesced dropped: {} < baseline {base_coalesced}",
            cur.coalesced
        ));
    }
    let p99_limit = base_p99 * (1.0 + P99_TOLERANCE);
    if cur.p99 > p99_limit + 1e-9 {
        failures.push(format!(
            "p99 queueing delay grew >{:.0}%: {} > {p99_limit:.3} \
             (baseline {base_p99})",
            P99_TOLERANCE * 100.0,
            cur.p99
        ));
    }

    if let Some(cur_speedup) = cur.speedup {
        match baseline.at(&["scale", "probe_speedup"]).and_then(Json::as_f64) {
            Some(base_speedup) => {
                println!(
                    "bench_gate: probe_speedup {base_speedup:.2} -> {cur_speedup:.2}"
                );
                let floor = base_speedup * (1.0 - SPEEDUP_TOLERANCE);
                if cur_speedup < floor - 1e-9 {
                    failures.push(format!(
                        "planner probe speedup dropped >{:.0}%: {cur_speedup:.2} < \
                         {floor:.2} (baseline {base_speedup:.2})",
                        SPEEDUP_TOLERANCE * 100.0
                    ));
                }
            }
            None => println!(
                "bench_gate: {baseline_path} has no scale.probe_speedup — the \
                 merged baseline below pins it"
            ),
        }
    }

    if let Some((cur_ratio, cur_mbps)) = cur.compress {
        let base_ratio = baseline.at(&["compress", "ratio"]).and_then(Json::as_f64);
        let base_mbps = baseline.at(&["compress", "decode_mbps"]).and_then(Json::as_f64);
        match (base_ratio, base_mbps) {
            (Some(ratio_floor), Some(mbps_floor)) => {
                println!(
                    "bench_gate: compress ratio floor {ratio_floor:.2} -> {cur_ratio:.2}, \
                     decode floor {mbps_floor:.0} MB/s -> {cur_mbps:.0} MB/s"
                );
                if cur_ratio < ratio_floor - 1e-9 {
                    failures.push(format!(
                        "compression ratio fell below floor: {cur_ratio:.2} < {ratio_floor:.2}"
                    ));
                }
                if cur_mbps < mbps_floor - 1e-9 {
                    failures.push(format!(
                        "decode throughput fell below floor: {cur_mbps:.0} < \
                         {mbps_floor:.0} MB/s"
                    ));
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no compress floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if let Some((cur_append, cur_recovery)) = cur.persist {
        let base_append = baseline.at(&["persist", "append_mbps"]).and_then(Json::as_f64);
        let base_recovery = baseline
            .at(&["persist", "recovery_events_per_s"])
            .and_then(Json::as_f64);
        match (base_append, base_recovery) {
            (Some(append_floor), Some(recovery_floor)) => {
                println!(
                    "bench_gate: persist append floor {append_floor:.1} -> \
                     {cur_append:.1} MB/s, recovery floor {recovery_floor:.0} -> \
                     {cur_recovery:.0} events/s"
                );
                if cur_append < append_floor - 1e-9 {
                    failures.push(format!(
                        "log append throughput fell below floor: {cur_append:.1} < \
                         {append_floor:.1} MB/s"
                    ));
                }
                if cur_recovery < recovery_floor - 1e-9 {
                    failures.push(format!(
                        "recovery replay rate fell below floor: {cur_recovery:.0} < \
                         {recovery_floor:.0} events/s"
                    ));
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no persist floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if let Some((cur_scaling, cur_merge)) = cur.fleet {
        let base_scaling = baseline.at(&["fleet", "scaling_2w"]).and_then(Json::as_f64);
        let base_merge = baseline.at(&["fleet", "merge_overhead"]).and_then(Json::as_f64);
        match (base_scaling, base_merge) {
            (Some(scaling_floor), Some(merge_ceiling)) => {
                println!(
                    "bench_gate: fleet scaling floor {scaling_floor:.2}x -> \
                     {cur_scaling:.2}x, merge ceiling {merge_ceiling:.2} -> \
                     {cur_merge:.3}"
                );
                if cur_scaling < scaling_floor - 1e-9 {
                    failures.push(format!(
                        "2-worker fleet scaling fell below floor: {cur_scaling:.2}x < \
                         {scaling_floor:.2}x"
                    ));
                }
                if cur_merge > merge_ceiling + 1e-9 {
                    failures.push(format!(
                        "fleet receipt-merge overhead grew above ceiling: \
                         {cur_merge:.3} > {merge_ceiling:.3}"
                    ));
                }
            }
            _ => println!(
                "bench_gate: {baseline_path} has no fleet floors — the merged \
                 baseline below pins them"
            ),
        }
    }

    if failures.is_empty() {
        println!("bench_gate: OK");
        // One ready-to-commit document covering every measured section
        // (tighten-only merge against the committed values) — commit it
        // verbatim to ratchet the floors.
        println!(
            "bench_gate: tightened baseline from this run (commit to ratchet):\n{}",
            cur.pin_block(&baseline).to_pretty()
        );
        Ok(())
    } else {
        Err(failures.join("; "))
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (baseline, current, rest) = match args.as_slice() {
        [b, c, rest @ ..] if rest.len() <= 4 => (b.as_str(), c.as_str(), rest),
        _ => {
            eprintln!(
                "usage: bench_gate <BENCH_baseline.json> <BENCH_coordinator.json> \
                 [<BENCH_scale.json> [<BENCH_compress.json> [<BENCH_persist.json> \
                 [<BENCH_fleet.json>]]]]"
            );
            return ExitCode::FAILURE;
        }
    };
    let opt = |i: usize| rest.get(i).map(String::as_str);
    match run(baseline, current, opt(0), opt(1), opt(2), opt(3)) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("bench_gate: FAIL: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, text: &str) -> String {
        let dir = std::env::temp_dir().join("cause_bench_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p.to_string_lossy().into_owned()
    }

    fn doc(coalesced: f64, p99: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj()
                    .set("retrains_coalesced", coalesced)
                    .set("p99_queue_delay", p99),
            )
            .to_pretty()
    }

    fn doc_with_scale(coalesced: f64, p99: f64, speedup: f64) -> String {
        Json::parse(&doc(coalesced, p99))
            .unwrap()
            .set("scale", Json::obj().set("probe_speedup", speedup))
            .to_pretty()
    }

    fn doc_full(coalesced: f64, p99: f64, speedup: f64, ratio: f64, mbps: f64) -> String {
        Json::parse(&doc_with_scale(coalesced, p99, speedup))
            .unwrap()
            .set(
                "compress",
                Json::obj().set("ratio", ratio).set("decode_mbps", mbps),
            )
            .to_pretty()
    }

    fn doc_all(
        coalesced: f64,
        p99: f64,
        speedup: f64,
        ratio: f64,
        mbps: f64,
        append: f64,
        recovery: f64,
    ) -> String {
        Json::parse(&doc_full(coalesced, p99, speedup, ratio, mbps))
            .unwrap()
            .set(
                "persist",
                Json::obj()
                    .set("append_mbps", append)
                    .set("recovery_events_per_s", recovery),
            )
            .to_pretty()
    }

    fn scale_doc(speedup: f64) -> String {
        Json::obj()
            .set("gate", Json::obj().set("probe_speedup", speedup))
            .to_pretty()
    }

    fn compress_doc(ratio: f64, mbps: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj().set("ratio", ratio).set("decode_mbps", mbps),
            )
            .to_pretty()
    }

    fn persist_doc(append: f64, recovery: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj()
                    .set("append_mbps", append)
                    .set("recovery_events_per_s", recovery),
            )
            .to_pretty()
    }

    fn doc_everything(scaling: f64, merge: f64) -> String {
        Json::parse(&doc_all(40.0, 4.0, 10.0, 2.0, 25.0, 20.0, 5000.0))
            .unwrap()
            .set(
                "fleet",
                Json::obj().set("scaling_2w", scaling).set("merge_overhead", merge),
            )
            .to_pretty()
    }

    fn fleet_doc(scaling: f64, merge: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj().set("scaling_2w", scaling).set("merge_overhead", merge),
            )
            .to_pretty()
    }

    #[test]
    fn passes_on_equal_and_improved() {
        let base = write_tmp("base.json", &doc(40.0, 4.0));
        let same = write_tmp("same.json", &doc(40.0, 4.0));
        let better = write_tmp("better.json", &doc(55.0, 3.0));
        assert!(run(&base, &same, None, None, None, None).is_ok());
        assert!(run(&base, &better, None, None, None, None).is_ok());
        // Within the 20% latency tolerance.
        let near = write_tmp("near.json", &doc(40.0, 4.8));
        assert!(run(&base, &near, None, None, None, None).is_ok());
    }

    #[test]
    fn fails_on_regressions() {
        let base = write_tmp("base2.json", &doc(40.0, 4.0));
        let fewer = write_tmp("fewer.json", &doc(39.0, 4.0));
        let slower = write_tmp("slower.json", &doc(40.0, 4.81));
        assert!(run(&base, &fewer, None, None, None, None).is_err());
        assert!(run(&base, &slower, None, None, None, None).is_err());
        assert!(run("/nonexistent.json", &base, None, None, None, None).is_err());
        let junk = write_tmp("junk.json", "not json");
        assert!(run(&junk, &base, None, None, None, None).is_err());
    }

    #[test]
    fn scale_gate_checks_probe_speedup() {
        let base = write_tmp("base3.json", &doc_with_scale(40.0, 4.0, 10.0));
        let cur = write_tmp("cur3.json", &doc(40.0, 4.0));
        // Within tolerance (20% of 10.0 → floor 8.0) and above.
        let ok = write_tmp("scale_ok.json", &scale_doc(8.5));
        let better = write_tmp("scale_better.json", &scale_doc(30.0));
        assert!(run(&base, &cur, Some(&ok), None, None, None).is_ok());
        assert!(run(&base, &cur, Some(&better), None, None, None).is_ok());
        // Below the floor: fail.
        let bad = write_tmp("scale_bad.json", &scale_doc(7.9));
        assert!(run(&base, &cur, Some(&bad), None, None, None).is_err());
        // Malformed scale summary: fail even though coordinator gates pass.
        let junk = write_tmp("scale_junk.json", "{}");
        assert!(run(&base, &cur, Some(&junk), None, None, None).is_err());
        // Baseline without a pinned scale value: informational pass.
        let base_unpinned = write_tmp("base4.json", &doc(40.0, 4.0));
        assert!(run(&base_unpinned, &cur, Some(&ok), None, None, None).is_ok());
    }

    #[test]
    fn compress_gate_checks_floors() {
        let base = write_tmp("base5.json", &doc_full(40.0, 4.0, 10.0, 2.0, 25.0));
        let cur = write_tmp("cur5.json", &doc(40.0, 4.0));
        let scale = write_tmp("scale5.json", &scale_doc(12.0));
        // At or above both floors: pass.
        let ok = write_tmp("comp_ok.json", &compress_doc(2.9, 400.0));
        let exact = write_tmp("comp_exact.json", &compress_doc(2.0, 25.0));
        assert!(run(&base, &cur, Some(&scale), Some(&ok), None, None).is_ok());
        assert!(run(&base, &cur, Some(&scale), Some(&exact), None, None).is_ok());
        // Ratio below the floor: fail (no extra tolerance on floors).
        let thin = write_tmp("comp_thin.json", &compress_doc(1.9, 400.0));
        assert!(run(&base, &cur, Some(&scale), Some(&thin), None, None).is_err());
        // Decode throughput below the floor: fail.
        let slow = write_tmp("comp_slow.json", &compress_doc(2.9, 20.0));
        assert!(run(&base, &cur, Some(&scale), Some(&slow), None, None).is_err());
        // Malformed compress summary: fail.
        let junk = write_tmp("comp_junk.json", "{}");
        assert!(run(&base, &cur, Some(&scale), Some(&junk), None, None).is_err());
        // Baseline without compress floors: informational pass.
        let base_nofloor = write_tmp("base6.json", &doc_with_scale(40.0, 4.0, 10.0));
        assert!(run(&base_nofloor, &cur, Some(&scale), Some(&ok), None, None).is_ok());
        // Compress artifact without the scale artifact also works.
        assert!(run(&base, &cur, None, Some(&ok), None, None).is_ok());
    }

    #[test]
    fn persist_gate_checks_floors() {
        let base =
            write_tmp("base7.json", &doc_all(40.0, 4.0, 10.0, 2.0, 25.0, 20.0, 5000.0));
        let cur = write_tmp("cur7.json", &doc(40.0, 4.0));
        let scale = write_tmp("scale7.json", &scale_doc(12.0));
        let comp = write_tmp("comp7.json", &compress_doc(2.9, 400.0));
        // At/above both floors: pass.
        let ok = write_tmp("pers_ok.json", &persist_doc(120.0, 90_000.0));
        let exact = write_tmp("pers_exact.json", &persist_doc(20.0, 5000.0));
        assert!(run(&base, &cur, Some(&scale), Some(&comp), Some(&ok), None).is_ok());
        assert!(run(&base, &cur, Some(&scale), Some(&comp), Some(&exact), None).is_ok());
        // Append below floor: fail.
        let slow_append = write_tmp("pers_slow_a.json", &persist_doc(19.0, 90_000.0));
        assert!(run(&base, &cur, Some(&scale), Some(&comp), Some(&slow_append), None).is_err());
        // Recovery below floor: fail.
        let slow_rec = write_tmp("pers_slow_r.json", &persist_doc(120.0, 4000.0));
        assert!(run(&base, &cur, Some(&scale), Some(&comp), Some(&slow_rec), None).is_err());
        // Malformed persist summary: fail.
        let junk = write_tmp("pers_junk.json", "{}");
        assert!(run(&base, &cur, Some(&scale), Some(&comp), Some(&junk), None).is_err());
        // Baseline without persist floors: informational pass.
        let base_nofloor = write_tmp("base8.json", &doc_full(40.0, 4.0, 10.0, 2.0, 25.0));
        assert!(run(&base_nofloor, &cur, Some(&scale), Some(&comp), Some(&ok), None).is_ok());
        // Persist artifact alone (no scale/compress) also works.
        assert!(run(&base, &cur, None, None, Some(&ok), None).is_ok());
    }

    #[test]
    fn fleet_gate_checks_scaling_and_merge() {
        let base = write_tmp("base9.json", &doc_everything(1.5, 0.5));
        let cur = write_tmp("cur9.json", &doc(40.0, 4.0));
        // At/above the scaling floor and under the merge ceiling: pass.
        let ok = write_tmp("fleet_ok.json", &fleet_doc(1.8, 0.02));
        let exact = write_tmp("fleet_exact.json", &fleet_doc(1.5, 0.5));
        assert!(run(&base, &cur, None, None, None, Some(&ok)).is_ok());
        assert!(run(&base, &cur, None, None, None, Some(&exact)).is_ok());
        // Scaling below the floor: fail (no extra tolerance on floors).
        let flat = write_tmp("fleet_flat.json", &fleet_doc(1.4, 0.02));
        assert!(run(&base, &cur, None, None, None, Some(&flat)).is_err());
        // Merge overhead above the ceiling: fail.
        let heavy = write_tmp("fleet_heavy.json", &fleet_doc(1.8, 0.6));
        assert!(run(&base, &cur, None, None, None, Some(&heavy)).is_err());
        // Malformed fleet summary: fail even though the rest passes.
        let junk = write_tmp("fleet_junk.json", "{}");
        assert!(run(&base, &cur, None, None, None, Some(&junk)).is_err());
        // Baseline without fleet floors: informational pass.
        let base_nofloor =
            write_tmp("base10.json", &doc_all(40.0, 4.0, 10.0, 2.0, 25.0, 20.0, 5000.0));
        assert!(run(&base_nofloor, &cur, None, None, None, Some(&ok)).is_ok());
        // The fleet artifact composes with the other positional artifacts.
        let scale = write_tmp("scale9.json", &scale_doc(12.0));
        let comp = write_tmp("comp9.json", &compress_doc(2.9, 400.0));
        let pers = write_tmp("pers9.json", &persist_doc(120.0, 90_000.0));
        assert!(run(&base, &cur, Some(&scale), Some(&comp), Some(&pers), Some(&ok)).is_ok());
        assert!(
            run(&base, &cur, Some(&scale), Some(&comp), Some(&pers), Some(&flat)).is_err()
        );
    }

    #[test]
    fn bootstrap_baseline_always_passes() {
        let boot = write_tmp(
            "boot.json",
            &Json::obj().set("bootstrap", true).to_pretty(),
        );
        let cur = write_tmp("cur.json", &doc(12.0, 2.0));
        assert!(run(&boot, &cur, None, None, None, None).is_ok());
        // Bootstrap still requires well-formed current summaries.
        let junk = write_tmp("junk2.json", "{}");
        assert!(run(&boot, &junk, None, None, None, None).is_err());
        let scale = write_tmp("boot_scale.json", &scale_doc(12.5));
        assert!(run(&boot, &cur, Some(&scale), None, None, None).is_ok());
        assert!(run(&boot, &cur, Some(&junk), None, None, None).is_err());
        let comp = write_tmp("boot_comp.json", &compress_doc(3.0, 500.0));
        assert!(run(&boot, &cur, Some(&scale), Some(&comp), None, None).is_ok());
        assert!(run(&boot, &cur, Some(&scale), Some(&junk), None, None).is_err());
        let pers = write_tmp("boot_pers.json", &persist_doc(100.0, 50_000.0));
        assert!(run(&boot, &cur, Some(&scale), Some(&comp), Some(&pers), None).is_ok());
        assert!(run(&boot, &cur, Some(&scale), Some(&comp), Some(&junk), None).is_err());
        let fleet = write_tmp("boot_fleet.json", &fleet_doc(1.9, 0.01));
        assert!(
            run(&boot, &cur, Some(&scale), Some(&comp), Some(&pers), Some(&fleet)).is_ok()
        );
        assert!(
            run(&boot, &cur, Some(&scale), Some(&comp), Some(&pers), Some(&junk)).is_err()
        );
    }

    #[test]
    fn pin_block_only_tightens_and_never_pins_wall_clock() {
        let at = |j: &Json, p: &[&str]| j.at(p).and_then(Json::as_f64);
        let baseline =
            Json::parse(&doc_everything(1.5, 0.5)).expect("baseline doc");
        // A run that passed within tolerance (worse p99, lower speedup)
        // must not loosen anything; genuine improvements do tighten.
        let cur = Current {
            coalesced: 55.0,          // better than 40 → ratchets up
            p99: 4.8,                 // worse than 4.0 (within 20%) → stays 4.0
            speedup: Some(8.5),       // worse than 10.0 (within 20%) → stays 10.0
            compress: Some((2.8, 310.0)), // ratio better; mbps is wall-clock
            persist: Some((500.0, 1_000_000.0)), // both wall-clock → floors stay
            fleet: Some((1.9, 0.01)), // core-count dependent → floors stay
        };
        let pin = cur.pin_block(&baseline);
        assert_eq!(at(&pin, &["gate", "retrains_coalesced"]), Some(55.0));
        assert_eq!(at(&pin, &["gate", "p99_queue_delay"]), Some(4.0));
        assert_eq!(at(&pin, &["scale", "probe_speedup"]), Some(10.0));
        assert_eq!(at(&pin, &["compress", "ratio"]), Some(2.8));
        // Wall-clock floors are never raised from a measured rate.
        assert_eq!(at(&pin, &["compress", "decode_mbps"]), Some(25.0));
        assert_eq!(at(&pin, &["persist", "append_mbps"]), Some(20.0));
        assert_eq!(at(&pin, &["persist", "recovery_events_per_s"]), Some(5000.0));
        // Fleet scaling floor / merge ceiling keep their committed values
        // even when this (possibly many-core, lightly loaded) run beat
        // them.
        assert_eq!(at(&pin, &["fleet", "scaling_2w"]), Some(1.5));
        assert_eq!(at(&pin, &["fleet", "merge_overhead"]), Some(0.5));
        // Improvements in the latency/speedup direction do ratchet.
        let better = Current {
            coalesced: 40.0,
            p99: 3.0,
            speedup: Some(30.0),
            compress: Some((1.5, 310.0)), // worse ratio → keeps the 2.0 floor
            persist: None,
            fleet: None,
        };
        let pin = better.pin_block(&baseline);
        assert_eq!(at(&pin, &["gate", "p99_queue_delay"]), Some(3.0));
        assert_eq!(at(&pin, &["scale", "probe_speedup"]), Some(30.0));
        assert_eq!(at(&pin, &["compress", "ratio"]), Some(2.0));
        // Sections not measured stay absent so they can't un-pin floors.
        assert_eq!(pin.get("persist"), None);
        assert_eq!(pin.get("fleet"), None);
        // No committed floors (bootstrap-style baseline): counters pin
        // as measured, wall-clock floors get 10x headroom, the fleet
        // scaling floor 1.25x headroom, the merge ceiling 10x headroom.
        let boot = Json::obj().set("bootstrap", true);
        let pin = cur.pin_block(&boot);
        assert_eq!(at(&pin, &["gate", "retrains_coalesced"]), Some(55.0));
        assert_eq!(at(&pin, &["gate", "p99_queue_delay"]), Some(4.8));
        assert_eq!(at(&pin, &["scale", "probe_speedup"]), Some(8.5));
        assert_eq!(at(&pin, &["compress", "decode_mbps"]), Some(31.0));
        assert_eq!(at(&pin, &["persist", "append_mbps"]), Some(50.0));
        assert_eq!(at(&pin, &["persist", "recovery_events_per_s"]), Some(100_000.0));
        assert_eq!(at(&pin, &["fleet", "scaling_2w"]), Some(1.9 / 1.25));
        assert_eq!(at(&pin, &["fleet", "merge_overhead"]), Some(0.01 * 10.0));
        let sparse = Current {
            coalesced: 1.0,
            p99: 1.0,
            speedup: None,
            compress: None,
            persist: None,
            fleet: None,
        };
        assert_eq!(sparse.pin_block(&boot).get("scale"), None);
        assert_eq!(sparse.pin_block(&boot).get("compress"), None);
        assert_eq!(sparse.pin_block(&boot).get("persist"), None);
        assert_eq!(sparse.pin_block(&boot).get("fleet"), None);
    }
}
