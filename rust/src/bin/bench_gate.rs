//! `bench_gate` — CI bench-regression gate.
//!
//! Compares the machine-readable summaries the benches wrote against the
//! committed `BENCH_baseline.json` and fails (exit 1) when the scheduler
//! or the planner regresses:
//!
//! * `gate.retrains_coalesced` (from `BENCH_coordinator.json`) drops below
//!   the baseline (the coalescing win shrank), or
//! * `gate.p99_queue_delay` grows more than 20% over the baseline (the
//!   latency SLO frontier moved the wrong way), or
//! * `gate.probe_speedup` (from `BENCH_scale.json`, when given) drops more
//!   than 20% below `scale.probe_speedup` in the baseline (the indexed
//!   planner lost throughput against the compiled-in naive-scan oracle).
//!
//! The coordinator values are deterministic workload counters and the
//! scale value is a same-machine ratio (indexed vs naive on identical
//! state) — never absolute wall-clock — so the gate is stable across
//! runner hardware.
//!
//! A baseline with `"bootstrap": true` passes unconditionally and prints
//! the block to commit as the pinned baseline — used to seed the gate on a
//! branch whose workload changed intentionally.
//!
//! ```bash
//! cargo run --release --bin bench_gate -- \
//!     BENCH_baseline.json BENCH_coordinator.json [BENCH_scale.json]
//! ```

use std::process::ExitCode;

use cause::util::Json;

/// Allowed relative growth of p99 queueing delay before the gate fails.
const P99_TOLERANCE: f64 = 0.20;

/// Allowed relative drop of the planner probe speedup before the gate
/// fails.
const SPEEDUP_TOLERANCE: f64 = 0.20;

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn gate_value(doc: &Json, path: &str, key: &str) -> Result<f64, String> {
    doc.at(&["gate", key])
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing numeric field gate.{key}"))
}

fn run(
    baseline_path: &str,
    current_path: &str,
    scale_path: Option<&str>,
) -> Result<(), String> {
    let baseline = load(baseline_path)?;
    let current = load(current_path)?;

    let cur_coalesced = gate_value(&current, current_path, "retrains_coalesced")?;
    let cur_p99 = gate_value(&current, current_path, "p99_queue_delay")?;
    let cur_speedup = match scale_path {
        Some(p) => Some(gate_value(&load(p)?, p, "probe_speedup")?),
        None => None,
    };

    if baseline.get("bootstrap").and_then(Json::as_bool) == Some(true) {
        let mut pin = Json::obj().set(
            "gate",
            Json::obj()
                .set("retrains_coalesced", cur_coalesced)
                .set("p99_queue_delay", cur_p99),
        );
        if let Some(s) = cur_speedup {
            pin = pin.set("scale", Json::obj().set("probe_speedup", s));
        }
        println!(
            "bench_gate: baseline {baseline_path} is in bootstrap mode — \
             pin it by committing:\n{}",
            pin.to_pretty()
        );
        return Ok(());
    }

    let base_coalesced = gate_value(&baseline, baseline_path, "retrains_coalesced")?;
    let base_p99 = gate_value(&baseline, baseline_path, "p99_queue_delay")?;

    println!(
        "bench_gate: retrains_coalesced {base_coalesced} -> {cur_coalesced}, \
         p99_queue_delay {base_p99} -> {cur_p99}"
    );

    let mut failures = Vec::new();
    if cur_coalesced < base_coalesced {
        failures.push(format!(
            "retrains_coalesced dropped: {cur_coalesced} < baseline {base_coalesced}"
        ));
    }
    let p99_limit = base_p99 * (1.0 + P99_TOLERANCE);
    if cur_p99 > p99_limit + 1e-9 {
        failures.push(format!(
            "p99 queueing delay grew >{:.0}%: {cur_p99} > {p99_limit:.3} \
             (baseline {base_p99})",
            P99_TOLERANCE * 100.0
        ));
    }

    if let Some(cur_speedup) = cur_speedup {
        match baseline.at(&["scale", "probe_speedup"]).and_then(Json::as_f64) {
            Some(base_speedup) => {
                println!(
                    "bench_gate: probe_speedup {base_speedup:.2} -> {cur_speedup:.2}"
                );
                let floor = base_speedup * (1.0 - SPEEDUP_TOLERANCE);
                if cur_speedup < floor - 1e-9 {
                    failures.push(format!(
                        "planner probe speedup dropped >{:.0}%: {cur_speedup:.2} < \
                         {floor:.2} (baseline {base_speedup:.2})",
                        SPEEDUP_TOLERANCE * 100.0
                    ));
                }
            }
            None => {
                println!(
                    "bench_gate: {baseline_path} has no scale.probe_speedup — pin it \
                     by committing:\n{}",
                    Json::obj()
                        .set("scale", Json::obj().set("probe_speedup", cur_speedup))
                        .to_pretty()
                );
            }
        }
    }

    if failures.is_empty() {
        println!("bench_gate: OK");
        Ok(())
    } else {
        Err(failures.join("; "))
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (baseline, current, scale) = match args.as_slice() {
        [b, c] => (b.as_str(), c.as_str(), None),
        [b, c, s] => (b.as_str(), c.as_str(), Some(s.as_str())),
        _ => {
            eprintln!(
                "usage: bench_gate <BENCH_baseline.json> <BENCH_coordinator.json> \
                 [<BENCH_scale.json>]"
            );
            return ExitCode::FAILURE;
        }
    };
    match run(baseline, current, scale) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("bench_gate: FAIL: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, text: &str) -> String {
        let dir = std::env::temp_dir().join("cause_bench_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p.to_string_lossy().into_owned()
    }

    fn doc(coalesced: f64, p99: f64) -> String {
        Json::obj()
            .set(
                "gate",
                Json::obj()
                    .set("retrains_coalesced", coalesced)
                    .set("p99_queue_delay", p99),
            )
            .to_pretty()
    }

    fn doc_with_scale(coalesced: f64, p99: f64, speedup: f64) -> String {
        Json::parse(&doc(coalesced, p99))
            .unwrap()
            .set("scale", Json::obj().set("probe_speedup", speedup))
            .to_pretty()
    }

    fn scale_doc(speedup: f64) -> String {
        Json::obj()
            .set("gate", Json::obj().set("probe_speedup", speedup))
            .to_pretty()
    }

    #[test]
    fn passes_on_equal_and_improved() {
        let base = write_tmp("base.json", &doc(40.0, 4.0));
        let same = write_tmp("same.json", &doc(40.0, 4.0));
        let better = write_tmp("better.json", &doc(55.0, 3.0));
        assert!(run(&base, &same, None).is_ok());
        assert!(run(&base, &better, None).is_ok());
        // Within the 20% latency tolerance.
        let near = write_tmp("near.json", &doc(40.0, 4.8));
        assert!(run(&base, &near, None).is_ok());
    }

    #[test]
    fn fails_on_regressions() {
        let base = write_tmp("base2.json", &doc(40.0, 4.0));
        let fewer = write_tmp("fewer.json", &doc(39.0, 4.0));
        let slower = write_tmp("slower.json", &doc(40.0, 4.81));
        assert!(run(&base, &fewer, None).is_err());
        assert!(run(&base, &slower, None).is_err());
        assert!(run("/nonexistent.json", &base, None).is_err());
        let junk = write_tmp("junk.json", "not json");
        assert!(run(&junk, &base, None).is_err());
    }

    #[test]
    fn scale_gate_checks_probe_speedup() {
        let base = write_tmp("base3.json", &doc_with_scale(40.0, 4.0, 10.0));
        let cur = write_tmp("cur3.json", &doc(40.0, 4.0));
        // Within tolerance (20% of 10.0 → floor 8.0) and above.
        let ok = write_tmp("scale_ok.json", &scale_doc(8.5));
        let better = write_tmp("scale_better.json", &scale_doc(30.0));
        assert!(run(&base, &cur, Some(&ok)).is_ok());
        assert!(run(&base, &cur, Some(&better)).is_ok());
        // Below the floor: fail.
        let bad = write_tmp("scale_bad.json", &scale_doc(7.9));
        assert!(run(&base, &cur, Some(&bad)).is_err());
        // Malformed scale summary: fail even though coordinator gates pass.
        let junk = write_tmp("scale_junk.json", "{}");
        assert!(run(&base, &cur, Some(&junk)).is_err());
        // Baseline without a pinned scale value: informational pass.
        let base_unpinned = write_tmp("base4.json", &doc(40.0, 4.0));
        assert!(run(&base_unpinned, &cur, Some(&ok)).is_ok());
    }

    #[test]
    fn bootstrap_baseline_always_passes() {
        let boot = write_tmp(
            "boot.json",
            &Json::obj().set("bootstrap", true).to_pretty(),
        );
        let cur = write_tmp("cur.json", &doc(12.0, 2.0));
        assert!(run(&boot, &cur, None).is_ok());
        // Bootstrap still requires well-formed current summaries.
        let junk = write_tmp("junk2.json", "{}");
        assert!(run(&boot, &junk, None).is_err());
        let scale = write_tmp("boot_scale.json", &scale_doc(12.5));
        assert!(run(&boot, &cur, Some(&scale)).is_ok());
        assert!(run(&boot, &cur, Some(&junk)).is_err());
    }
}
