//! Device memory accounting: the checkpoint (sub-model) store, metered in
//! normalized slots (paper baseline) or true encoded bytes.

pub mod store;

pub use store::{
    CapacityMode, Checkpoint, CheckpointId, ModelStore, StoreEvent, StoreMeter, StoreStats,
};
