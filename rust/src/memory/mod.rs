//! Device memory accounting: the checkpoint (sub-model) store.

pub mod store;

pub use store::{Checkpoint, CheckpointId, ModelStore, StoreEvent, StoreStats};
