//! The sub-model checkpoint store — the paper's memory budget C_m plus the
//! replacement machinery of Algorithm 2, metered in **slots** (the paper's
//! normalized N_mem, the default) or in **true bytes**.
//!
//! Slots hold checkpoints of shard lineages at specific rounds. While free
//! capacity remains, new checkpoints are stored directly (Algorithm 2 lines
//! 5–7); once full, the configured [`ReplacementPolicy`] picks the victim
//! (lines 9–11) or rejects the store (the no-replacement baselines).
//!
//! The store also implements Algorithm 3 line 11: when an unlearning
//! request invalidates checkpoints (they contain the unlearned data), they
//! are deleted in place, freeing capacity.
//!
//! ## Capacity modes
//!
//! * [`ModelStore::new`] — `capacity` = N_mem equal slots (the paper
//!   normalizes memory by *dense* sub-model size). Semantics are byte-
//!   identical to the pre-byte-mode store: every admission, eviction, and
//!   rejection receipt is unchanged, which keeps the SISA/ARCANE/OMP
//!   baselines exactly reproducible.
//! * [`ModelStore::with_byte_budget`] — C_m in bytes. Admission reasons in
//!   each checkpoint's true `size_bytes` (derived from the codec's actual
//!   encoding): the policy evicts **as many victims as needed** to fit the
//!   incoming checkpoint, so a keep=0.3 sparse-encoded model occupies ~1/3
//!   of a dense one and the same C_m holds ~3x the checkpoints. The victim
//!   policy ranks over the *resident* checkpoints (rank r → r-th occupied
//!   slot); on a full uniform-size store that mapping is the identity, so
//!   unit-size byte budgets replay slot mode byte for byte
//!   (property-tested in `tests/compressed_store.rs`).
//!
//! ## Delta-pinned parent accounting
//!
//! Byte accounting is *identity-keyed over payloads*, not a naive sum of
//! `size_bytes`: every distinct [`EncodedParams`] reachable from a resident
//! checkpoint — its own payload plus the parents its delta chain pins via
//! `Arc` — is charged exactly once. While a delta's parent is itself
//! resident this equals the old sum; when the parent's checkpoint is
//! evicted but the payload stays pinned by a resident delta child, the
//! parent's bytes **stay charged** until the last pinning child dies, so a
//! long delta chain can never hold more real memory than
//! `memory_budget_bytes` (this closes the PR 4 retention caveat; the
//! eviction loop keeps evicting until the charged total — pins included —
//! fits). Checkpoints without payloads (the accounting backend) charge
//! their declared `size_bytes`, which also keeps slot-mode numbers
//! unchanged.
//!
//! ## Complexity
//!
//! A secondary index ordered by `(lineage, coverage, slot)` is maintained
//! by every mutation, so the planner's point lookups never scan the slot
//! array:
//!
//! * [`ModelStore::best_checkpoint`] / [`ModelStore::latest`] — O(log n)
//!   range queries (tie-broken exactly like the original scan: highest
//!   coverage, then highest slot)
//! * [`ModelStore::occupied`] — O(1) (free-slot set)
//! * [`ModelStore::stored_bytes`] — O(1) (a counter maintained by
//!   store/evict/invalidate)
//! * [`ModelStore::store`] — O(log n) (lowest free slot via the set), plus
//!   O(occupied) per eviction in byte mode (victim-rank resolution)
//!
//! The `*_scan` twins keep the original linear scans alive as differential
//! oracles for the property tests and the benches' naive baselines.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::replacement::ReplacementPolicy;
use crate::runtime::codec::{payload_chain, EncodedParams};

/// Unique checkpoint id (monotonic per store).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CheckpointId(pub u64);

/// A stored sub-model checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub id: CheckpointId,
    /// Shard lineage this checkpoint belongs to.
    pub lineage: usize,
    /// Training round after which it was taken (1-based).
    pub round: u32,
    /// Number of lineage *segments* (rounds of data) covered — a checkpoint
    /// covers a contiguous prefix of its lineage's training history.
    pub covered_segments: u32,
    /// Stored size in bytes. For tensor-carrying backends this is the true
    /// encoded payload size ([`EncodedParams::size_bytes`]); the accounting
    /// backend supplies its paper-scale formula value.
    pub size_bytes: u64,
    /// Encoded parameters when running with a tensor-carrying trainer;
    /// None in the pure-accounting path. Shared ownership: warm-start
    /// resolution and serving restores clone the refcount and decode
    /// through a per-plan cache, never copying payload bytes.
    pub params: Option<Arc<EncodedParams>>,
}

/// How a store meters its capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapacityMode {
    /// N_mem equal slots (paper baseline).
    Slots(usize),
    /// C_m true bytes.
    Bytes(u64),
}

/// Config-level store metering choice; the budget value itself is the
/// experiment's C_m (`memory_bytes`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StoreMeter {
    /// C_m normalized to N_mem equal slots (the paper's accounting).
    #[default]
    Slots,
    /// C_m metered in true encoded bytes.
    Bytes,
}

impl StoreMeter {
    pub fn by_name(name: &str) -> Option<StoreMeter> {
        match name.to_ascii_lowercase().as_str() {
            "slots" | "slot" => Some(StoreMeter::Slots),
            "bytes" | "byte" => Some(StoreMeter::Bytes),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StoreMeter::Slots => "slots",
            StoreMeter::Bytes => "bytes",
        }
    }
}

/// Outcome of a store attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreEvent {
    /// Stored into free capacity.
    Stored { slot: usize },
    /// Evicted the previous occupant of `slot` (slot mode, and the
    /// byte-mode case where one victim's slot is reused directly).
    Replaced { slot: usize, evicted: CheckpointId },
    /// Byte mode: made room by evicting one or more victims, then stored
    /// into `slot` (which need not be a victim's slot).
    Evicted { slot: usize, victims: Vec<CheckpointId> },
    /// Dropped (no-replacement policy and memory full).
    Rejected,
}

/// Cumulative counters for reporting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub stored: u64,
    pub replaced: u64,
    pub rejected: u64,
    pub invalidated: u64,
}

/// The checkpoint store.
pub struct ModelStore {
    slots: Vec<Option<Checkpoint>>,
    policy: Box<dyn ReplacementPolicy>,
    next_id: u64,
    stats: StoreStats,
    mode: CapacityMode,
    /// Currently empty slots (lowest-first allocation, like the original
    /// free-slot scan).
    free: BTreeSet<usize>,
    /// `(lineage, covered_segments, slot)` for every stored checkpoint.
    /// The last element of a `(lineage, ..=coverage)` range is exactly the
    /// checkpoint the original `max_by_key` scan selected.
    by_cover: BTreeSet<(usize, u32, usize)>,
    /// Bytes held by resident checkpoints *including delta-pinned parent
    /// payloads*, each distinct payload charged once — maintained by every
    /// store/evict/invalidate so [`ModelStore::stored_bytes`] is O(1).
    bytes: u64,
    /// Identity-keyed refcounts behind `bytes`: payload identity (the
    /// `Arc` allocation address) → (owned bytes, resident chains that
    /// reach it). A payload leaves the map — and stops being charged —
    /// only when no resident checkpoint's chain reaches it any more.
    charged: HashMap<usize, (u64, u32)>,
}

impl ModelStore {
    /// Slot mode: `capacity` = N_mem (the paper normalizes memory by
    /// sub-model size).
    pub fn new(capacity: usize, policy: Box<dyn ReplacementPolicy>) -> Self {
        assert!(capacity >= 1, "store needs at least one slot");
        Self {
            slots: vec![None; capacity],
            policy,
            next_id: 0,
            stats: StoreStats::default(),
            mode: CapacityMode::Slots(capacity),
            free: (0..capacity).collect(),
            by_cover: BTreeSet::new(),
            bytes: 0,
            charged: HashMap::new(),
        }
    }

    /// Byte mode: admission, eviction, and `would_accept` reason in true
    /// checkpoint bytes against `budget` = C_m. Slots are allocated on
    /// demand and only bound diagnostics.
    pub fn with_byte_budget(budget: u64, policy: Box<dyn ReplacementPolicy>) -> Self {
        assert!(budget >= 1, "store needs a positive byte budget");
        Self {
            slots: Vec::new(),
            policy,
            next_id: 0,
            stats: StoreStats::default(),
            mode: CapacityMode::Bytes(budget),
            free: BTreeSet::new(),
            by_cover: BTreeSet::new(),
            bytes: 0,
            charged: HashMap::new(),
        }
    }

    /// Charge one checkpoint's memory: its declared size when it carries
    /// no payload, otherwise every payload its chain reaches that is not
    /// already charged (identity-keyed, so shared parents count once).
    fn charge_payload(&mut self, params: Option<&Arc<EncodedParams>>, size_bytes: u64) {
        match params {
            None => self.bytes += size_bytes,
            Some(p) => {
                for a in payload_chain(p) {
                    let entry = self
                        .charged
                        .entry(Arc::as_ptr(&a) as usize)
                        .or_insert((a.size_bytes(), 0));
                    if entry.1 == 0 {
                        self.bytes += entry.0;
                    }
                    entry.1 += 1;
                }
            }
        }
    }

    fn charge(&mut self, ckpt: &Checkpoint) {
        self.charge_payload(ckpt.params.as_ref(), ckpt.size_bytes);
    }

    /// Release one checkpoint's memory charge; a payload stays charged
    /// while any other resident chain (a delta child pinning its parent)
    /// still reaches it.
    fn release(&mut self, ckpt: &Checkpoint) {
        match &ckpt.params {
            None => self.bytes -= ckpt.size_bytes,
            Some(p) => {
                for a in payload_chain(p) {
                    let key = Arc::as_ptr(&a) as usize;
                    let entry =
                        self.charged.get_mut(&key).expect("released payload was charged");
                    entry.1 -= 1;
                    if entry.1 == 0 {
                        self.bytes -= entry.0;
                        self.charged.remove(&key);
                    }
                }
            }
        }
    }

    /// Bytes admitting `ckpt` would add right now (payloads already
    /// charged through a resident chain are free).
    fn marginal_charge(&self, ckpt: &Checkpoint) -> u64 {
        match &ckpt.params {
            None => ckpt.size_bytes,
            Some(p) => payload_chain(p)
                .iter()
                .filter(|a| !self.charged.contains_key(&(Arc::as_ptr(a) as usize)))
                .map(|a| a.size_bytes())
                .sum(),
        }
    }

    /// Bytes `ckpt` would occupy in an otherwise empty store — its whole
    /// chain. If this exceeds the budget, no eviction set can ever fit it.
    fn standalone_charge(ckpt: &Checkpoint) -> u64 {
        match &ckpt.params {
            None => ckpt.size_bytes,
            Some(p) => payload_chain(p).iter().map(|a| a.size_bytes()).sum(),
        }
    }

    /// Slot-array length: the fixed N_mem in slot mode; in byte mode the
    /// high-water mark of simultaneously resident checkpoints
    /// (diagnostics — the byte budget is what binds).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// How this store meters capacity.
    pub fn mode(&self) -> CapacityMode {
        self.mode
    }

    /// The byte budget when metering bytes.
    pub fn byte_budget(&self) -> Option<u64> {
        match self.mode {
            CapacityMode::Slots(_) => None,
            CapacityMode::Bytes(b) => Some(b),
        }
    }

    /// Occupied slot count. O(1) via the free-slot set.
    pub fn occupied(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Differential oracle for [`ModelStore::occupied`]: the original
    /// linear count. Test/bench use only.
    pub fn occupied_scan(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Total bytes currently held: every distinct payload reachable from a
    /// resident checkpoint (delta-pinned parents included) charged once,
    /// plus declared sizes of payloadless checkpoints. O(1) maintained
    /// counter.
    pub fn stored_bytes(&self) -> u64 {
        self.bytes
    }

    /// Differential oracle for [`ModelStore::stored_bytes`]: a full scan
    /// that re-derives the identity-deduplicated charge from the slots.
    /// Test/bench use only.
    pub fn stored_bytes_scan(&self) -> u64 {
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        let mut total = 0;
        for c in self.iter() {
            match &c.params {
                None => total += c.size_bytes,
                Some(p) => {
                    for a in payload_chain(p) {
                        if seen.insert(Arc::as_ptr(&a) as usize) {
                            total += a.size_bytes();
                        }
                    }
                }
            }
        }
        total
    }

    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Allocate an id for a checkpoint (ids are store-scoped).
    pub fn next_id(&mut self) -> CheckpointId {
        let id = CheckpointId(self.next_id);
        self.next_id += 1;
        id
    }

    /// Would [`ModelStore::store`] accept a checkpoint right now (free
    /// capacity, or an evicting policy), or reject it (no-replacement
    /// policy and memory full)? Read-only probe — lets the engine skip the
    /// checkpoint snapshot entirely when the store would drop it anyway.
    /// In byte mode the probe is size-free and therefore *conservative*:
    /// it may say yes to a payload that turns out oversized, in which case
    /// `store()` rejects with identical accounting (use
    /// [`ModelStore::would_accept_bytes`] for a size-aware answer).
    pub fn would_accept(&self) -> bool {
        match self.mode {
            CapacityMode::Slots(_) => !self.free.is_empty() || self.policy.would_evict(),
            CapacityMode::Bytes(budget) => self.policy.would_evict() || self.bytes < budget,
        }
    }

    /// Size-aware admission probe: would `store()` accept a checkpoint of
    /// `size` bytes right now? Slot mode ignores `size`.
    pub fn would_accept_bytes(&self, size: u64) -> bool {
        match self.mode {
            CapacityMode::Slots(_) => self.would_accept(),
            CapacityMode::Bytes(budget) => {
                size <= budget && (self.policy.would_evict() || self.bytes + size <= budget)
            }
        }
    }

    /// Account a rejection decided via [`ModelStore::would_accept`]
    /// without materializing the checkpoint — keeps [`StoreStats`]
    /// identical to a real `store` → [`StoreEvent::Rejected`] round-trip.
    pub fn record_rejection(&mut self) {
        self.stats.rejected += 1;
    }

    /// Store a checkpoint per Algorithm 2. Returns what happened.
    pub fn store(&mut self, ckpt: Checkpoint) -> StoreEvent {
        match self.mode {
            CapacityMode::Slots(_) => self.store_slot(ckpt),
            CapacityMode::Bytes(budget) => self.store_bytes(ckpt, budget),
        }
    }

    /// Slot-mode admission — byte-identical to the pre-byte-mode store.
    fn store_slot(&mut self, ckpt: Checkpoint) -> StoreEvent {
        if let Some(free) = self.free.pop_first() {
            self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, free));
            self.charge(&ckpt);
            self.slots[free] = Some(ckpt);
            self.stats.stored += 1;
            return StoreEvent::Stored { slot: free };
        }
        match self.policy.victim(self.slots.len()) {
            Some(slot) => {
                let old = self.slots[slot].take().expect("full store");
                let evicted = old.id;
                self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
                self.release(&old);
                self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, slot));
                self.charge(&ckpt);
                self.slots[slot] = Some(ckpt);
                self.stats.stored += 1;
                self.stats.replaced += 1;
                StoreEvent::Replaced { slot, evicted }
            }
            None => {
                self.stats.rejected += 1;
                StoreEvent::Rejected
            }
        }
    }

    /// Byte-mode admission: evict as many victims as the budget requires.
    /// The loop reasons in *charged* bytes — a victim whose payload stays
    /// pinned by a resident delta child frees nothing, so the loop keeps
    /// evicting (occupancy strictly shrinks, and an empty store always
    /// fits anything that passed the standalone precheck).
    fn store_bytes(&mut self, ckpt: Checkpoint, budget: u64) -> StoreEvent {
        if Self::standalone_charge(&ckpt) > budget {
            // Larger than all of C_m (chain included): no eviction set can
            // ever fit it.
            self.stats.rejected += 1;
            return StoreEvent::Rejected;
        }
        let mut victims: Vec<(usize, CheckpointId)> = Vec::new();
        while self.bytes + self.marginal_charge(&ckpt) > budget {
            let resident = self.occupied();
            debug_assert!(resident > 0, "empty store over budget despite precheck");
            let Some(rank) = self.policy.victim(resident) else {
                // No-replacement policy: it rejects on the first call, so
                // nothing has been evicted yet.
                debug_assert!(victims.is_empty(), "policy flipped mid-eviction");
                self.stats.rejected += 1;
                return StoreEvent::Rejected;
            };
            let slot = self.nth_occupied(rank);
            let old = self.slots[slot].take().expect("occupied rank maps to a full slot");
            self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
            self.release(&old);
            self.free.insert(slot);
            victims.push((slot, old.id));
        }
        let slot = match self.free.pop_first() {
            Some(s) => s,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, slot));
        self.charge(&ckpt);
        self.slots[slot] = Some(ckpt);
        self.stats.stored += 1;
        self.stats.replaced += victims.len() as u64;
        if victims.is_empty() {
            StoreEvent::Stored { slot }
        } else if victims.len() == 1 && victims[0].0 == slot {
            // One victim whose slot is reused directly: the receipt is the
            // slot path's, so unit-size byte budgets replay slot mode
            // byte for byte.
            StoreEvent::Replaced { slot, evicted: victims[0].1 }
        } else {
            StoreEvent::Evicted {
                slot,
                victims: victims.into_iter().map(|(_, id)| id).collect(),
            }
        }
    }

    /// Slot index of the `rank`-th resident checkpoint (ascending slot
    /// order). On a full store this is the identity, matching the slot
    /// path's policy semantics exactly.
    fn nth_occupied(&self, rank: usize) -> usize {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .nth(rank)
            .map(|(i, _)| i)
            .expect("victim rank within occupancy")
    }

    /// Newest stored checkpoint of `lineage` covering at most
    /// `max_segments` segments (i.e. taken before the poisoned data) —
    /// the retrain start point of Algorithm 3 line 8. O(log n).
    pub fn best_checkpoint(&self, lineage: usize, max_segments: u32) -> Option<&Checkpoint> {
        self.by_cover
            .range((lineage, 0, 0)..=(lineage, max_segments, usize::MAX))
            .next_back()
            .map(|&(_, _, slot)| self.slots[slot].as_ref().expect("indexed slot occupied"))
    }

    /// Differential oracle for [`ModelStore::best_checkpoint`]: the
    /// original O(slots) scan with identical tie-breaking (`max_by_key`
    /// keeps the last maximum — the highest slot). Test/bench use only.
    pub fn best_checkpoint_scan(
        &self,
        lineage: usize,
        max_segments: u32,
    ) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage && c.covered_segments <= max_segments)
            .max_by_key(|c| c.covered_segments)
    }

    /// Latest checkpoint of a lineage regardless of coverage (warm start
    /// for incremental training). O(log n).
    pub fn latest(&self, lineage: usize) -> Option<&Checkpoint> {
        self.by_cover
            .range((lineage, 0, 0)..=(lineage, u32::MAX, usize::MAX))
            .next_back()
            .map(|&(_, _, slot)| self.slots[slot].as_ref().expect("indexed slot occupied"))
    }

    /// Differential oracle for [`ModelStore::latest`]. Test/bench use only.
    pub fn latest_scan(&self, lineage: usize) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage)
            .max_by_key(|c| c.covered_segments)
    }

    /// Delete every checkpoint matching `pred` (Algorithm 3 line 11);
    /// returns how many were removed.
    pub fn invalidate(&mut self, pred: impl FnMut(&Checkpoint) -> bool) -> usize {
        self.invalidate_collect(pred).len()
    }

    /// [`ModelStore::invalidate`] returning the removed checkpoint ids —
    /// the audit/durability layer records exactly which versions died.
    pub fn invalidate_collect(
        &mut self,
        mut pred: impl FnMut(&Checkpoint) -> bool,
    ) -> Vec<CheckpointId> {
        let mut removed = Vec::new();
        for slot in 0..self.slots.len() {
            let matches = self.slots[slot].as_ref().map(&mut pred).unwrap_or(false);
            if matches {
                let old = self.slots[slot].take().expect("checked above");
                self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
                self.release(&old);
                self.free.insert(slot);
                removed.push(old.id);
            }
        }
        self.stats.invalidated += removed.len() as u64;
        removed
    }

    /// Iterate stored checkpoints.
    pub fn iter(&self) -> impl Iterator<Item = &Checkpoint> {
        self.slots.iter().flatten()
    }

    /// `(slot, checkpoint)` pairs in ascending slot order (durability
    /// snapshots capture exact placement so recovery rebuilds the same
    /// victim-rank geometry).
    pub fn slot_entries(&self) -> impl Iterator<Item = (usize, &Checkpoint)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|c| (i, c)))
    }

    /// The next id [`ModelStore::next_id`] would hand out, without
    /// allocating it (durability snapshots).
    pub fn next_id_peek(&self) -> u64 {
        self.next_id
    }

    /// Replacement-policy counters for durability snapshots.
    pub fn policy_state(&self) -> Vec<u64> {
        self.policy.persist_state()
    }

    /// Restore counters saved by [`ModelStore::policy_state`].
    pub fn restore_policy_state(&mut self, state: &[u64]) {
        self.policy.restore_state(state);
    }

    /// Replay one recorded admission (crash recovery): re-applies the
    /// exact placement and victim set the live run produced — slots, the
    /// coverage index, byte charges, stats, and the id sequence all end up
    /// identical without consulting the policy (whose counters are
    /// restored separately from the same journal entry).
    pub(crate) fn apply_store_record(&mut self, ckpt: Checkpoint, event: &StoreEvent) {
        self.next_id = self.next_id.max(ckpt.id.0 + 1);
        match event {
            StoreEvent::Rejected => self.stats.rejected += 1,
            StoreEvent::Stored { slot } => {
                self.place_at(*slot, ckpt);
                self.stats.stored += 1;
            }
            StoreEvent::Replaced { slot, evicted } => {
                self.remove_by_id(*evicted);
                self.place_at(*slot, ckpt);
                self.stats.stored += 1;
                self.stats.replaced += 1;
            }
            StoreEvent::Evicted { slot, victims } => {
                for v in victims {
                    self.remove_by_id(*v);
                }
                self.place_at(*slot, ckpt);
                self.stats.stored += 1;
                self.stats.replaced += victims.len() as u64;
            }
        }
    }

    /// Account a rejection whose id was already allocated (replaying the
    /// engine's probe-and-skip path).
    pub(crate) fn apply_skipped_rejection(&mut self, id: u64) {
        self.next_id = self.next_id.max(id + 1);
        self.stats.rejected += 1;
    }

    /// Rebuild the store from a durability snapshot: exact slot layout,
    /// id sequence, and cumulative stats. Byte charges and the coverage
    /// index are re-derived from the slots.
    pub(crate) fn restore_slots(
        &mut self,
        slots: Vec<Option<Checkpoint>>,
        next_id: u64,
        stats: StoreStats,
    ) {
        self.by_cover.clear();
        self.free.clear();
        self.charged.clear();
        self.bytes = 0;
        for (i, s) in slots.iter().enumerate() {
            match s {
                Some(c) => {
                    self.by_cover.insert((c.lineage, c.covered_segments, i));
                }
                None => {
                    self.free.insert(i);
                }
            }
        }
        let charges: Vec<(Option<Arc<EncodedParams>>, u64)> = slots
            .iter()
            .flatten()
            .map(|c| (c.params.clone(), c.size_bytes))
            .collect();
        self.slots = slots;
        for (params, size) in &charges {
            self.charge_payload(params.as_ref(), *size);
        }
        self.next_id = next_id;
        self.stats = stats;
    }

    fn remove_by_id(&mut self, id: CheckpointId) {
        let slot = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|c| c.id == id))
            .expect("replayed victim is resident");
        let old = self.slots[slot].take().expect("found above");
        self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
        self.release(&old);
        self.free.insert(slot);
    }

    fn place_at(&mut self, slot: usize, ckpt: Checkpoint) {
        while self.slots.len() <= slot {
            let i = self.slots.len();
            self.slots.push(None);
            self.free.insert(i);
        }
        debug_assert!(self.slots[slot].is_none(), "replayed slot occupied");
        self.free.remove(&slot);
        self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, slot));
        self.charge(&ckpt);
        self.slots[slot] = Some(ckpt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replacement::{FiboR, NoReplace};
    use crate::runtime::codec::{CodecMode, TensorCodec};
    use crate::runtime::HostTensor;
    use crate::testkit::forall_prefixes;

    fn ckpt(id: u64, lineage: usize, round: u32, segs: u32) -> Checkpoint {
        sized_ckpt(id, lineage, round, segs, 100)
    }

    fn sized_ckpt(id: u64, lineage: usize, round: u32, segs: u32, bytes: u64) -> Checkpoint {
        Checkpoint {
            id: CheckpointId(id),
            lineage,
            round,
            covered_segments: segs,
            size_bytes: bytes,
            params: None,
        }
    }

    /// Every indexed lookup must agree with its scan oracle.
    fn assert_index_matches_scan(st: &ModelStore) -> Result<(), String> {
        if st.occupied() != st.occupied_scan() {
            return Err(format!(
                "occupied {} != scan {}",
                st.occupied(),
                st.occupied_scan()
            ));
        }
        if st.stored_bytes() != st.stored_bytes_scan() {
            return Err(format!(
                "stored_bytes {} != scan {}",
                st.stored_bytes(),
                st.stored_bytes_scan()
            ));
        }
        for l in 0..5 {
            for cover in 0..12 {
                let idx = st.best_checkpoint(l, cover).map(|c| c.id);
                let scan = st.best_checkpoint_scan(l, cover).map(|c| c.id);
                if idx != scan {
                    return Err(format!(
                        "best_checkpoint({l},{cover}): index {idx:?} != scan {scan:?}"
                    ));
                }
            }
            let idx = st.latest(l).map(|c| c.id);
            let scan = st.latest_scan(l).map(|c| c.id);
            if idx != scan {
                return Err(format!("latest({l}): index {idx:?} != scan {scan:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn fills_free_slots_first() {
        let mut st = ModelStore::new(3, Box::new(FiboR::new()));
        assert_eq!(st.store(ckpt(0, 0, 1, 1)), StoreEvent::Stored { slot: 0 });
        assert_eq!(st.store(ckpt(1, 1, 1, 1)), StoreEvent::Stored { slot: 1 });
        assert_eq!(st.store(ckpt(2, 2, 1, 1)), StoreEvent::Stored { slot: 2 });
        assert_eq!(st.occupied(), 3);
        assert_eq!(st.stored_bytes(), 300);
        match st.store(ckpt(3, 0, 2, 2)) {
            StoreEvent::Replaced { evicted, .. } => assert_eq!(evicted, CheckpointId(0)),
            other => panic!("expected replacement, got {other:?}"),
        }
        assert_eq!(st.occupied(), 3);
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn no_replace_rejects_when_full() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        assert_eq!(st.stats().rejected, 1);
    }

    #[test]
    fn would_accept_predicts_store_outcome() {
        // No-replacement: accepts while free, rejects when full, accepts
        // again after invalidation frees a slot.
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        assert!(st.would_accept());
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert!(!st.would_accept());
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        st.invalidate(|c| c.covered_segments == 2);
        assert!(st.would_accept());
        assert!(matches!(st.store(ckpt(3, 0, 3, 3)), StoreEvent::Stored { .. }));
        // Evicting policies always accept.
        let mut st = ModelStore::new(1, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        assert!(st.would_accept());
        assert!(matches!(st.store(ckpt(1, 0, 2, 2)), StoreEvent::Replaced { .. }));
    }

    #[test]
    fn record_rejection_mirrors_rejected_store() {
        let mut st = ModelStore::new(1, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.record_rejection();
        assert_eq!(st.stats().rejected, 1);
        assert_eq!(st.stats().stored, 1);
    }

    #[test]
    fn best_checkpoint_respects_coverage_bound() {
        let mut st = ModelStore::new(4, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        st.store(ckpt(2, 0, 3, 3));
        st.store(ckpt(3, 1, 3, 3));
        // Unlearning data learned in segment 3 → need coverage <= 2.
        let best = st.best_checkpoint(0, 2).unwrap();
        assert_eq!(best.id, CheckpointId(1));
        // Nothing early enough → None.
        assert!(st.best_checkpoint(0, 0).is_none());
        // Other lineage untouched.
        assert_eq!(st.best_checkpoint(1, 3).unwrap().id, CheckpointId(3));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn invalidate_frees_slots_for_reuse() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.invalidate(|c| c.covered_segments >= 2), 1);
        assert_eq!(st.occupied(), 1);
        assert_eq!(st.stored_bytes(), 100);
        // Freed slot accepts a new checkpoint even under NoReplace.
        assert!(matches!(st.store(ckpt(2, 0, 3, 1)), StoreEvent::Stored { .. }));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn byte_budget_admits_by_size_not_count() {
        let mut st = ModelStore::with_byte_budget(1000, Box::new(NoReplace));
        assert_eq!(st.mode(), CapacityMode::Bytes(1000));
        assert_eq!(st.byte_budget(), Some(1000));
        for i in 0..10 {
            assert!(st.would_accept_bytes(100));
            assert_eq!(
                st.store(sized_ckpt(i, 0, i as u32 + 1, i as u32 + 1, 100)),
                StoreEvent::Stored { slot: i as usize }
            );
        }
        // Budget exhausted: no-replacement rejects regardless of slots.
        assert!(!st.would_accept_bytes(1));
        assert!(!st.would_accept());
        assert_eq!(st.store(sized_ckpt(10, 0, 11, 11, 1)), StoreEvent::Rejected);
        assert_eq!(st.occupied(), 10);
        assert_eq!(st.stored_bytes(), 1000);
        // Invalidation frees bytes, not just slots.
        st.invalidate(|c| c.covered_segments <= 2);
        assert_eq!(st.stored_bytes(), 800);
        assert!(st.would_accept_bytes(200));
        assert!(!st.would_accept_bytes(201));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn byte_budget_evicts_as_many_victims_as_needed() {
        let mut st = ModelStore::with_byte_budget(100, Box::new(FiboR::new()));
        st.store(sized_ckpt(0, 0, 1, 1, 40));
        st.store(sized_ckpt(1, 0, 2, 2, 40));
        assert_eq!(st.stored_bytes(), 80);
        // An 80-byte incomer must displace both residents.
        match st.store(sized_ckpt(2, 0, 3, 3, 80)) {
            StoreEvent::Evicted { victims, .. } => {
                assert_eq!(victims.len(), 2);
            }
            other => panic!("expected multi-victim eviction, got {other:?}"),
        }
        assert_eq!(st.occupied(), 1);
        assert_eq!(st.stored_bytes(), 80);
        assert_eq!(st.stats().stored, 3);
        assert_eq!(st.stats().replaced, 2);
        // Oversized payloads are rejected outright, evicting nothing.
        assert_eq!(st.store(sized_ckpt(3, 0, 4, 4, 101)), StoreEvent::Rejected);
        assert_eq!(st.occupied(), 1);
        assert!(!st.would_accept_bytes(101));
        assert!(st.would_accept_bytes(100)); // evicting policy
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn byte_budget_unit_sizes_replay_slot_mode() {
        // With unit-size checkpoints and budget == slot count, the byte
        // store must produce the slot store's exact receipts.
        let mut slot = ModelStore::new(4, Box::new(FiboR::new()));
        let mut byte = ModelStore::with_byte_budget(4, Box::new(FiboR::new()));
        for i in 0..20u64 {
            let a = slot.store(sized_ckpt(i, (i % 3) as usize, i as u32 + 1, i as u32 + 1, 1));
            let b = byte.store(sized_ckpt(i, (i % 3) as usize, i as u32 + 1, i as u32 + 1, 1));
            assert_eq!(a, b, "event diverged at store #{i}");
        }
        assert_eq!(slot.stats(), byte.stats());
        assert_eq!(slot.occupied(), byte.occupied());
        assert_eq!(slot.stored_bytes(), byte.stored_bytes());
        for l in 0..3 {
            assert_eq!(slot.latest(l).map(|c| c.id), byte.latest(l).map(|c| c.id));
        }
    }

    /// Build a delta chain: `payloads[0]` self-contained, each later
    /// payload a delta against its predecessor. Returns the encoded
    /// payloads (chain links pinned via `Arc`).
    fn delta_chain(len: usize) -> Vec<Arc<EncodedParams>> {
        let codec = TensorCodec::new(CodecMode::Delta);
        let mut tensors = vec![HostTensor::from_fn(&[128], |i| (i as f32).sin() + 1.0)];
        let mut out: Vec<Arc<EncodedParams>> = vec![Arc::new(codec.encode(&tensors, None))];
        for step in 1..len {
            tensors[0].data[(step * 11) % 128] += 1.0;
            let enc = codec.encode(&tensors, Some(out.last().unwrap()));
            out.push(Arc::new(enc));
        }
        out
    }

    fn payload_ckpt(id: u64, segs: u32, p: &Arc<EncodedParams>) -> Checkpoint {
        Checkpoint {
            id: CheckpointId(id),
            lineage: 0,
            round: segs,
            covered_segments: segs,
            size_bytes: p.size_bytes(),
            params: Some(p.clone()),
        }
    }

    /// The PR 4 retention caveat, closed: evicting a delta's parent keeps
    /// the parent payload charged while the child pins it, so the charged
    /// total equals real memory and the budget is honored by evicting
    /// further instead of silently overshooting.
    #[test]
    fn delta_pinned_parents_count_against_budget() {
        let chain = delta_chain(2);
        let (p0, p1) = (&chain[0], &chain[1]);
        assert!(p1.is_delta(), "chain link must be a delta");
        let (s0, s1) = (p0.size_bytes(), p1.size_bytes());
        assert!(s1 < s0, "delta must be cheaper than its parent here");

        // Budget fits the parent + child chain plus a little slack, but
        // not a second parent-sized payload on top.
        let budget = s0 + s1 + 8;
        let mut st = ModelStore::with_byte_budget(budget, Box::new(FiboR::new()));
        assert!(matches!(st.store(payload_ckpt(0, 1, p0)), StoreEvent::Stored { .. }));
        assert!(matches!(st.store(payload_ckpt(1, 2, p1)), StoreEvent::Stored { .. }));
        // Shared chain: the child only added its own delta bytes.
        assert_eq!(st.stored_bytes(), s0 + s1);
        assert_eq!(st.stored_bytes(), st.stored_bytes_scan());

        // An independent payload of the parent's size cannot fit by
        // evicting only the parent's checkpoint: the child still pins the
        // parent payload, so the store must evict the child too. Under the
        // pre-fix accounting a single eviction would have "freed" s0 while
        // the payload stayed resident — a real-memory overshoot.
        let solo = delta_chain(1).remove(0);
        match st.store(payload_ckpt(2, 3, &solo)) {
            StoreEvent::Evicted { victims, .. } => {
                assert_eq!(victims.len(), 2, "pinned parent forces a second eviction");
            }
            other => panic!("expected multi-victim eviction, got {other:?}"),
        }
        assert_eq!(st.stored_bytes(), solo.size_bytes());
        assert_eq!(st.stored_bytes(), st.stored_bytes_scan());
        assert!(st.stored_bytes() <= budget);
    }

    /// A long delta chain stored link by link can never overshoot the
    /// byte budget: at every step the charged total (pinned parents
    /// included) matches the dedup scan oracle and stays within C_m.
    #[test]
    fn long_delta_chain_cannot_overshoot_budget() {
        let chain = delta_chain(8);
        let budget = chain[0].size_bytes() * 2;
        let mut st = ModelStore::with_byte_budget(budget, Box::new(FiboR::new()));
        for (i, p) in chain.iter().enumerate() {
            st.store(payload_ckpt(i as u64, i as u32 + 1, p));
            assert!(
                st.stored_bytes() <= budget,
                "overshoot at link {i}: {} > {budget}",
                st.stored_bytes()
            );
            assert_eq!(st.stored_bytes(), st.stored_bytes_scan(), "link {i}");
            // The true retained memory (chains deduped) is the charge.
            let retained: u64 = st.stored_bytes_scan();
            assert_eq!(st.stored_bytes(), retained);
        }
        // Invalidation of a pinned parent keeps it charged until the
        // pinning child dies.
        let chain = delta_chain(2);
        let budget = chain[0].size_bytes() + chain[1].size_bytes();
        let mut st = ModelStore::with_byte_budget(budget, Box::new(NoReplace));
        st.store(payload_ckpt(0, 1, &chain[0]));
        st.store(payload_ckpt(1, 2, &chain[1]));
        let full = st.stored_bytes();
        st.invalidate(|c| c.covered_segments == 1); // parent checkpoint dies
        assert_eq!(st.stored_bytes(), full, "pinned parent stays charged");
        assert_eq!(st.stored_bytes(), st.stored_bytes_scan());
        st.invalidate(|c| c.covered_segments == 2); // child dies → all freed
        assert_eq!(st.stored_bytes(), 0);
        assert_eq!(st.stored_bytes_scan(), 0);
    }

    /// Replaying recorded admissions (`apply_store_record`) reproduces the
    /// live store byte for byte: slots, stats, bytes, index, id sequence.
    #[test]
    fn apply_store_record_mirrors_live_store() {
        let mk = || ModelStore::with_byte_budget(350, Box::new(FiboR::new()));
        let mut live = mk();
        let mut replayed = mk();
        for i in 0..20u64 {
            let c = sized_ckpt(0, (i % 3) as usize, i as u32 + 1, i as u32 + 1, 60 + (i % 4) * 20);
            let id = live.next_id();
            let ckpt = Checkpoint { id, ..c.clone() };
            let event = live.store(Checkpoint { id, ..c.clone() });
            replayed.apply_store_record(ckpt, &event);
            if i % 7 == 3 {
                let ids = live.invalidate_collect(|k| k.covered_segments <= i as u32 / 2);
                let removed =
                    replayed.invalidate_collect(|k| ids.contains(&k.id));
                assert_eq!(ids, removed, "invalidation set diverged at {i}");
            }
        }
        assert_eq!(live.stats(), replayed.stats());
        assert_eq!(live.occupied(), replayed.occupied());
        assert_eq!(live.stored_bytes(), replayed.stored_bytes());
        assert_eq!(live.next_id_peek(), replayed.next_id_peek());
        let ids = |s: &ModelStore| -> Vec<(usize, u64)> {
            s.slot_entries().map(|(slot, c)| (slot, c.id.0)).collect()
        };
        assert_eq!(ids(&live), ids(&replayed), "slot layout diverged");
        for l in 0..3 {
            for cover in 0..22 {
                assert_eq!(
                    live.best_checkpoint(l, cover).map(|c| c.id),
                    replayed.best_checkpoint(l, cover).map(|c| c.id)
                );
            }
        }
        assert_index_matches_scan(&replayed).unwrap();
    }

    /// An incoming checkpoint whose *chain* exceeds C_m is rejected
    /// outright, evicting nothing (the standalone precheck).
    #[test]
    fn oversized_chain_rejected_without_eviction() {
        let chain = delta_chain(2);
        let child_chain_bytes = chain[0].size_bytes() + chain[1].size_bytes();
        let mut st =
            ModelStore::with_byte_budget(child_chain_bytes - 1, Box::new(FiboR::new()));
        // The child alone is small, but admitting it would pin its parent
        // beyond the budget even in an empty store.
        assert!(chain[1].size_bytes() < child_chain_bytes - 1);
        assert_eq!(st.store(payload_ckpt(0, 2, &chain[1])), StoreEvent::Rejected);
        assert_eq!(st.occupied(), 0);
        assert_eq!(st.stats().rejected, 1);
    }

    #[test]
    fn prop_occupancy_never_exceeds_capacity() {
        forall_prefixes(
            0xF1B0,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.2),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(5, Box::new(FiboR::new())),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept();
                    let event = st.store(ckpt(*id, *lineage, *round, *round));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept disagreed with store()"
                    );
                    assert!(
                        !matches!(event, StoreEvent::Evicted { .. }),
                        "slot mode must never emit byte-mode receipts"
                    );
                }
            },
            |st| {
                if st.occupied() > st.capacity() {
                    return Err("over capacity".into());
                }
                // best_checkpoint coverage bound always honored.
                for l in 0..4 {
                    if let Some(c) = st.best_checkpoint(l, 3) {
                        if c.covered_segments > 3 {
                            return Err("coverage bound violated".into());
                        }
                    }
                }
                assert_index_matches_scan(st)
            },
        );
    }

    /// Same interleaving property under a rejecting policy, so the index
    /// is exercised across the store/reject/invalidate triangle.
    #[test]
    fn prop_index_matches_scan_under_no_replace() {
        forall_prefixes(
            0x1DE7,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.35),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(3, Box::new(NoReplace)),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept();
                    let event = st.store(ckpt(*id, *lineage, *round, *round));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept disagreed with store()"
                    );
                }
            },
            |st| assert_index_matches_scan(st),
        );
    }

    /// Byte mode under random sizes and interleavings: the O(1) byte
    /// counter must track the scan oracle, the budget must never be
    /// exceeded, and the size-aware probe must predict admission.
    #[test]
    fn prop_byte_mode_counter_matches_scan_and_budget_holds() {
        const BUDGET: u64 = 250;
        forall_prefixes(
            0xB7E5,
            60,
            |rng, size| {
                let n = 1 + (50.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.range(1, 120) as u64, // checkpoint bytes
                            rng.chance(0.25),
                            // the policy is fixed per store; this picks
                            // invalidation breadth instead
                            rng.chance(0.5),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::with_byte_budget(BUDGET, Box::new(FiboR::new())),
            |st, (id, lineage, round, bytes, invalidate, wide)| {
                if *invalidate {
                    if *wide {
                        st.invalidate(|c| c.lineage == *lineage);
                    } else {
                        st.invalidate(|c| c.lineage == *lineage && c.covered_segments == *round);
                    }
                } else {
                    let accepts = st.would_accept_bytes(*bytes);
                    let event = st.store(sized_ckpt(*id, *lineage, *round, *round, *bytes));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept_bytes disagreed with store() for {bytes} bytes"
                    );
                }
            },
            |st| {
                if st.stored_bytes() > BUDGET {
                    return Err(format!("over budget: {}", st.stored_bytes()));
                }
                assert_index_matches_scan(st)
            },
        );
    }

    /// Byte mode with a rejecting policy: the probe and the store must
    /// agree even when admission depends on the incoming size.
    #[test]
    fn prop_byte_mode_no_replace_probe_agrees() {
        const BUDGET: u64 = 120;
        forall_prefixes(
            0xB0B5,
            50,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 3),
                            rng.range(1, 8) as u32,
                            rng.range(1, 150) as u64,
                            rng.chance(0.3),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::with_byte_budget(BUDGET, Box::new(NoReplace)),
            |st, (id, lineage, round, bytes, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept_bytes(*bytes);
                    let event = st.store(sized_ckpt(*id, *lineage, *round, *round, *bytes));
                    assert_eq!(accepts, event != StoreEvent::Rejected);
                }
            },
            |st| {
                if st.stored_bytes() > BUDGET {
                    return Err(format!("over budget: {}", st.stored_bytes()));
                }
                assert_index_matches_scan(st)
            },
        );
    }
}
