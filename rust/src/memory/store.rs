//! The sub-model checkpoint store — the paper's normalized memory
//! (`N_mem` slots) plus the replacement machinery of Algorithm 2.
//!
//! Slots hold checkpoints of shard lineages at specific rounds. While free
//! slots remain, new checkpoints are stored directly (Algorithm 2 lines
//! 5–7); once full, the configured [`ReplacementPolicy`] picks the victim
//! slot (lines 9–11) or rejects the store (the no-replacement baselines).
//!
//! The store also implements Algorithm 3 line 11: when an unlearning
//! request invalidates checkpoints (they contain the unlearned data), they
//! are deleted in place, freeing slots.
//!
//! ## Complexity
//!
//! A secondary index ordered by `(lineage, coverage, slot)` is maintained
//! by every mutation, so the planner's point lookups never scan the slot
//! array:
//!
//! * [`ModelStore::best_checkpoint`] / [`ModelStore::latest`] — O(log n)
//!   range queries (tie-broken exactly like the original scan: highest
//!   coverage, then highest slot)
//! * [`ModelStore::occupied`] — O(1) (free-slot set)
//! * [`ModelStore::store`] — O(log n) (lowest free slot via the set)
//!
//! The `*_scan` twins keep the original linear scans alive as differential
//! oracles for the property tests and `bench_scale`'s naive baseline.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::replacement::ReplacementPolicy;
use crate::runtime::HostTensor;

/// Unique checkpoint id (monotonic per store).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CheckpointId(pub u64);

/// A stored sub-model checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub id: CheckpointId,
    /// Shard lineage this checkpoint belongs to.
    pub lineage: usize,
    /// Training round after which it was taken (1-based).
    pub round: u32,
    /// Number of lineage *segments* (rounds of data) covered — a checkpoint
    /// covers a contiguous prefix of its lineage's training history.
    pub covered_segments: u32,
    /// Stored (pruned) size in bytes.
    pub size_bytes: u64,
    /// Actual parameters when running with the PJRT trainer; None in the
    /// pure-accounting path. Shared ownership: warm-start resolution and
    /// serving restores clone the refcount, never the tensor data.
    pub params: Option<Arc<[HostTensor]>>,
}

/// Outcome of a store attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreEvent {
    /// Stored into a free slot.
    Stored { slot: usize },
    /// Evicted the previous occupant of `slot`.
    Replaced { slot: usize, evicted: CheckpointId },
    /// Dropped (no-replacement policy and memory full).
    Rejected,
}

/// Cumulative counters for reporting.
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    pub stored: u64,
    pub replaced: u64,
    pub rejected: u64,
    pub invalidated: u64,
}

/// The checkpoint store: `capacity` normalized slots.
pub struct ModelStore {
    slots: Vec<Option<Checkpoint>>,
    policy: Box<dyn ReplacementPolicy>,
    next_id: u64,
    stats: StoreStats,
    /// Currently empty slots (lowest-first allocation, like the original
    /// free-slot scan).
    free: BTreeSet<usize>,
    /// `(lineage, covered_segments, slot)` for every stored checkpoint.
    /// The last element of a `(lineage, ..=coverage)` range is exactly the
    /// checkpoint the original `max_by_key` scan selected.
    by_cover: BTreeSet<(usize, u32, usize)>,
}

impl ModelStore {
    /// `capacity` = N_mem (the paper normalizes memory by sub-model size).
    pub fn new(capacity: usize, policy: Box<dyn ReplacementPolicy>) -> Self {
        assert!(capacity >= 1, "store needs at least one slot");
        Self {
            slots: vec![None; capacity],
            policy,
            next_id: 0,
            stats: StoreStats::default(),
            free: (0..capacity).collect(),
            by_cover: BTreeSet::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Occupied slot count. O(1) via the free-slot set.
    pub fn occupied(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Differential oracle for [`ModelStore::occupied`]: the original
    /// linear count. Test/bench use only.
    pub fn occupied_scan(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Allocate an id for a checkpoint (ids are store-scoped).
    pub fn next_id(&mut self) -> CheckpointId {
        let id = CheckpointId(self.next_id);
        self.next_id += 1;
        id
    }

    /// Would [`ModelStore::store`] accept a checkpoint right now (free
    /// slot, or an evicting policy), or reject it (no-replacement policy
    /// and memory full)? Read-only probe — lets the engine skip the
    /// checkpoint snapshot entirely when the store would drop it anyway.
    pub fn would_accept(&self) -> bool {
        !self.free.is_empty() || self.policy.would_evict()
    }

    /// Account a rejection decided via [`ModelStore::would_accept`]
    /// without materializing the checkpoint — keeps [`StoreStats`]
    /// identical to a real `store` → [`StoreEvent::Rejected`] round-trip.
    pub fn record_rejection(&mut self) {
        self.stats.rejected += 1;
    }

    /// Store a checkpoint per Algorithm 2. Returns what happened.
    pub fn store(&mut self, ckpt: Checkpoint) -> StoreEvent {
        if let Some(free) = self.free.pop_first() {
            self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, free));
            self.slots[free] = Some(ckpt);
            self.stats.stored += 1;
            return StoreEvent::Stored { slot: free };
        }
        match self.policy.victim(self.slots.len()) {
            Some(slot) => {
                let old = self.slots[slot].as_ref().expect("full store");
                let evicted = old.id;
                let old_key = (old.lineage, old.covered_segments, slot);
                self.by_cover.remove(&old_key);
                self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, slot));
                self.slots[slot] = Some(ckpt);
                self.stats.stored += 1;
                self.stats.replaced += 1;
                StoreEvent::Replaced { slot, evicted }
            }
            None => {
                self.stats.rejected += 1;
                StoreEvent::Rejected
            }
        }
    }

    /// Newest stored checkpoint of `lineage` covering at most
    /// `max_segments` segments (i.e. taken before the poisoned data) —
    /// the retrain start point of Algorithm 3 line 8. O(log n).
    pub fn best_checkpoint(&self, lineage: usize, max_segments: u32) -> Option<&Checkpoint> {
        self.by_cover
            .range((lineage, 0, 0)..=(lineage, max_segments, usize::MAX))
            .next_back()
            .map(|&(_, _, slot)| self.slots[slot].as_ref().expect("indexed slot occupied"))
    }

    /// Differential oracle for [`ModelStore::best_checkpoint`]: the
    /// original O(slots) scan with identical tie-breaking (`max_by_key`
    /// keeps the last maximum — the highest slot). Test/bench use only.
    pub fn best_checkpoint_scan(
        &self,
        lineage: usize,
        max_segments: u32,
    ) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage && c.covered_segments <= max_segments)
            .max_by_key(|c| c.covered_segments)
    }

    /// Latest checkpoint of a lineage regardless of coverage (warm start
    /// for incremental training). O(log n).
    pub fn latest(&self, lineage: usize) -> Option<&Checkpoint> {
        self.by_cover
            .range((lineage, 0, 0)..=(lineage, u32::MAX, usize::MAX))
            .next_back()
            .map(|&(_, _, slot)| self.slots[slot].as_ref().expect("indexed slot occupied"))
    }

    /// Differential oracle for [`ModelStore::latest`]. Test/bench use only.
    pub fn latest_scan(&self, lineage: usize) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage)
            .max_by_key(|c| c.covered_segments)
    }

    /// Delete every checkpoint matching `pred` (Algorithm 3 line 11);
    /// returns how many were removed.
    pub fn invalidate(&mut self, mut pred: impl FnMut(&Checkpoint) -> bool) -> usize {
        let mut n = 0;
        for (slot, s) in self.slots.iter_mut().enumerate() {
            if s.as_ref().map(&mut pred).unwrap_or(false) {
                let old = s.take().expect("checked above");
                self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
                self.free.insert(slot);
                n += 1;
            }
        }
        self.stats.invalidated += n as u64;
        n
    }

    /// Iterate stored checkpoints.
    pub fn iter(&self) -> impl Iterator<Item = &Checkpoint> {
        self.slots.iter().flatten()
    }

    /// Total bytes currently stored (diagnostics; capacity is slot-based).
    pub fn stored_bytes(&self) -> u64 {
        self.iter().map(|c| c.size_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replacement::{FiboR, NoReplace};
    use crate::testkit::forall_prefixes;

    fn ckpt(id: u64, lineage: usize, round: u32, segs: u32) -> Checkpoint {
        Checkpoint {
            id: CheckpointId(id),
            lineage,
            round,
            covered_segments: segs,
            size_bytes: 100,
            params: None,
        }
    }

    /// Every indexed lookup must agree with its scan oracle.
    fn assert_index_matches_scan(st: &ModelStore) -> Result<(), String> {
        if st.occupied() != st.occupied_scan() {
            return Err(format!(
                "occupied {} != scan {}",
                st.occupied(),
                st.occupied_scan()
            ));
        }
        for l in 0..5 {
            for cover in 0..12 {
                let idx = st.best_checkpoint(l, cover).map(|c| c.id);
                let scan = st.best_checkpoint_scan(l, cover).map(|c| c.id);
                if idx != scan {
                    return Err(format!(
                        "best_checkpoint({l},{cover}): index {idx:?} != scan {scan:?}"
                    ));
                }
            }
            let idx = st.latest(l).map(|c| c.id);
            let scan = st.latest_scan(l).map(|c| c.id);
            if idx != scan {
                return Err(format!("latest({l}): index {idx:?} != scan {scan:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn fills_free_slots_first() {
        let mut st = ModelStore::new(3, Box::new(FiboR::new()));
        assert_eq!(st.store(ckpt(0, 0, 1, 1)), StoreEvent::Stored { slot: 0 });
        assert_eq!(st.store(ckpt(1, 1, 1, 1)), StoreEvent::Stored { slot: 1 });
        assert_eq!(st.store(ckpt(2, 2, 1, 1)), StoreEvent::Stored { slot: 2 });
        assert_eq!(st.occupied(), 3);
        match st.store(ckpt(3, 0, 2, 2)) {
            StoreEvent::Replaced { evicted, .. } => assert_eq!(evicted, CheckpointId(0)),
            other => panic!("expected replacement, got {other:?}"),
        }
        assert_eq!(st.occupied(), 3);
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn no_replace_rejects_when_full() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        assert_eq!(st.stats().rejected, 1);
    }

    #[test]
    fn would_accept_predicts_store_outcome() {
        // No-replacement: accepts while free, rejects when full, accepts
        // again after invalidation frees a slot.
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        assert!(st.would_accept());
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert!(!st.would_accept());
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        st.invalidate(|c| c.covered_segments == 2);
        assert!(st.would_accept());
        assert!(matches!(st.store(ckpt(3, 0, 3, 3)), StoreEvent::Stored { .. }));
        // Evicting policies always accept.
        let mut st = ModelStore::new(1, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        assert!(st.would_accept());
        assert!(matches!(st.store(ckpt(1, 0, 2, 2)), StoreEvent::Replaced { .. }));
    }

    #[test]
    fn record_rejection_mirrors_rejected_store() {
        let mut st = ModelStore::new(1, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.record_rejection();
        assert_eq!(st.stats().rejected, 1);
        assert_eq!(st.stats().stored, 1);
    }

    #[test]
    fn best_checkpoint_respects_coverage_bound() {
        let mut st = ModelStore::new(4, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        st.store(ckpt(2, 0, 3, 3));
        st.store(ckpt(3, 1, 3, 3));
        // Unlearning data learned in segment 3 → need coverage <= 2.
        let best = st.best_checkpoint(0, 2).unwrap();
        assert_eq!(best.id, CheckpointId(1));
        // Nothing early enough → None.
        assert!(st.best_checkpoint(0, 0).is_none());
        // Other lineage untouched.
        assert_eq!(st.best_checkpoint(1, 3).unwrap().id, CheckpointId(3));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn invalidate_frees_slots_for_reuse() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.invalidate(|c| c.covered_segments >= 2), 1);
        assert_eq!(st.occupied(), 1);
        // Freed slot accepts a new checkpoint even under NoReplace.
        assert!(matches!(st.store(ckpt(2, 0, 3, 1)), StoreEvent::Stored { .. }));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn prop_occupancy_never_exceeds_capacity() {
        forall_prefixes(
            0xF1B0,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.2),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(5, Box::new(FiboR::new())),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept();
                    let event = st.store(ckpt(*id, *lineage, *round, *round));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept disagreed with store()"
                    );
                }
            },
            |st| {
                if st.occupied() > st.capacity() {
                    return Err("over capacity".into());
                }
                // best_checkpoint coverage bound always honored.
                for l in 0..4 {
                    if let Some(c) = st.best_checkpoint(l, 3) {
                        if c.covered_segments > 3 {
                            return Err("coverage bound violated".into());
                        }
                    }
                }
                assert_index_matches_scan(st)
            },
        );
    }

    /// Same interleaving property under a rejecting policy, so the index
    /// is exercised across the store/reject/invalidate triangle.
    #[test]
    fn prop_index_matches_scan_under_no_replace() {
        forall_prefixes(
            0x1DE7,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.35),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(3, Box::new(NoReplace)),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept();
                    let event = st.store(ckpt(*id, *lineage, *round, *round));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept disagreed with store()"
                    );
                }
            },
            |st| assert_index_matches_scan(st),
        );
    }
}
