//! The sub-model checkpoint store — the paper's memory budget C_m plus the
//! replacement machinery of Algorithm 2, metered in **slots** (the paper's
//! normalized N_mem, the default) or in **true bytes**.
//!
//! Slots hold checkpoints of shard lineages at specific rounds. While free
//! capacity remains, new checkpoints are stored directly (Algorithm 2 lines
//! 5–7); once full, the configured [`ReplacementPolicy`] picks the victim
//! (lines 9–11) or rejects the store (the no-replacement baselines).
//!
//! The store also implements Algorithm 3 line 11: when an unlearning
//! request invalidates checkpoints (they contain the unlearned data), they
//! are deleted in place, freeing capacity.
//!
//! ## Capacity modes
//!
//! * [`ModelStore::new`] — `capacity` = N_mem equal slots (the paper
//!   normalizes memory by *dense* sub-model size). Semantics are byte-
//!   identical to the pre-byte-mode store: every admission, eviction, and
//!   rejection receipt is unchanged, which keeps the SISA/ARCANE/OMP
//!   baselines exactly reproducible.
//! * [`ModelStore::with_byte_budget`] — C_m in bytes. Admission reasons in
//!   each checkpoint's true `size_bytes` (derived from the codec's actual
//!   encoding): the policy evicts **as many victims as needed** to fit the
//!   incoming checkpoint, so a keep=0.3 sparse-encoded model occupies ~1/3
//!   of a dense one and the same C_m holds ~3x the checkpoints. The victim
//!   policy ranks over the *resident* checkpoints (rank r → r-th occupied
//!   slot); on a full uniform-size store that mapping is the identity, so
//!   unit-size byte budgets replay slot mode byte for byte
//!   (property-tested in `tests/compressed_store.rs`).
//!
//! ## Complexity
//!
//! A secondary index ordered by `(lineage, coverage, slot)` is maintained
//! by every mutation, so the planner's point lookups never scan the slot
//! array:
//!
//! * [`ModelStore::best_checkpoint`] / [`ModelStore::latest`] — O(log n)
//!   range queries (tie-broken exactly like the original scan: highest
//!   coverage, then highest slot)
//! * [`ModelStore::occupied`] — O(1) (free-slot set)
//! * [`ModelStore::stored_bytes`] — O(1) (a counter maintained by
//!   store/evict/invalidate)
//! * [`ModelStore::store`] — O(log n) (lowest free slot via the set), plus
//!   O(occupied) per eviction in byte mode (victim-rank resolution)
//!
//! The `*_scan` twins keep the original linear scans alive as differential
//! oracles for the property tests and the benches' naive baselines.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::replacement::ReplacementPolicy;
use crate::runtime::codec::EncodedParams;

/// Unique checkpoint id (monotonic per store).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CheckpointId(pub u64);

/// A stored sub-model checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub id: CheckpointId,
    /// Shard lineage this checkpoint belongs to.
    pub lineage: usize,
    /// Training round after which it was taken (1-based).
    pub round: u32,
    /// Number of lineage *segments* (rounds of data) covered — a checkpoint
    /// covers a contiguous prefix of its lineage's training history.
    pub covered_segments: u32,
    /// Stored size in bytes. For tensor-carrying backends this is the true
    /// encoded payload size ([`EncodedParams::size_bytes`]); the accounting
    /// backend supplies its paper-scale formula value.
    pub size_bytes: u64,
    /// Encoded parameters when running with a tensor-carrying trainer;
    /// None in the pure-accounting path. Shared ownership: warm-start
    /// resolution and serving restores clone the refcount and decode
    /// through a per-plan cache, never copying payload bytes.
    pub params: Option<Arc<EncodedParams>>,
}

/// How a store meters its capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapacityMode {
    /// N_mem equal slots (paper baseline).
    Slots(usize),
    /// C_m true bytes.
    Bytes(u64),
}

/// Config-level store metering choice; the budget value itself is the
/// experiment's C_m (`memory_bytes`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StoreMeter {
    /// C_m normalized to N_mem equal slots (the paper's accounting).
    #[default]
    Slots,
    /// C_m metered in true encoded bytes.
    Bytes,
}

impl StoreMeter {
    pub fn by_name(name: &str) -> Option<StoreMeter> {
        match name.to_ascii_lowercase().as_str() {
            "slots" | "slot" => Some(StoreMeter::Slots),
            "bytes" | "byte" => Some(StoreMeter::Bytes),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StoreMeter::Slots => "slots",
            StoreMeter::Bytes => "bytes",
        }
    }
}

/// Outcome of a store attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreEvent {
    /// Stored into free capacity.
    Stored { slot: usize },
    /// Evicted the previous occupant of `slot` (slot mode, and the
    /// byte-mode case where one victim's slot is reused directly).
    Replaced { slot: usize, evicted: CheckpointId },
    /// Byte mode: made room by evicting one or more victims, then stored
    /// into `slot` (which need not be a victim's slot).
    Evicted { slot: usize, victims: Vec<CheckpointId> },
    /// Dropped (no-replacement policy and memory full).
    Rejected,
}

/// Cumulative counters for reporting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub stored: u64,
    pub replaced: u64,
    pub rejected: u64,
    pub invalidated: u64,
}

/// The checkpoint store.
pub struct ModelStore {
    slots: Vec<Option<Checkpoint>>,
    policy: Box<dyn ReplacementPolicy>,
    next_id: u64,
    stats: StoreStats,
    mode: CapacityMode,
    /// Currently empty slots (lowest-first allocation, like the original
    /// free-slot scan).
    free: BTreeSet<usize>,
    /// `(lineage, covered_segments, slot)` for every stored checkpoint.
    /// The last element of a `(lineage, ..=coverage)` range is exactly the
    /// checkpoint the original `max_by_key` scan selected.
    by_cover: BTreeSet<(usize, u32, usize)>,
    /// Σ `size_bytes` over stored checkpoints — maintained by every
    /// store/evict/invalidate so [`ModelStore::stored_bytes`] is O(1).
    bytes: u64,
}

impl ModelStore {
    /// Slot mode: `capacity` = N_mem (the paper normalizes memory by
    /// sub-model size).
    pub fn new(capacity: usize, policy: Box<dyn ReplacementPolicy>) -> Self {
        assert!(capacity >= 1, "store needs at least one slot");
        Self {
            slots: vec![None; capacity],
            policy,
            next_id: 0,
            stats: StoreStats::default(),
            mode: CapacityMode::Slots(capacity),
            free: (0..capacity).collect(),
            by_cover: BTreeSet::new(),
            bytes: 0,
        }
    }

    /// Byte mode: admission, eviction, and `would_accept` reason in true
    /// checkpoint bytes against `budget` = C_m. Slots are allocated on
    /// demand and only bound diagnostics.
    pub fn with_byte_budget(budget: u64, policy: Box<dyn ReplacementPolicy>) -> Self {
        assert!(budget >= 1, "store needs a positive byte budget");
        Self {
            slots: Vec::new(),
            policy,
            next_id: 0,
            stats: StoreStats::default(),
            mode: CapacityMode::Bytes(budget),
            free: BTreeSet::new(),
            by_cover: BTreeSet::new(),
            bytes: 0,
        }
    }

    /// Slot-array length: the fixed N_mem in slot mode; in byte mode the
    /// high-water mark of simultaneously resident checkpoints
    /// (diagnostics — the byte budget is what binds).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// How this store meters capacity.
    pub fn mode(&self) -> CapacityMode {
        self.mode
    }

    /// The byte budget when metering bytes.
    pub fn byte_budget(&self) -> Option<u64> {
        match self.mode {
            CapacityMode::Slots(_) => None,
            CapacityMode::Bytes(b) => Some(b),
        }
    }

    /// Occupied slot count. O(1) via the free-slot set.
    pub fn occupied(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Differential oracle for [`ModelStore::occupied`]: the original
    /// linear count. Test/bench use only.
    pub fn occupied_scan(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Total bytes currently stored. O(1) maintained counter.
    pub fn stored_bytes(&self) -> u64 {
        self.bytes
    }

    /// Differential oracle for [`ModelStore::stored_bytes`]: the original
    /// full-slot scan. Test/bench use only.
    pub fn stored_bytes_scan(&self) -> u64 {
        self.iter().map(|c| c.size_bytes).sum()
    }

    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Allocate an id for a checkpoint (ids are store-scoped).
    pub fn next_id(&mut self) -> CheckpointId {
        let id = CheckpointId(self.next_id);
        self.next_id += 1;
        id
    }

    /// Would [`ModelStore::store`] accept a checkpoint right now (free
    /// capacity, or an evicting policy), or reject it (no-replacement
    /// policy and memory full)? Read-only probe — lets the engine skip the
    /// checkpoint snapshot entirely when the store would drop it anyway.
    /// In byte mode the probe is size-free and therefore *conservative*:
    /// it may say yes to a payload that turns out oversized, in which case
    /// `store()` rejects with identical accounting (use
    /// [`ModelStore::would_accept_bytes`] for a size-aware answer).
    pub fn would_accept(&self) -> bool {
        match self.mode {
            CapacityMode::Slots(_) => !self.free.is_empty() || self.policy.would_evict(),
            CapacityMode::Bytes(budget) => self.policy.would_evict() || self.bytes < budget,
        }
    }

    /// Size-aware admission probe: would `store()` accept a checkpoint of
    /// `size` bytes right now? Slot mode ignores `size`.
    pub fn would_accept_bytes(&self, size: u64) -> bool {
        match self.mode {
            CapacityMode::Slots(_) => self.would_accept(),
            CapacityMode::Bytes(budget) => {
                size <= budget && (self.policy.would_evict() || self.bytes + size <= budget)
            }
        }
    }

    /// Account a rejection decided via [`ModelStore::would_accept`]
    /// without materializing the checkpoint — keeps [`StoreStats`]
    /// identical to a real `store` → [`StoreEvent::Rejected`] round-trip.
    pub fn record_rejection(&mut self) {
        self.stats.rejected += 1;
    }

    /// Store a checkpoint per Algorithm 2. Returns what happened.
    pub fn store(&mut self, ckpt: Checkpoint) -> StoreEvent {
        match self.mode {
            CapacityMode::Slots(_) => self.store_slot(ckpt),
            CapacityMode::Bytes(budget) => self.store_bytes(ckpt, budget),
        }
    }

    /// Slot-mode admission — byte-identical to the pre-byte-mode store.
    fn store_slot(&mut self, ckpt: Checkpoint) -> StoreEvent {
        if let Some(free) = self.free.pop_first() {
            self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, free));
            self.bytes += ckpt.size_bytes;
            self.slots[free] = Some(ckpt);
            self.stats.stored += 1;
            return StoreEvent::Stored { slot: free };
        }
        match self.policy.victim(self.slots.len()) {
            Some(slot) => {
                let old = self.slots[slot].as_ref().expect("full store");
                let evicted = old.id;
                let old_key = (old.lineage, old.covered_segments, slot);
                self.bytes -= old.size_bytes;
                self.by_cover.remove(&old_key);
                self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, slot));
                self.bytes += ckpt.size_bytes;
                self.slots[slot] = Some(ckpt);
                self.stats.stored += 1;
                self.stats.replaced += 1;
                StoreEvent::Replaced { slot, evicted }
            }
            None => {
                self.stats.rejected += 1;
                StoreEvent::Rejected
            }
        }
    }

    /// Byte-mode admission: evict as many victims as the budget requires.
    fn store_bytes(&mut self, ckpt: Checkpoint, budget: u64) -> StoreEvent {
        if ckpt.size_bytes > budget {
            // Larger than all of C_m: no eviction set can ever fit it.
            self.stats.rejected += 1;
            return StoreEvent::Rejected;
        }
        let mut victims: Vec<(usize, CheckpointId)> = Vec::new();
        while self.bytes + ckpt.size_bytes > budget {
            let resident = self.occupied();
            debug_assert!(resident > 0, "positive stored bytes imply occupancy");
            let Some(rank) = self.policy.victim(resident) else {
                // No-replacement policy: it rejects on the first call, so
                // nothing has been evicted yet.
                debug_assert!(victims.is_empty(), "policy flipped mid-eviction");
                self.stats.rejected += 1;
                return StoreEvent::Rejected;
            };
            let slot = self.nth_occupied(rank);
            let old = self.slots[slot].take().expect("occupied rank maps to a full slot");
            self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
            self.bytes -= old.size_bytes;
            self.free.insert(slot);
            victims.push((slot, old.id));
        }
        let slot = match self.free.pop_first() {
            Some(s) => s,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.by_cover.insert((ckpt.lineage, ckpt.covered_segments, slot));
        self.bytes += ckpt.size_bytes;
        self.slots[slot] = Some(ckpt);
        self.stats.stored += 1;
        self.stats.replaced += victims.len() as u64;
        if victims.is_empty() {
            StoreEvent::Stored { slot }
        } else if victims.len() == 1 && victims[0].0 == slot {
            // One victim whose slot is reused directly: the receipt is the
            // slot path's, so unit-size byte budgets replay slot mode
            // byte for byte.
            StoreEvent::Replaced { slot, evicted: victims[0].1 }
        } else {
            StoreEvent::Evicted {
                slot,
                victims: victims.into_iter().map(|(_, id)| id).collect(),
            }
        }
    }

    /// Slot index of the `rank`-th resident checkpoint (ascending slot
    /// order). On a full store this is the identity, matching the slot
    /// path's policy semantics exactly.
    fn nth_occupied(&self, rank: usize) -> usize {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .nth(rank)
            .map(|(i, _)| i)
            .expect("victim rank within occupancy")
    }

    /// Newest stored checkpoint of `lineage` covering at most
    /// `max_segments` segments (i.e. taken before the poisoned data) —
    /// the retrain start point of Algorithm 3 line 8. O(log n).
    pub fn best_checkpoint(&self, lineage: usize, max_segments: u32) -> Option<&Checkpoint> {
        self.by_cover
            .range((lineage, 0, 0)..=(lineage, max_segments, usize::MAX))
            .next_back()
            .map(|&(_, _, slot)| self.slots[slot].as_ref().expect("indexed slot occupied"))
    }

    /// Differential oracle for [`ModelStore::best_checkpoint`]: the
    /// original O(slots) scan with identical tie-breaking (`max_by_key`
    /// keeps the last maximum — the highest slot). Test/bench use only.
    pub fn best_checkpoint_scan(
        &self,
        lineage: usize,
        max_segments: u32,
    ) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage && c.covered_segments <= max_segments)
            .max_by_key(|c| c.covered_segments)
    }

    /// Latest checkpoint of a lineage regardless of coverage (warm start
    /// for incremental training). O(log n).
    pub fn latest(&self, lineage: usize) -> Option<&Checkpoint> {
        self.by_cover
            .range((lineage, 0, 0)..=(lineage, u32::MAX, usize::MAX))
            .next_back()
            .map(|&(_, _, slot)| self.slots[slot].as_ref().expect("indexed slot occupied"))
    }

    /// Differential oracle for [`ModelStore::latest`]. Test/bench use only.
    pub fn latest_scan(&self, lineage: usize) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage)
            .max_by_key(|c| c.covered_segments)
    }

    /// Delete every checkpoint matching `pred` (Algorithm 3 line 11);
    /// returns how many were removed.
    pub fn invalidate(&mut self, mut pred: impl FnMut(&Checkpoint) -> bool) -> usize {
        let mut n = 0;
        let mut freed = 0u64;
        for (slot, s) in self.slots.iter_mut().enumerate() {
            if s.as_ref().map(&mut pred).unwrap_or(false) {
                let old = s.take().expect("checked above");
                self.by_cover.remove(&(old.lineage, old.covered_segments, slot));
                freed += old.size_bytes;
                self.free.insert(slot);
                n += 1;
            }
        }
        self.bytes -= freed;
        self.stats.invalidated += n as u64;
        n
    }

    /// Iterate stored checkpoints.
    pub fn iter(&self) -> impl Iterator<Item = &Checkpoint> {
        self.slots.iter().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replacement::{FiboR, NoReplace};
    use crate::testkit::forall_prefixes;

    fn ckpt(id: u64, lineage: usize, round: u32, segs: u32) -> Checkpoint {
        sized_ckpt(id, lineage, round, segs, 100)
    }

    fn sized_ckpt(id: u64, lineage: usize, round: u32, segs: u32, bytes: u64) -> Checkpoint {
        Checkpoint {
            id: CheckpointId(id),
            lineage,
            round,
            covered_segments: segs,
            size_bytes: bytes,
            params: None,
        }
    }

    /// Every indexed lookup must agree with its scan oracle.
    fn assert_index_matches_scan(st: &ModelStore) -> Result<(), String> {
        if st.occupied() != st.occupied_scan() {
            return Err(format!(
                "occupied {} != scan {}",
                st.occupied(),
                st.occupied_scan()
            ));
        }
        if st.stored_bytes() != st.stored_bytes_scan() {
            return Err(format!(
                "stored_bytes {} != scan {}",
                st.stored_bytes(),
                st.stored_bytes_scan()
            ));
        }
        for l in 0..5 {
            for cover in 0..12 {
                let idx = st.best_checkpoint(l, cover).map(|c| c.id);
                let scan = st.best_checkpoint_scan(l, cover).map(|c| c.id);
                if idx != scan {
                    return Err(format!(
                        "best_checkpoint({l},{cover}): index {idx:?} != scan {scan:?}"
                    ));
                }
            }
            let idx = st.latest(l).map(|c| c.id);
            let scan = st.latest_scan(l).map(|c| c.id);
            if idx != scan {
                return Err(format!("latest({l}): index {idx:?} != scan {scan:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn fills_free_slots_first() {
        let mut st = ModelStore::new(3, Box::new(FiboR::new()));
        assert_eq!(st.store(ckpt(0, 0, 1, 1)), StoreEvent::Stored { slot: 0 });
        assert_eq!(st.store(ckpt(1, 1, 1, 1)), StoreEvent::Stored { slot: 1 });
        assert_eq!(st.store(ckpt(2, 2, 1, 1)), StoreEvent::Stored { slot: 2 });
        assert_eq!(st.occupied(), 3);
        assert_eq!(st.stored_bytes(), 300);
        match st.store(ckpt(3, 0, 2, 2)) {
            StoreEvent::Replaced { evicted, .. } => assert_eq!(evicted, CheckpointId(0)),
            other => panic!("expected replacement, got {other:?}"),
        }
        assert_eq!(st.occupied(), 3);
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn no_replace_rejects_when_full() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        assert_eq!(st.stats().rejected, 1);
    }

    #[test]
    fn would_accept_predicts_store_outcome() {
        // No-replacement: accepts while free, rejects when full, accepts
        // again after invalidation frees a slot.
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        assert!(st.would_accept());
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert!(!st.would_accept());
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        st.invalidate(|c| c.covered_segments == 2);
        assert!(st.would_accept());
        assert!(matches!(st.store(ckpt(3, 0, 3, 3)), StoreEvent::Stored { .. }));
        // Evicting policies always accept.
        let mut st = ModelStore::new(1, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        assert!(st.would_accept());
        assert!(matches!(st.store(ckpt(1, 0, 2, 2)), StoreEvent::Replaced { .. }));
    }

    #[test]
    fn record_rejection_mirrors_rejected_store() {
        let mut st = ModelStore::new(1, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.record_rejection();
        assert_eq!(st.stats().rejected, 1);
        assert_eq!(st.stats().stored, 1);
    }

    #[test]
    fn best_checkpoint_respects_coverage_bound() {
        let mut st = ModelStore::new(4, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        st.store(ckpt(2, 0, 3, 3));
        st.store(ckpt(3, 1, 3, 3));
        // Unlearning data learned in segment 3 → need coverage <= 2.
        let best = st.best_checkpoint(0, 2).unwrap();
        assert_eq!(best.id, CheckpointId(1));
        // Nothing early enough → None.
        assert!(st.best_checkpoint(0, 0).is_none());
        // Other lineage untouched.
        assert_eq!(st.best_checkpoint(1, 3).unwrap().id, CheckpointId(3));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn invalidate_frees_slots_for_reuse() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.invalidate(|c| c.covered_segments >= 2), 1);
        assert_eq!(st.occupied(), 1);
        assert_eq!(st.stored_bytes(), 100);
        // Freed slot accepts a new checkpoint even under NoReplace.
        assert!(matches!(st.store(ckpt(2, 0, 3, 1)), StoreEvent::Stored { .. }));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn byte_budget_admits_by_size_not_count() {
        let mut st = ModelStore::with_byte_budget(1000, Box::new(NoReplace));
        assert_eq!(st.mode(), CapacityMode::Bytes(1000));
        assert_eq!(st.byte_budget(), Some(1000));
        for i in 0..10 {
            assert!(st.would_accept_bytes(100));
            assert_eq!(
                st.store(sized_ckpt(i, 0, i as u32 + 1, i as u32 + 1, 100)),
                StoreEvent::Stored { slot: i as usize }
            );
        }
        // Budget exhausted: no-replacement rejects regardless of slots.
        assert!(!st.would_accept_bytes(1));
        assert!(!st.would_accept());
        assert_eq!(st.store(sized_ckpt(10, 0, 11, 11, 1)), StoreEvent::Rejected);
        assert_eq!(st.occupied(), 10);
        assert_eq!(st.stored_bytes(), 1000);
        // Invalidation frees bytes, not just slots.
        st.invalidate(|c| c.covered_segments <= 2);
        assert_eq!(st.stored_bytes(), 800);
        assert!(st.would_accept_bytes(200));
        assert!(!st.would_accept_bytes(201));
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn byte_budget_evicts_as_many_victims_as_needed() {
        let mut st = ModelStore::with_byte_budget(100, Box::new(FiboR::new()));
        st.store(sized_ckpt(0, 0, 1, 1, 40));
        st.store(sized_ckpt(1, 0, 2, 2, 40));
        assert_eq!(st.stored_bytes(), 80);
        // An 80-byte incomer must displace both residents.
        match st.store(sized_ckpt(2, 0, 3, 3, 80)) {
            StoreEvent::Evicted { victims, .. } => {
                assert_eq!(victims.len(), 2);
            }
            other => panic!("expected multi-victim eviction, got {other:?}"),
        }
        assert_eq!(st.occupied(), 1);
        assert_eq!(st.stored_bytes(), 80);
        assert_eq!(st.stats().stored, 3);
        assert_eq!(st.stats().replaced, 2);
        // Oversized payloads are rejected outright, evicting nothing.
        assert_eq!(st.store(sized_ckpt(3, 0, 4, 4, 101)), StoreEvent::Rejected);
        assert_eq!(st.occupied(), 1);
        assert!(!st.would_accept_bytes(101));
        assert!(st.would_accept_bytes(100)); // evicting policy
        assert_index_matches_scan(&st).unwrap();
    }

    #[test]
    fn byte_budget_unit_sizes_replay_slot_mode() {
        // With unit-size checkpoints and budget == slot count, the byte
        // store must produce the slot store's exact receipts.
        let mut slot = ModelStore::new(4, Box::new(FiboR::new()));
        let mut byte = ModelStore::with_byte_budget(4, Box::new(FiboR::new()));
        for i in 0..20u64 {
            let a = slot.store(sized_ckpt(i, (i % 3) as usize, i as u32 + 1, i as u32 + 1, 1));
            let b = byte.store(sized_ckpt(i, (i % 3) as usize, i as u32 + 1, i as u32 + 1, 1));
            assert_eq!(a, b, "event diverged at store #{i}");
        }
        assert_eq!(slot.stats(), byte.stats());
        assert_eq!(slot.occupied(), byte.occupied());
        assert_eq!(slot.stored_bytes(), byte.stored_bytes());
        for l in 0..3 {
            assert_eq!(slot.latest(l).map(|c| c.id), byte.latest(l).map(|c| c.id));
        }
    }

    #[test]
    fn prop_occupancy_never_exceeds_capacity() {
        forall_prefixes(
            0xF1B0,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.2),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(5, Box::new(FiboR::new())),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept();
                    let event = st.store(ckpt(*id, *lineage, *round, *round));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept disagreed with store()"
                    );
                    assert!(
                        !matches!(event, StoreEvent::Evicted { .. }),
                        "slot mode must never emit byte-mode receipts"
                    );
                }
            },
            |st| {
                if st.occupied() > st.capacity() {
                    return Err("over capacity".into());
                }
                // best_checkpoint coverage bound always honored.
                for l in 0..4 {
                    if let Some(c) = st.best_checkpoint(l, 3) {
                        if c.covered_segments > 3 {
                            return Err("coverage bound violated".into());
                        }
                    }
                }
                assert_index_matches_scan(st)
            },
        );
    }

    /// Same interleaving property under a rejecting policy, so the index
    /// is exercised across the store/reject/invalidate triangle.
    #[test]
    fn prop_index_matches_scan_under_no_replace() {
        forall_prefixes(
            0x1DE7,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.35),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(3, Box::new(NoReplace)),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept();
                    let event = st.store(ckpt(*id, *lineage, *round, *round));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept disagreed with store()"
                    );
                }
            },
            |st| assert_index_matches_scan(st),
        );
    }

    /// Byte mode under random sizes and interleavings: the O(1) byte
    /// counter must track the scan oracle, the budget must never be
    /// exceeded, and the size-aware probe must predict admission.
    #[test]
    fn prop_byte_mode_counter_matches_scan_and_budget_holds() {
        const BUDGET: u64 = 250;
        forall_prefixes(
            0xB7E5,
            60,
            |rng, size| {
                let n = 1 + (50.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.range(1, 120) as u64, // checkpoint bytes
                            rng.chance(0.25),
                            // the policy is fixed per store; this picks
                            // invalidation breadth instead
                            rng.chance(0.5),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::with_byte_budget(BUDGET, Box::new(FiboR::new())),
            |st, (id, lineage, round, bytes, invalidate, wide)| {
                if *invalidate {
                    if *wide {
                        st.invalidate(|c| c.lineage == *lineage);
                    } else {
                        st.invalidate(|c| c.lineage == *lineage && c.covered_segments == *round);
                    }
                } else {
                    let accepts = st.would_accept_bytes(*bytes);
                    let event = st.store(sized_ckpt(*id, *lineage, *round, *round, *bytes));
                    assert_eq!(
                        accepts,
                        event != StoreEvent::Rejected,
                        "would_accept_bytes disagreed with store() for {bytes} bytes"
                    );
                }
            },
            |st| {
                if st.stored_bytes() > BUDGET {
                    return Err(format!("over budget: {}", st.stored_bytes()));
                }
                assert_index_matches_scan(st)
            },
        );
    }

    /// Byte mode with a rejecting policy: the probe and the store must
    /// agree even when admission depends on the incoming size.
    #[test]
    fn prop_byte_mode_no_replace_probe_agrees() {
        const BUDGET: u64 = 120;
        forall_prefixes(
            0xB0B5,
            50,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 3),
                            rng.range(1, 8) as u32,
                            rng.range(1, 150) as u64,
                            rng.chance(0.3),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::with_byte_budget(BUDGET, Box::new(NoReplace)),
            |st, (id, lineage, round, bytes, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    let accepts = st.would_accept_bytes(*bytes);
                    let event = st.store(sized_ckpt(*id, *lineage, *round, *round, *bytes));
                    assert_eq!(accepts, event != StoreEvent::Rejected);
                }
            },
            |st| {
                if st.stored_bytes() > BUDGET {
                    return Err(format!("over budget: {}", st.stored_bytes()));
                }
                assert_index_matches_scan(st)
            },
        );
    }
}
