//! The sub-model checkpoint store — the paper's normalized memory
//! (`N_mem` slots) plus the replacement machinery of Algorithm 2.
//!
//! Slots hold checkpoints of shard lineages at specific rounds. While free
//! slots remain, new checkpoints are stored directly (Algorithm 2 lines
//! 5–7); once full, the configured [`ReplacementPolicy`] picks the victim
//! slot (lines 9–11) or rejects the store (the no-replacement baselines).
//!
//! The store also implements Algorithm 3 line 11: when an unlearning
//! request invalidates checkpoints (they contain the unlearned data), they
//! are deleted in place, freeing slots.

use crate::replacement::ReplacementPolicy;
use crate::runtime::HostTensor;

/// Unique checkpoint id (monotonic per store).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CheckpointId(pub u64);

/// A stored sub-model checkpoint.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub id: CheckpointId,
    /// Shard lineage this checkpoint belongs to.
    pub lineage: usize,
    /// Training round after which it was taken (1-based).
    pub round: u32,
    /// Number of lineage *segments* (rounds of data) covered — a checkpoint
    /// covers a contiguous prefix of its lineage's training history.
    pub covered_segments: u32,
    /// Stored (pruned) size in bytes.
    pub size_bytes: u64,
    /// Actual parameters when running with the PJRT trainer; None in the
    /// pure-accounting path.
    pub params: Option<Vec<HostTensor>>,
}

/// Outcome of a store attempt.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreEvent {
    /// Stored into a free slot.
    Stored { slot: usize },
    /// Evicted the previous occupant of `slot`.
    Replaced { slot: usize, evicted: CheckpointId },
    /// Dropped (no-replacement policy and memory full).
    Rejected,
}

/// Cumulative counters for reporting.
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    pub stored: u64,
    pub replaced: u64,
    pub rejected: u64,
    pub invalidated: u64,
}

/// The checkpoint store: `capacity` normalized slots.
pub struct ModelStore {
    slots: Vec<Option<Checkpoint>>,
    policy: Box<dyn ReplacementPolicy>,
    next_id: u64,
    stats: StoreStats,
}

impl ModelStore {
    /// `capacity` = N_mem (the paper normalizes memory by sub-model size).
    pub fn new(capacity: usize, policy: Box<dyn ReplacementPolicy>) -> Self {
        assert!(capacity >= 1, "store needs at least one slot");
        Self { slots: vec![None; capacity], policy, next_id: 0, stats: StoreStats::default() }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Allocate an id for a checkpoint (ids are store-scoped).
    pub fn next_id(&mut self) -> CheckpointId {
        let id = CheckpointId(self.next_id);
        self.next_id += 1;
        id
    }

    /// Store a checkpoint per Algorithm 2. Returns what happened.
    pub fn store(&mut self, ckpt: Checkpoint) -> StoreEvent {
        if let Some(free) = self.slots.iter().position(|s| s.is_none()) {
            self.slots[free] = Some(ckpt);
            self.stats.stored += 1;
            return StoreEvent::Stored { slot: free };
        }
        match self.policy.victim(self.slots.len()) {
            Some(slot) => {
                let evicted = self.slots[slot].as_ref().expect("full store").id;
                self.slots[slot] = Some(ckpt);
                self.stats.stored += 1;
                self.stats.replaced += 1;
                StoreEvent::Replaced { slot, evicted }
            }
            None => {
                self.stats.rejected += 1;
                StoreEvent::Rejected
            }
        }
    }

    /// Newest stored checkpoint of `lineage` covering at most
    /// `max_segments` segments (i.e. taken before the poisoned data) —
    /// the retrain start point of Algorithm 3 line 8.
    pub fn best_checkpoint(&self, lineage: usize, max_segments: u32) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage && c.covered_segments <= max_segments)
            .max_by_key(|c| c.covered_segments)
    }

    /// Latest checkpoint of a lineage regardless of coverage (warm start
    /// for incremental training).
    pub fn latest(&self, lineage: usize) -> Option<&Checkpoint> {
        self.slots
            .iter()
            .flatten()
            .filter(|c| c.lineage == lineage)
            .max_by_key(|c| c.covered_segments)
    }

    /// Delete every checkpoint matching `pred` (Algorithm 3 line 11);
    /// returns how many were removed.
    pub fn invalidate(&mut self, mut pred: impl FnMut(&Checkpoint) -> bool) -> usize {
        let mut n = 0;
        for slot in &mut self.slots {
            if slot.as_ref().map(&mut pred).unwrap_or(false) {
                *slot = None;
                n += 1;
            }
        }
        self.stats.invalidated += n as u64;
        n
    }

    /// Iterate stored checkpoints.
    pub fn iter(&self) -> impl Iterator<Item = &Checkpoint> {
        self.slots.iter().flatten()
    }

    /// Total bytes currently stored (diagnostics; capacity is slot-based).
    pub fn stored_bytes(&self) -> u64 {
        self.iter().map(|c| c.size_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replacement::{FiboR, NoReplace};
    use crate::testkit::forall_prefixes;

    fn ckpt(id: u64, lineage: usize, round: u32, segs: u32) -> Checkpoint {
        Checkpoint {
            id: CheckpointId(id),
            lineage,
            round,
            covered_segments: segs,
            size_bytes: 100,
            params: None,
        }
    }

    #[test]
    fn fills_free_slots_first() {
        let mut st = ModelStore::new(3, Box::new(FiboR::new()));
        assert_eq!(st.store(ckpt(0, 0, 1, 1)), StoreEvent::Stored { slot: 0 });
        assert_eq!(st.store(ckpt(1, 1, 1, 1)), StoreEvent::Stored { slot: 1 });
        assert_eq!(st.store(ckpt(2, 2, 1, 1)), StoreEvent::Stored { slot: 2 });
        assert_eq!(st.occupied(), 3);
        match st.store(ckpt(3, 0, 2, 2)) {
            StoreEvent::Replaced { evicted, .. } => assert_eq!(evicted, CheckpointId(0)),
            other => panic!("expected replacement, got {other:?}"),
        }
        assert_eq!(st.occupied(), 3);
    }

    #[test]
    fn no_replace_rejects_when_full() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.store(ckpt(2, 0, 3, 3)), StoreEvent::Rejected);
        assert_eq!(st.stats().rejected, 1);
    }

    #[test]
    fn best_checkpoint_respects_coverage_bound() {
        let mut st = ModelStore::new(4, Box::new(FiboR::new()));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        st.store(ckpt(2, 0, 3, 3));
        st.store(ckpt(3, 1, 3, 3));
        // Unlearning data learned in segment 3 → need coverage <= 2.
        let best = st.best_checkpoint(0, 2).unwrap();
        assert_eq!(best.id, CheckpointId(1));
        // Nothing early enough → None.
        assert!(st.best_checkpoint(0, 0).is_none());
        // Other lineage untouched.
        assert_eq!(st.best_checkpoint(1, 3).unwrap().id, CheckpointId(3));
    }

    #[test]
    fn invalidate_frees_slots_for_reuse() {
        let mut st = ModelStore::new(2, Box::new(NoReplace));
        st.store(ckpt(0, 0, 1, 1));
        st.store(ckpt(1, 0, 2, 2));
        assert_eq!(st.invalidate(|c| c.covered_segments >= 2), 1);
        assert_eq!(st.occupied(), 1);
        // Freed slot accepts a new checkpoint even under NoReplace.
        assert!(matches!(st.store(ckpt(2, 0, 3, 1)), StoreEvent::Stored { .. }));
    }

    #[test]
    fn prop_occupancy_never_exceeds_capacity() {
        forall_prefixes(
            0xF1B0,
            60,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (
                            i as u64,
                            rng.range(0, 4),
                            rng.range(1, 10) as u32,
                            rng.chance(0.2),
                        )
                    })
                    .collect::<Vec<_>>()
            },
            || ModelStore::new(5, Box::new(FiboR::new())),
            |st, (id, lineage, round, invalidate)| {
                if *invalidate {
                    st.invalidate(|c| c.lineage == *lineage);
                } else {
                    st.store(ckpt(*id, *lineage, *round, *round));
                }
            },
            |st| {
                if st.occupied() > st.capacity() {
                    return Err("over capacity".into());
                }
                // best_checkpoint coverage bound always honored.
                for l in 0..4 {
                    if let Some(c) = st.best_checkpoint(l, 3) {
                        if c.covered_segments > 3 {
                            return Err("coverage bound violated".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
