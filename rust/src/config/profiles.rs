//! Model profiles at *paper scale* (Table 2): parameter counts, file sizes,
//! per-sample compute, and the AOT artifact variant that proxies each model
//! for real-training experiments.
//!
//! The cost/energy accounting path uses these paper-scale numbers so memory
//! budgets like "C_m = 2 GB" carry the paper's meaning; the PJRT path uses
//! the proxy artifacts' true sizes (read from the manifest).

/// Static profile of one backbone model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Parameters, millions (Table 2 "Params (M)" original).
    pub params_m: f64,
    /// Dense model file size, MB (Table 2 "Model File Size" original).
    pub file_mb: f64,
    /// Seconds to train one epoch over the full corpus on the Jetson-class
    /// device (derived from Table 2 retrain times; used only to translate
    /// RSN into seconds for readability).
    pub train_secs_per_epoch: f64,
    /// Training samples covered by `train_secs_per_epoch`.
    pub corpus_samples: f64,
    /// Fraction of parameters that magnitude pruning can remove (dense
    /// layers; conv/bn overhead is the remainder). Derived from Table 2:
    /// at δ=70%, file size drops 58.8–63.6% → prunable ≈ 0.9.
    pub prunable_frac: f64,
    /// AOT artifact variant used when this profile trains for real.
    pub variant_c10: &'static str,
    pub variant_c100: &'static str,
}

impl ModelProfile {
    pub fn by_name(name: &str) -> Option<ModelProfile> {
        match name {
            "resnet34" => Some(RESNET34),
            "vgg16" => Some(VGG16),
            "densenet121" => Some(DENSENET121),
            "mobilenetv2" => Some(MOBILENETV2),
            _ => None,
        }
    }

    pub const fn file_bytes(&self) -> u64 {
        (self.file_mb * 1024.0 * 1024.0) as u64
    }

    /// Stored size after pruning with keep fraction `keep` (CSR-style
    /// sparse encoding ≈ value + index per nonzero; Table 2 shows the
    /// file size shrinking near-linearly with δ).
    pub fn pruned_bytes(&self, keep: f64) -> u64 {
        let keep = keep.clamp(0.0, 1.0);
        let dense = self.file_mb * 1024.0 * 1024.0;
        let fixed = dense * (1.0 - self.prunable_frac);
        let kept = dense * self.prunable_frac * keep;
        (fixed + kept) as u64
    }

    /// Device seconds to (re)train `samples` for `epochs` epochs.
    pub fn train_secs(&self, samples: u64, epochs: u32) -> f64 {
        self.train_secs_per_epoch * (samples as f64 / self.corpus_samples) * epochs as f64
    }
}

// Table 2 anchors. Retrain-time entries in Table 2 are for the pruning
// experiment's epoch counts (Appendix A); we normalize to per-epoch over
// the training split.
pub const RESNET34: ModelProfile = ModelProfile {
    name: "resnet34",
    params_m: 23.61,
    file_mb: 85.82,
    train_secs_per_epoch: 746.37 / 20.0,
    corpus_samples: 50_000.0,
    prunable_frac: 0.9,
    variant_c10: "resnet34_c10",
    variant_c100: "resnet34_c100",
};

pub const VGG16: ModelProfile = ModelProfile {
    name: "vgg16",
    params_m: 15.05,
    file_mb: 53.02,
    train_secs_per_epoch: 750.31 / 30.0,
    corpus_samples: 50_000.0,
    prunable_frac: 0.95,
    variant_c10: "vgg16_c10",
    variant_c100: "vgg16_c100",
};

pub const DENSENET121: ModelProfile = ModelProfile {
    name: "densenet121",
    params_m: 7.14,
    file_mb: 26.24,
    train_secs_per_epoch: 957.20 / 20.0,
    corpus_samples: 50_000.0,
    prunable_frac: 0.88,
    variant_c10: "densenet121_c100", // paper pairs DenseNet with CIFAR-100
    variant_c100: "densenet121_c100",
};

pub const MOBILENETV2: ModelProfile = ModelProfile {
    name: "mobilenetv2",
    params_m: 2.18,
    file_mb: 7.71,
    train_secs_per_epoch: 212.42 / 20.0,
    corpus_samples: 50_000.0,
    prunable_frac: 0.9,
    variant_c10: "mobilenetv2_c10",
    variant_c100: "mobilenetv2_c10",
};

/// All four profiles in the paper's comparison order.
pub const ALL_MODELS: [ModelProfile; 4] = [RESNET34, VGG16, DENSENET121, MOBILENETV2];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_ordering_matches_paper() {
        assert!(RESNET34.file_mb > VGG16.file_mb);
        assert!(VGG16.file_mb > DENSENET121.file_mb);
        assert!(DENSENET121.file_mb > MOBILENETV2.file_mb);
    }

    #[test]
    fn pruning_shrinks_linearly() {
        let full = RESNET34.pruned_bytes(1.0);
        let p70 = RESNET34.pruned_bytes(0.3);
        let p0 = RESNET34.pruned_bytes(0.0);
        assert_eq!(full, RESNET34.file_bytes());
        // Table 2: δ=70% → ~63.6% size reduction for ResNet-34.
        let reduction = 1.0 - p70 as f64 / full as f64;
        assert!((reduction - 0.63).abs() < 0.02, "reduction {reduction}");
        assert!(p0 < p70);
    }

    #[test]
    fn train_time_scales_with_samples_and_epochs() {
        let t1 = MOBILENETV2.train_secs(50_000, 1);
        let t2 = MOBILENETV2.train_secs(25_000, 2);
        assert!((t1 - t2).abs() < 1e-9);
        assert!((t1 - 212.42 / 20.0).abs() < 1e-6);
    }

    #[test]
    fn lookup() {
        assert_eq!(ModelProfile::by_name("vgg16").unwrap().name, "vgg16");
        assert!(ModelProfile::by_name("alexnet").is_none());
    }
}
