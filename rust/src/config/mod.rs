//! Configuration system: model profiles (paper Table 2 scale), experiment
//! configs, and a tiny `key = value` config-file loader for the CLI.

pub mod profiles;

use anyhow::{bail, Result};

use crate::data::catalog::{DatasetSpec, CIFAR10};
use crate::memory::store::StoreMeter;
use crate::persist::{DurabilityMode, FsyncPolicy};
use crate::runtime::codec::CodecMode;
use crate::unlearning::batch::BatchPolicy;
pub use profiles::ModelProfile;

/// Everything a simulated run needs; defaults are the paper's §5.1 setup.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Number of users contributing data (paper: 100, non-IID).
    pub users: usize,
    /// Training rounds T (paper: 10).
    pub rounds: u32,
    /// Epochs per training round (paper: 80). Affects energy, not RSN.
    pub epochs_per_round: u32,
    /// Initial shard count S (paper default: 4).
    pub shards: usize,
    /// Device memory budget for sub-model storage, bytes (paper C_m = 2 GB).
    pub memory_bytes: u64,
    /// Unlearning request probability ρ_u (paper default: 0.1).
    pub unlearn_prob: f64,
    /// Shard-controller γ (min shard fraction) and p (decay) — paper: 0.5.
    pub sc_gamma: f64,
    pub sc_p: f64,
    /// Fraction of prunable weights KEPT by RCMP (paper δ=70% pruned → 0.3).
    pub prune_keep: f64,
    /// Service batching: how the unlearning service merges queued requests
    /// (the paper's FCFS baseline, per-window retrain coalescing, or
    /// deadline-aware coalescing under a latency SLO).
    pub batch_policy: BatchPolicy,
    /// Max requests coalesced per drain window (0 = the whole queue).
    pub batch_window: usize,
    /// Latency SLO for `batch_policy = deadline`, service-clock ticks: the
    /// max queueing delay any request may incur before its window closes.
    /// `0` degenerates to FCFS, `u64::MAX` (config value `inf`) to
    /// whole-queue coalescing at flush time. Ignored by other policies.
    pub batch_slo: u64,
    /// How the checkpoint store meters C_m: `slots` (the paper's N_mem
    /// normalization — the default, and what every baseline reproduces) or
    /// `bytes` (admission/eviction reason in each checkpoint's true
    /// encoded size, so pruned checkpoints really pack denser). The
    /// `memory_budget_bytes` config key sets C_m and switches to `bytes`
    /// in one assignment.
    pub store_meter: StoreMeter,
    /// Checkpoint payload codec for tensor-carrying backends: `dense`,
    /// `sparse` (default — bitmask+values when it pays), or `delta`
    /// (additionally diff against the lineage's previous payload). The
    /// accounting backend stores no tensors and ignores this.
    pub codec: CodecMode,
    /// Service durability: `off` (default — byte-identical to the
    /// in-memory service), `log` (write-ahead event log, crash-consistent
    /// recovery of all accounting state), or `log+spill` (additionally
    /// spill checkpoint payload bytes so recovery restores store tensors
    /// bit-exactly).
    pub durability: DurabilityMode,
    /// When the journal reaches the OS: `never` (default — fastest, an
    /// OS crash may lose the page-cache tail), `always` (one fsync
    /// barrier per event), or `group` (group commit: one barrier per
    /// sealed batch window — the amortized middle ground). Config keys:
    /// `fsync = never|always|group`, `fsync_group_commit = true`, or the
    /// `durability = log+fsync` shorthand. Ignored when `durability` is
    /// `off`.
    pub fsync: FsyncPolicy,
    /// Cross-shard log shipping (`ship_to_peer = true`): every fleet
    /// shard streams its sealed WAL frames to an in-process peer replica
    /// so a dead shard can be rebuilt by `failover` with zero
    /// acknowledged obligations lost. Needs `durability != off`; a
    /// 1-worker fleet has no peer and ignores the knob.
    pub ship_to_peer: bool,
    /// Spool directory for file-backed log shipping. When set (and
    /// `ship_to_peer` is on), shards ship over an on-disk
    /// [`FileSpool`](crate::persist::FileSpool) rooted here instead of
    /// the in-process replica store, so shipped frames survive process
    /// death and failover can recover from the spool alone. Empty
    /// string (`ship_spool_dir =`) switches back to in-process.
    pub ship_spool_dir: Option<String>,
    /// Directory for the write-ahead log / snapshots when `durability`
    /// is not `off`.
    pub persist_dir: String,
    /// Auto-compact the event log after this many events accumulate in
    /// the tail (0 = only on explicit `compact_now`).
    pub compact_every: u64,
    /// Shard workers for the fleet service: 1 (default) runs the single
    /// unsharded `UnlearningService` path byte-identically; N > 1 runs N
    /// independent per-shard workers behind a UCDP routing front-end
    /// (each with its own engine, store, battery, and — when durability
    /// is on — its own WAL under `persist_dir/shard-<k>/`).
    pub fleet_workers: usize,
    /// Enable the deterministic span tracer (`obs = true`): every
    /// service/fleet layer records plan→price→admit→retrain→seal→ship
    /// spans into per-shard ring buffers (see [`crate::obs`]). Off by
    /// default; the metrics registry is available regardless.
    pub obs: bool,
    /// Where `run` writes the trace exports (Chrome `trace_event` JSON
    /// + flat JSONL). Setting a non-empty `obs_dir` implies `obs`.
    pub obs_dir: Option<String>,
    pub model: ModelProfile,
    pub dataset: DatasetSpec,
}

/// Parse a `batch_slo` value: a tick count, or `inf`/`max`/`none` for an
/// unbounded SLO (coalesce until an explicit flush).
fn parse_slo(v: &str) -> Result<u64> {
    match v.trim().to_ascii_lowercase().as_str() {
        "inf" | "max" | "none" => Ok(u64::MAX),
        n => Ok(n.parse()?),
    }
}

/// Parse a boolean config value (`true`/`false`, `1`/`0`, `on`/`off`).
fn parse_bool(v: &str) -> Result<bool> {
    match v.trim().to_ascii_lowercase().as_str() {
        "true" | "1" | "on" | "yes" => Ok(true),
        "false" | "0" | "off" | "no" => Ok(false),
        other => bail!("expected a boolean, got '{other}'"),
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            users: 100,
            rounds: 10,
            epochs_per_round: 80,
            shards: 4,
            memory_bytes: 2 * 1024 * 1024 * 1024,
            unlearn_prob: 0.1,
            sc_gamma: 0.5,
            sc_p: 0.5,
            prune_keep: 0.3,
            batch_policy: BatchPolicy::Coalesce,
            batch_window: 0,
            batch_slo: 0,
            store_meter: StoreMeter::Slots,
            codec: CodecMode::Sparse,
            durability: DurabilityMode::Off,
            fsync: FsyncPolicy::Never,
            ship_to_peer: false,
            ship_spool_dir: None,
            persist_dir: "cause_persist".to_string(),
            compact_every: 512,
            fleet_workers: 1,
            obs: false,
            obs_dir: None,
            model: profiles::RESNET34,
            dataset: CIFAR10,
        }
    }
}

impl ExperimentConfig {
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_shards(mut self, s: usize) -> Self {
        self.shards = s;
        self
    }

    pub fn with_memory_gb(mut self, gb: f64) -> Self {
        self.memory_bytes = (gb * 1024.0 * 1024.0 * 1024.0) as u64;
        self
    }

    pub fn with_unlearn_prob(mut self, p: f64) -> Self {
        self.unlearn_prob = p;
        self
    }

    pub fn with_model(mut self, m: ModelProfile) -> Self {
        self.model = m;
        self
    }

    pub fn with_dataset(mut self, d: DatasetSpec) -> Self {
        self.dataset = d;
        self
    }

    pub fn with_batching(mut self, policy: BatchPolicy, window: usize) -> Self {
        self.batch_policy = policy;
        self.batch_window = window;
        self
    }

    /// Switch to the deadline-aware batch policy with this latency SLO
    /// (service-clock ticks).
    pub fn with_slo(mut self, slo_ticks: u64) -> Self {
        self.batch_slo = slo_ticks;
        self.batch_policy = BatchPolicy::Deadline { slo_ticks };
        self
    }

    /// Meter the store in true bytes with this C_m (the
    /// `memory_budget_bytes` config key).
    pub fn with_byte_budget(mut self, bytes: u64) -> Self {
        self.memory_bytes = bytes;
        self.store_meter = StoreMeter::Bytes;
        self
    }

    /// Select the checkpoint payload codec.
    pub fn with_codec(mut self, codec: CodecMode) -> Self {
        self.codec = codec;
        self
    }

    /// Enable service durability (write-ahead log at `persist_dir`).
    pub fn with_durability(mut self, mode: DurabilityMode, dir: impl Into<String>) -> Self {
        self.durability = mode;
        self.persist_dir = dir.into();
        self
    }

    /// Choose when journal writes reach the OS (fsync barriers).
    pub fn with_fsync(mut self, fsync: FsyncPolicy) -> Self {
        self.fsync = fsync;
        self
    }

    /// Stream every fleet shard's sealed WAL frames to a peer replica.
    pub fn with_ship_to_peer(mut self, ship: bool) -> Self {
        self.ship_to_peer = ship;
        self
    }

    /// Ship over a file-backed spool rooted at `dir` (frames survive
    /// process death) instead of the in-process replica store.
    pub fn with_ship_spool_dir(mut self, dir: impl Into<String>) -> Self {
        self.ship_spool_dir = Some(dir.into());
        self
    }

    /// Run the service as a sharded fleet with this many workers.
    pub fn with_fleet_workers(mut self, workers: usize) -> Self {
        self.fleet_workers = workers;
        self
    }

    /// Enable the deterministic span tracer.
    pub fn with_obs(mut self, obs: bool) -> Self {
        self.obs = obs;
        self
    }

    /// Enable tracing and write the exports under `dir`.
    pub fn with_obs_dir(mut self, dir: impl Into<String>) -> Self {
        self.obs_dir = Some(dir.into());
        self.obs = true;
        self
    }

    /// Apply a `key = value` assignment (config file / CLI override).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key.trim() {
            "seed" => self.seed = v.parse()?,
            "users" => self.users = v.parse()?,
            "rounds" => self.rounds = v.parse()?,
            "epochs_per_round" => self.epochs_per_round = v.parse()?,
            "shards" => self.shards = v.parse()?,
            "memory_gb" => {
                self.memory_bytes = (v.parse::<f64>()? * 1024.0 * 1024.0 * 1024.0) as u64
            }
            "unlearn_prob" => self.unlearn_prob = v.parse()?,
            "sc_gamma" => self.sc_gamma = v.parse()?,
            "sc_p" => self.sc_p = v.parse()?,
            "prune_keep" => self.prune_keep = v.parse()?,
            "batch_window" => self.batch_window = v.parse()?,
            "batch_policy" => {
                let policy = BatchPolicy::by_name(v)
                    .ok_or_else(|| anyhow::anyhow!("unknown batch policy '{v}'"))?;
                // `deadline` binds the configured SLO regardless of
                // whether batch_slo was assigned before or after.
                self.batch_policy = match policy {
                    BatchPolicy::Deadline { .. } => {
                        BatchPolicy::Deadline { slo_ticks: self.batch_slo }
                    }
                    other => other,
                };
            }
            "batch_slo" => {
                self.batch_slo = parse_slo(v)?;
                if let BatchPolicy::Deadline { .. } = self.batch_policy {
                    self.batch_policy = BatchPolicy::Deadline { slo_ticks: self.batch_slo };
                }
            }
            "store_mode" | "store_meter" => {
                self.store_meter = StoreMeter::by_name(v)
                    .ok_or_else(|| anyhow::anyhow!("unknown store mode '{v}'"))?
            }
            "memory_budget_bytes" => {
                self.memory_bytes = v.parse()?;
                self.store_meter = StoreMeter::Bytes;
            }
            "codec" => {
                self.codec = CodecMode::by_name(v)
                    .ok_or_else(|| anyhow::anyhow!("unknown codec '{v}'"))?
            }
            "durability" => {
                // `log+fsync` / `log+spill+fsync`: mode with per-event
                // fsync barriers in one assignment.
                let (mode, fsync) = match v.strip_suffix("+fsync") {
                    Some(base) => (base, true),
                    None => (v, false),
                };
                self.durability = DurabilityMode::by_name(mode)
                    .ok_or_else(|| anyhow::anyhow!("unknown durability mode '{v}'"))?;
                if fsync {
                    self.fsync = FsyncPolicy::Always;
                }
            }
            "fsync" => {
                self.fsync = FsyncPolicy::by_name(v)
                    .ok_or_else(|| anyhow::anyhow!("unknown fsync policy '{v}'"))?
            }
            "fsync_group_commit" => {
                if parse_bool(v)? {
                    self.fsync = FsyncPolicy::GroupCommit;
                } else if self.fsync == FsyncPolicy::GroupCommit {
                    self.fsync = FsyncPolicy::Never;
                }
            }
            "ship_to_peer" => self.ship_to_peer = parse_bool(v)?,
            "ship_spool_dir" => {
                self.ship_spool_dir = if v.is_empty() { None } else { Some(v.to_string()) };
            }
            "persist_dir" => {
                if v.is_empty() {
                    bail!("persist_dir must not be empty");
                }
                self.persist_dir = v.to_string();
            }
            "compact_every" => self.compact_every = v.parse()?,
            "fleet_workers" => self.fleet_workers = v.parse()?,
            "obs" => self.obs = parse_bool(v)?,
            "obs_dir" => {
                self.obs_dir = if v.is_empty() { None } else { Some(v.to_string()) };
                if self.obs_dir.is_some() {
                    self.obs = true;
                }
            }
            "model" => {
                self.model = ModelProfile::by_name(v)
                    .ok_or_else(|| anyhow::anyhow!("unknown model '{v}'"))?
            }
            "dataset" => {
                self.dataset = DatasetSpec::by_name(v)
                    .ok_or_else(|| anyhow::anyhow!("unknown dataset '{v}'"))?
                    .clone()
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse a config file: `#` comments, `key = value` lines.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let mut cfg = Self::default();
        let text = std::fs::read_to_string(path)?;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("{}:{}: expected 'key = value'", path.display(), ln + 1);
            };
            cfg.apply(k, v)
                .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), ln + 1))?;
        }
        Ok(cfg)
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        if self.users == 0 || self.rounds == 0 || self.shards == 0 {
            bail!("users/rounds/shards must be positive");
        }
        if !(0.0..=1.0).contains(&self.unlearn_prob)
            || !(0.0..=1.0).contains(&self.sc_gamma)
            || !(0.0..=1.0).contains(&self.prune_keep)
        {
            bail!("probabilities/fractions must be in [0,1]");
        }
        if self.sc_p < 0.0 {
            bail!("sc_p must be >= 0");
        }
        if self.fleet_workers == 0 {
            bail!("fleet_workers must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.users, 100);
        assert_eq!(c.rounds, 10);
        assert_eq!(c.epochs_per_round, 80);
        assert_eq!(c.shards, 4);
        assert_eq!(c.memory_bytes, 2 << 30);
        assert_eq!(c.unlearn_prob, 0.1);
        assert_eq!(c.sc_gamma, 0.5);
        assert_eq!(c.sc_p, 0.5);
        assert!((c.prune_keep - 0.3).abs() < 1e-12);
        assert_eq!(c.batch_policy, BatchPolicy::Coalesce);
        assert_eq!(c.batch_window, 0);
        assert_eq!(c.batch_slo, 0);
        assert_eq!(c.store_meter, StoreMeter::Slots);
        assert_eq!(c.codec, CodecMode::Sparse);
        assert_eq!(c.fleet_workers, 1, "default is the unsharded service");
        c.validate().unwrap();
    }

    #[test]
    fn fleet_workers_knob() {
        let mut c = ExperimentConfig::default();
        c.apply("fleet_workers", "4").unwrap();
        assert_eq!(c.fleet_workers, 4);
        assert!(c.apply("fleet_workers", "many").is_err());
        c.fleet_workers = 0;
        assert!(c.validate().is_err(), "0 workers is no fleet at all");
        let c = ExperimentConfig::default().with_fleet_workers(2);
        assert_eq!(c.fleet_workers, 2);
        c.validate().unwrap();
    }

    #[test]
    fn store_and_codec_knobs() {
        let mut c = ExperimentConfig::default();
        c.apply("store_mode", "bytes").unwrap();
        assert_eq!(c.store_meter, StoreMeter::Bytes);
        c.apply("store_mode", "slots").unwrap();
        assert_eq!(c.store_meter, StoreMeter::Slots);
        // One-assignment byte budget: sets C_m and flips the meter.
        c.apply("memory_budget_bytes", "1048576").unwrap();
        assert_eq!(c.memory_bytes, 1 << 20);
        assert_eq!(c.store_meter, StoreMeter::Bytes);
        c.apply("codec", "delta").unwrap();
        assert_eq!(c.codec, CodecMode::Delta);
        c.apply("codec", "dense").unwrap();
        assert_eq!(c.codec, CodecMode::Dense);
        assert!(c.apply("codec", "gzip").is_err());
        assert!(c.apply("store_mode", "pages").is_err());
        // Builder shorthands.
        let c = ExperimentConfig::default()
            .with_byte_budget(2048)
            .with_codec(CodecMode::Delta);
        assert_eq!(c.memory_bytes, 2048);
        assert_eq!(c.store_meter, StoreMeter::Bytes);
        assert_eq!(c.codec, CodecMode::Delta);
        c.validate().unwrap();
    }

    #[test]
    fn durability_knobs() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.durability, DurabilityMode::Off);
        assert_eq!(c.persist_dir, "cause_persist");
        assert_eq!(c.compact_every, 512);
        c.apply("durability", "log").unwrap();
        assert_eq!(c.durability, DurabilityMode::Log);
        c.apply("durability", "log+spill").unwrap();
        assert_eq!(c.durability, DurabilityMode::LogSpill);
        c.apply("durability", "off").unwrap();
        assert_eq!(c.durability, DurabilityMode::Off);
        assert!(c.apply("durability", "raid5").is_err());
        c.apply("persist_dir", "/tmp/sat-7/wal").unwrap();
        assert_eq!(c.persist_dir, "/tmp/sat-7/wal");
        assert!(c.apply("persist_dir", "").is_err());
        c.apply("compact_every", "64").unwrap();
        assert_eq!(c.compact_every, 64);
        assert!(c.apply("compact_every", "soon").is_err());
        // Builder shorthand.
        let c = ExperimentConfig::default().with_durability(DurabilityMode::Log, "d");
        assert_eq!(c.durability, DurabilityMode::Log);
        assert_eq!(c.persist_dir, "d");
        c.validate().unwrap();
    }

    #[test]
    fn fsync_and_shipping_knobs() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.fsync, FsyncPolicy::Never);
        assert!(!c.ship_to_peer);
        c.apply("fsync", "always").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::Always);
        c.apply("fsync", "group").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::GroupCommit);
        c.apply("fsync", "never").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::Never);
        assert!(c.apply("fsync", "maybe").is_err());
        // Dedicated group-commit toggle.
        c.apply("fsync_group_commit", "true").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::GroupCommit);
        c.apply("fsync_group_commit", "false").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::Never);
        // Turning the toggle off leaves a non-group policy alone.
        c.fsync = FsyncPolicy::Always;
        c.apply("fsync_group_commit", "off").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::Always);
        assert!(c.apply("fsync_group_commit", "sometimes").is_err());
        // `durability = log+fsync` shorthand binds mode + barriers.
        let mut c = ExperimentConfig::default();
        c.apply("durability", "log+fsync").unwrap();
        assert_eq!(c.durability, DurabilityMode::Log);
        assert_eq!(c.fsync, FsyncPolicy::Always);
        c.apply("durability", "log+spill+fsync").unwrap();
        assert_eq!(c.durability, DurabilityMode::LogSpill);
        // Plain re-assignment keeps the explicit fsync policy.
        c.apply("durability", "log").unwrap();
        assert_eq!(c.fsync, FsyncPolicy::Always);
        assert!(c.apply("durability", "chrome+fsync").is_err());
        // Shipping knob.
        c.apply("ship_to_peer", "true").unwrap();
        assert!(c.ship_to_peer);
        c.apply("ship_to_peer", "0").unwrap();
        assert!(!c.ship_to_peer);
        assert!(c.apply("ship_to_peer", "maybe").is_err());
        // File-backed spool directory; empty reverts to in-process.
        assert_eq!(c.ship_spool_dir, None);
        c.apply("ship_spool_dir", "peer_spool").unwrap();
        assert_eq!(c.ship_spool_dir.as_deref(), Some("peer_spool"));
        c.apply("ship_spool_dir", "").unwrap();
        assert_eq!(c.ship_spool_dir, None);
        let c2 = ExperimentConfig::default().with_ship_spool_dir("sp");
        assert_eq!(c2.ship_spool_dir.as_deref(), Some("sp"));
        // Builder shorthands.
        let c = ExperimentConfig::default()
            .with_fsync(FsyncPolicy::GroupCommit)
            .with_ship_to_peer(true);
        assert_eq!(c.fsync, FsyncPolicy::GroupCommit);
        assert!(c.ship_to_peer);
        c.validate().unwrap();
    }

    #[test]
    fn obs_knobs() {
        let mut c = ExperimentConfig::default();
        assert!(!c.obs, "tracing is off by default");
        assert_eq!(c.obs_dir, None);
        c.apply("obs", "true").unwrap();
        assert!(c.obs);
        c.apply("obs", "0").unwrap();
        assert!(!c.obs);
        assert!(c.apply("obs", "maybe").is_err());
        // A non-empty obs_dir implies tracing.
        c.apply("obs_dir", "traces").unwrap();
        assert_eq!(c.obs_dir.as_deref(), Some("traces"));
        assert!(c.obs, "obs_dir implies obs");
        // Clearing the dir keeps the explicit obs flag alone.
        c.apply("obs_dir", "").unwrap();
        assert_eq!(c.obs_dir, None);
        assert!(c.obs);
        // Builder shorthands.
        let c = ExperimentConfig::default().with_obs(true);
        assert!(c.obs);
        let c = ExperimentConfig::default().with_obs_dir("t");
        assert!(c.obs);
        assert_eq!(c.obs_dir.as_deref(), Some("t"));
        c.validate().unwrap();
    }

    #[test]
    fn apply_overrides() {
        let mut c = ExperimentConfig::default();
        c.apply("shards", "16").unwrap();
        c.apply("memory_gb", "0.5").unwrap();
        c.apply("model", "vgg16").unwrap();
        c.apply("dataset", "svhn").unwrap();
        c.apply("batch_policy", "fcfs").unwrap();
        c.apply("batch_window", "32").unwrap();
        assert_eq!(c.shards, 16);
        assert_eq!(c.memory_bytes, 512 * 1024 * 1024);
        assert_eq!(c.model.name, "vgg16");
        assert_eq!(c.dataset.name, "svhn");
        assert_eq!(c.batch_policy, BatchPolicy::Fcfs);
        assert_eq!(c.batch_window, 32);
        assert!(c.apply("batch_policy", "lifo").is_err());
        assert!(c.apply("nope", "1").is_err());
    }

    #[test]
    fn batch_slo_binds_in_either_order() {
        // slo first, then policy.
        let mut c = ExperimentConfig::default();
        c.apply("batch_slo", "5").unwrap();
        c.apply("batch_policy", "deadline").unwrap();
        assert_eq!(c.batch_policy, BatchPolicy::Deadline { slo_ticks: 5 });
        // policy first, then slo.
        let mut c = ExperimentConfig::default();
        c.apply("batch_policy", "deadline").unwrap();
        c.apply("batch_slo", "3").unwrap();
        assert_eq!(c.batch_policy, BatchPolicy::Deadline { slo_ticks: 3 });
        // `inf` = unbounded (coalesce-at-flush degenerate point).
        c.apply("batch_slo", "inf").unwrap();
        assert_eq!(c.batch_policy, BatchPolicy::Deadline { slo_ticks: u64::MAX });
        assert!(c.apply("batch_slo", "soon").is_err());
        // Non-deadline policies leave the knob parked but recorded.
        let mut c = ExperimentConfig::default();
        c.apply("batch_slo", "9").unwrap();
        assert_eq!(c.batch_policy, BatchPolicy::Coalesce);
        assert_eq!(c.batch_slo, 9);
        // Builder shorthand.
        let c = ExperimentConfig::default().with_slo(4);
        assert_eq!(c.batch_policy, BatchPolicy::Deadline { slo_ticks: 4 });
        c.validate().unwrap();
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cause_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.conf");
        std::fs::write(&p, "# test\nshards = 8\nunlearn_prob = 0.3\n").unwrap();
        let c = ExperimentConfig::from_file(&p).unwrap();
        assert_eq!(c.shards, 8);
        assert_eq!(c.unlearn_prob, 0.3);
        std::fs::write(&p, "bogus\n").unwrap();
        assert!(ExperimentConfig::from_file(&p).is_err());
    }

    #[test]
    fn validate_rejects_bad_ranges() {
        let mut c = ExperimentConfig::default();
        c.unlearn_prob = 1.5;
        assert!(c.validate().is_err());
        c = ExperimentConfig::default();
        c.shards = 0;
        assert!(c.validate().is_err());
    }
}
