//! Log-linear latency histogram — full-distribution latency recording
//! for the open-loop load harness.
//!
//! The service's own `queue_delay_summary` keeps every receipt and sorts
//! on demand; fine for a few thousand receipts, wrong for an open-loop
//! harness that may record millions of latencies across shards and wants
//! to merge them without concatenating vectors. This histogram is the
//! standard log-linear design (HdrHistogram's layout, cut down to what
//! the harness needs): each power-of-two octave is split into
//! `2^SUB_BITS` equal-width sub-buckets, so the relative width of any
//! bucket is at most `2^-SUB_BITS` = 12.5% and every quantile estimate
//! is within that of the true value. Values below `2^(SUB_BITS+1)` get
//! exact unit buckets.
//!
//! Two properties the tests pin down (and `tests/load_scenarios.rs`
//! relies on):
//!
//! * **Oracle agreement** — `quantile(q)` equals the upper bound of the
//!   bucket holding the sorted oracle's rank-`ceil(q*n)` element, so the
//!   estimate is never below the true quantile and at most one bucket
//!   width (≤ 12.5% + 1) above it.
//! * **Merge = interleave** — merging per-shard histograms is
//!   bucket-wise addition, so the merged histogram is *identical* (not
//!   just approximately equal) to recording the interleaved stream into
//!   one histogram. This is what makes per-shard recording in the fleet
//!   harness lossless.

use crate::util::Json;

/// Sub-bucket resolution: each octave is split into `2^SUB_BITS` equal
/// sub-buckets, bounding relative bucket width at `2^-SUB_BITS` (12.5%).
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS; // sub-buckets per octave

/// Mergeable log-bucketed histogram of `u64` latencies (ticks).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencyHistogram {
    /// Bucket counts, indexed by [`bucket_of`]; grown on demand.
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
}

/// Bucket index for a value. Values `< 2*SUB` are their own bucket
/// (exact); above that, octave `o = floor(log2 v)` contributes `SUB`
/// buckets of width `2^(o-SUB_BITS)` each.
pub fn bucket_of(v: u64) -> usize {
    if v < 2 * SUB {
        return v as usize;
    }
    let o = 63 - v.leading_zeros() as u64; // o >= SUB_BITS + 1
    let w = (v >> (o - SUB_BITS as u64)) - SUB; // 0..SUB within the octave
    ((o - SUB_BITS as u64) * SUB + SUB + w) as usize
}

/// Inclusive `[lo, hi]` value range of bucket `i` — the inverse of
/// [`bucket_of`]: every `v` with `bucket_of(v) == i` lies in the range,
/// and both endpoints map back to `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < (2 * SUB) as usize {
        return (i as u64, i as u64);
    }
    let k = (i as u64) - SUB;
    let o = k / SUB + SUB_BITS as u64;
    let w = k % SUB;
    let lo = (SUB + w) << (o - SUB_BITS as u64);
    // Width-minus-one first: `lo + 2^(o-SUB_BITS)` overflows u64 for the
    // top octave's last sub-bucket (lo = 15<<60), but `lo + (width - 1)`
    // is at most u64::MAX.
    let hi = lo + ((1u64 << (o - SUB_BITS as u64)) - 1);
    (lo, hi)
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: u64) {
        let b = bucket_of(v);
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    /// Bucket-wise addition — the merged histogram equals recording both
    /// streams (in any interleaving) into one histogram.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket holding the rank-`ceil(q*n)` element
    /// (nearest-rank definition, ranks clamped to `[1, n]`). Never
    /// underestimates the true quantile; overestimates by at most one
    /// bucket width (12.5% relative, +1 absolute).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_bounds(i).1.min(self.max);
            }
        }
        self.max
    }

    /// Decompose into raw parts `(counts, count, sum, max)` for snapshot
    /// encoding; [`from_parts`](LatencyHistogram::from_parts) inverts it
    /// exactly (including trailing-zero buckets, so the rebuilt value is
    /// `==` the original, not just JSON-equal).
    pub fn to_parts(&self) -> (Vec<u64>, u64, u128, u64) {
        (self.counts.clone(), self.count, self.sum, self.max)
    }

    /// Rebuild from [`to_parts`](LatencyHistogram::to_parts) output.
    pub fn from_parts(counts: Vec<u64>, count: u64, sum: u128, max: u64) -> Self {
        LatencyHistogram { counts, count, sum, max }
    }

    /// Deterministic JSON: summary quantiles plus the non-empty buckets
    /// as `[lower_bound, count]` rows (full distribution, mergeable by
    /// re-recording).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| Json::Arr(vec![Json::from(bucket_bounds(i).0), Json::from(*c)]))
            .collect();
        Json::obj()
            .set("count", self.count)
            .set("max", self.max)
            .set("mean", self.mean())
            .set("p50", self.quantile(0.50))
            .set("p90", self.quantile(0.90))
            .set("p99", self.quantile(0.99))
            .set("p999", self.quantile(0.999))
            .set("buckets", Json::Arr(buckets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::testkit::forall;

    /// Nearest-rank oracle on a sorted copy of the raw samples.
    fn oracle_quantile(sorted: &[u64], q: f64) -> u64 {
        let n = sorted.len() as u64;
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    #[test]
    fn bucket_of_and_bounds_are_inverse_on_edges() {
        // Exact unit buckets below 2*SUB, then octave sub-buckets.
        for v in 0..16u64 {
            assert_eq!(bucket_of(v), v as usize, "unit bucket for {v}");
            assert_eq!(bucket_bounds(v as usize), (v, v));
        }
        assert_eq!(bucket_of(16), 16);
        assert_eq!(bucket_bounds(16), (16, 17));
        assert_eq!(bucket_of(17), 16);
        assert_eq!(bucket_of(18), 17);
        // Every bucket's bounds map back to the bucket, and buckets tile
        // the value line with no gaps.
        let mut expect_lo = 0u64;
        for i in 0..200usize {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expect_lo, "bucket {i} starts where {} ended", i.wrapping_sub(1));
            assert!(hi >= lo);
            assert_eq!(bucket_of(lo), i, "lo of bucket {i}");
            assert_eq!(bucket_of(hi), i, "hi of bucket {i}");
            expect_lo = hi + 1;
        }
        // Relative width bound: hi <= lo * (1 + 2^-SUB_BITS) for lo >= SUB.
        for i in (2 * SUB) as usize..300 {
            let (lo, hi) = bucket_bounds(i);
            assert!(
                (hi - lo + 1) * SUB <= lo,
                "bucket {i} [{lo},{hi}] wider than lo/SUB"
            );
        }
        // Huge values don't overflow the index math: u64::MAX lands in
        // the last sub-bucket of the top octave, whose hi is u64::MAX.
        let b = bucket_of(u64::MAX);
        let (lo, hi) = bucket_bounds(b);
        assert_eq!(hi, u64::MAX);
        assert_eq!(bucket_of(lo), b);
    }

    /// Random sample per distribution shape; property checks below.
    fn rand_samples(rng: &mut Rng, size: f64) -> Vec<u64> {
        let n = 1 + (400.0 * size) as usize;
        let shape = rng.range(0, 4);
        (0..n)
            .map(|_| match shape {
                0 => rng.below(10),                              // tiny exact values
                1 => rng.below(100_000),                         // uniform wide
                2 => rng.log_normal(3.0, 2.0).round() as u64,    // heavy tail
                _ => 1u64 << rng.range(0, 40),                   // octave edges
            })
            .collect()
    }

    #[test]
    fn prop_quantiles_match_sorted_oracle_within_bucket_error() {
        forall(
            0x10ad1,
            120,
            rand_samples,
            |samples| {
                let mut h = LatencyHistogram::new();
                for &v in samples {
                    h.record(v);
                }
                let mut sorted = samples.clone();
                sorted.sort_unstable();
                if h.count() != samples.len() as u64 {
                    return Err("count mismatch".into());
                }
                if h.max() != *sorted.last().unwrap() {
                    return Err("max mismatch".into());
                }
                for &q in &[0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
                    let est = h.quantile(q);
                    let truth = oracle_quantile(&sorted, q);
                    // Exact relationship: the estimate is the upper bound
                    // of the oracle value's bucket (clamped to max).
                    let expect = bucket_bounds(bucket_of(truth)).1.min(h.max());
                    if est != expect {
                        return Err(format!(
                            "q={q}: est {est} != bucket-hi {expect} (oracle {truth})"
                        ));
                    }
                    // Derived error bound: never below the truth, at most
                    // one bucket width (12.5% + 1) above it.
                    if est < truth {
                        return Err(format!("q={q}: est {est} below oracle {truth}"));
                    }
                    if est as f64 > truth as f64 * (1.0 + 1.0 / SUB as f64) + 1.0 {
                        return Err(format!(
                            "q={q}: est {est} beyond bucket error bound of oracle {truth}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_sharded_merge_equals_interleaved_recording() {
        forall(
            0x10ad2,
            120,
            |rng, size| {
                let samples = rand_samples(rng, size);
                let shards = 1 + rng.range(0, 4);
                let assign: Vec<usize> =
                    samples.iter().map(|_| rng.range(0, shards)).collect();
                (samples, shards, assign)
            },
            |(samples, shards, assign)| {
                // One histogram over the interleaved stream…
                let mut whole = LatencyHistogram::new();
                for &v in samples {
                    whole.record(v);
                }
                // …vs per-shard histograms merged in shard order.
                let mut per: Vec<LatencyHistogram> =
                    (0..*shards).map(|_| LatencyHistogram::new()).collect();
                for (&v, &s) in samples.iter().zip(assign) {
                    per[s].record(v);
                }
                let mut merged = LatencyHistogram::new();
                for h in &per {
                    merged.merge(h);
                }
                // counts vectors may differ in trailing zeros; the JSON
                // form (non-empty buckets + summary) must be identical.
                if merged.to_json().to_string() != whole.to_json().to_string() {
                    return Err(format!(
                        "merged != interleaved:\n  merged {}\n  whole  {}",
                        merged.to_json(),
                        whole.to_json()
                    ));
                }
                if merged.count() != whole.count() || merged.max() != whole.max() {
                    return Err("merged count/max mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
