//! The scenario corpus: six arrival patterns the north-star's "heavy
//! traffic" claim has to survive, each an energy-bounded open-loop
//! workload with a stable name that doubles as its CI gate key
//! (`load.<name>_rps_at_slo` in `BENCH_baseline.json`).
//!
//! Every scenario runs on an energy-harvesting battery rather than
//! mains. That is deliberate: on mains the accounting engine retrains
//! any window instantly in logical time, so no offered rate could ever
//! saturate the service and throughput-at-SLO would be vacuous. With a
//! battery, each tick harvests a bounded number of joules, a retrain
//! window costs joules proportional to its replay (RSN × epochs), and
//! offered rates above the harvest envelope push work into battery
//! carryover — queueing delay then grows without bound and the SLO
//! check fails deterministically. The battery starts nearly empty
//! (`START_CHARGE_J`, not the cubesat's full 72 kJ) so the measured
//! rate reflects the *sustained* envelope, not a stored-energy subsidy.
//!
//! Calibration (MOBILENETV2 cost model, `epochs_per_round = 4`):
//! one replayed sample costs ≈ 0.0127 J, a full single-lineage replay
//! of a 12 000-sample / 4-shard population ≈ 38 J, and the default
//! harvest of 15 s/tick at the cubesat's 4 W ≈ 60 J/tick — roughly
//! 1.5 cold lineage replays per tick, before checkpoint warm starts.

use crate::config::profiles::MOBILENETV2;
use crate::config::ExperimentConfig;
use crate::data::catalog::CIFAR10;
use crate::data::dataset::UserId;
use crate::data::trace::UnlearnRequest;
use crate::prng::Rng;
use crate::sim::device::AI_CUBESAT;
use crate::sim::Battery;
use crate::util::Json;

use super::{RequestFactory, Scenario, ServiceUnderTest};

/// Initial battery charge for every scenario, joules — ten ticks of
/// default harvest, enough to ride out a burst but not to fund a run.
const START_CHARGE_J: f64 = 600.0;

/// Default harvest per tick, seconds of the cubesat's 4 W panel (60 J).
const HARVEST_SECS: f64 = 15.0;

/// The full corpus, in gate-key order.
pub fn corpus() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(GdprStorm),
        Box::new(DiurnalBurst),
        Box::new(HeavyTail),
        Box::new(SatelliteWindows),
        Box::new(IotFleetChurn),
        Box::new(AdversarialOldest),
    ]
}

/// Shared experiment shape: an edge-sized backbone and a population
/// small enough that determinism tests replay scenarios in seconds.
fn base_cfg(seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        seed,
        users: 40,
        rounds: 5,
        epochs_per_round: 4,
        shards: 4,
        model: MOBILENETV2,
        dataset: CIFAR10.scaled(12_000),
        ..Default::default()
    }
}

fn edge_battery() -> Battery {
    let mut b = Battery::new(&AI_CUBESAT);
    b.charge_j = START_CHARGE_J;
    b
}

/// First user at or after `start` (wrapping) that still owns deletable
/// samples.
fn live_user_from(factory: &RequestFactory, users: usize, start: usize) -> Option<UserId> {
    (0..users)
        .map(|o| UserId(((start + o) % users) as u32))
        .find(|u| factory.user_remaining(*u) > 0)
}

/// Build a request deleting `frac` of up to `max_blocks` of `user`'s
/// live blocks (chosen uniformly without replacement).
fn request_for(
    factory: &mut RequestFactory,
    user: UserId,
    max_blocks: usize,
    frac: f64,
    rng: &mut Rng,
) -> Option<UnlearnRequest> {
    let live = factory.live_user_blocks(user);
    if live.is_empty() {
        return None;
    }
    let k = live.len().min(max_blocks);
    let mut parts = Vec::with_capacity(k);
    for i in rng.choose(live.len(), k) {
        if let Some(part) = factory.take(live[i].0, frac) {
            parts.push(part);
        }
    }
    if parts.is_empty() {
        return None;
    }
    Some(UnlearnRequest {
        round: factory.ingested_rounds(),
        user,
        arrival_tick: 0, // re-stamped by the service on submit
        parts,
    })
}

// ---------------------------------------------------------------------
// 1. GDPR deletion storm
// ---------------------------------------------------------------------

/// One data subject exercises their right to erasure: every request
/// targets the blocks of a single user — the one currently holding the
/// most undeleted samples — across all of that user's training rounds,
/// rotating to the next-heaviest subject once one is scrubbed clean.
pub struct GdprStorm;

impl Scenario for GdprStorm {
    fn name(&self) -> &'static str {
        "gdpr_storm"
    }

    fn description(&self) -> &'static str {
        "single-subject erasure storm: all requests target the heaviest \
         remaining user's blocks across their training rounds"
    }

    fn config(&self) -> ExperimentConfig {
        base_cfg(0xe1)
    }

    fn battery(&self) -> Option<Battery> {
        Some(edge_battery())
    }

    fn harvest_secs(&self, _tick: u64) -> f64 {
        HARVEST_SECS
    }

    fn slo_ticks(&self) -> u64 {
        8
    }

    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest> {
        // The storm's subject: heaviest remaining user (lowest id wins
        // ties), recomputed per request so depletion rotates subjects.
        let users = factory.population().cfg.users;
        let subject = (0..users)
            .map(|u| UserId(u as u32))
            .max_by_key(|u| (factory.user_remaining(*u), std::cmp::Reverse(u.0)))?;
        request_for(factory, subject, 4, 0.3, rng)
    }

    fn knobs(&self) -> Json {
        Json::obj()
            .set("subject", "heaviest remaining user, rotating on depletion")
            .set("blocks_per_request", 4u64)
            .set("frac_per_block", 0.3)
    }
}

// ---------------------------------------------------------------------
// 2. Diurnal burst
// ---------------------------------------------------------------------

/// Uniform per-user requests whose arrival rate swings ±90% over a
/// 24-tick "day" — the service must bank harvest through the trough to
/// survive the peak.
pub struct DiurnalBurst;

impl DiurnalBurst {
    const PERIOD: u64 = 24;
    const SWING: f64 = 0.9;
}

impl Scenario for DiurnalBurst {
    fn name(&self) -> &'static str {
        "diurnal_burst"
    }

    fn description(&self) -> &'static str {
        "uniform user deletions with a sinusoidal day/night arrival \
         swing (±90% around the offered rate)"
    }

    fn config(&self) -> ExperimentConfig {
        base_cfg(0xe2)
    }

    fn battery(&self) -> Option<Battery> {
        Some(edge_battery())
    }

    fn harvest_secs(&self, _tick: u64) -> f64 {
        HARVEST_SECS
    }

    fn intensity(&self, tick: u64) -> f64 {
        let phase = (tick % Self::PERIOD) as f64 / Self::PERIOD as f64;
        1.0 + Self::SWING * (2.0 * std::f64::consts::PI * phase).sin()
    }

    fn slo_ticks(&self) -> u64 {
        8
    }

    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest> {
        let users = factory.population().cfg.users;
        let user = live_user_from(factory, users, rng.range(0, users))?;
        request_for(factory, user, 1, 0.2, rng)
    }

    fn knobs(&self) -> Json {
        Json::obj()
            .set("period_ticks", Self::PERIOD)
            .set("swing", Self::SWING)
            .set("frac_per_block", 0.2)
    }
}

// ---------------------------------------------------------------------
// 3. Heavy-tail per-user skew
// ---------------------------------------------------------------------

/// Zipf-like request skew: a handful of users file most deletion
/// requests (rank drawn as `users * U^alpha`), so a few lineages retrain
/// over and over while the rest idle.
pub struct HeavyTail;

impl HeavyTail {
    const ALPHA: f64 = 3.0;
}

impl Scenario for HeavyTail {
    fn name(&self) -> &'static str {
        "heavy_tail"
    }

    fn description(&self) -> &'static str {
        "zipf-skewed requesters: a few users file most deletions, \
         concentrating retrains on their lineages"
    }

    fn config(&self) -> ExperimentConfig {
        base_cfg(0xe3)
    }

    fn battery(&self) -> Option<Battery> {
        Some(edge_battery())
    }

    fn harvest_secs(&self, _tick: u64) -> f64 {
        HARVEST_SECS
    }

    fn slo_ticks(&self) -> u64 {
        8
    }

    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest> {
        let users = factory.population().cfg.users;
        // rank 0 is ~alpha times likelier than the median rank.
        let rank = ((users as f64) * rng.f64().powf(Self::ALPHA)) as usize;
        let user = live_user_from(factory, users, rank.min(users - 1))?;
        request_for(factory, user, 2, 0.25, rng)
    }

    fn knobs(&self) -> Json {
        Json::obj()
            .set("alpha", Self::ALPHA)
            .set("blocks_per_request", 2u64)
            .set("frac_per_block", 0.25)
    }
}

// ---------------------------------------------------------------------
// 4. Satellite contact windows
// ---------------------------------------------------------------------

/// The satellite example (`examples/satellite_energy.rs`) promoted into
/// the corpus: solar harvest only lands during the sunlit fraction of a
/// 16-tick orbit, and the service runs the deadline-aware planner so
/// windows close against a contact SLO instead of every tick.
pub struct SatelliteWindows;

impl SatelliteWindows {
    const ORBIT_TICKS: u64 = 16;
    const SUNLIT_TICKS: u64 = 6;
    /// 40 s × 4 W × 6 sunlit ticks = 960 J per orbit ≈ 60 J/tick mean.
    const SUNLIT_HARVEST_SECS: f64 = 40.0;
    const CONTACT_SLO: u64 = 4;
}

impl Scenario for SatelliteWindows {
    fn name(&self) -> &'static str {
        "satellite_windows"
    }

    fn description(&self) -> &'static str {
        "orbit-gated harvest with a deadline planner: energy arrives \
         only in the sunlit arc, windows close at the contact SLO"
    }

    fn config(&self) -> ExperimentConfig {
        base_cfg(0xe4).with_slo(Self::CONTACT_SLO)
    }

    fn battery(&self) -> Option<Battery> {
        Some(edge_battery())
    }

    fn harvest_secs(&self, tick: u64) -> f64 {
        if tick % Self::ORBIT_TICKS < Self::SUNLIT_TICKS {
            Self::SUNLIT_HARVEST_SECS
        } else {
            0.0
        }
    }

    fn slo_ticks(&self) -> u64 {
        Self::ORBIT_TICKS
    }

    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest> {
        let users = factory.population().cfg.users;
        let user = live_user_from(factory, users, rng.range(0, users))?;
        request_for(factory, user, 2, 0.3, rng)
    }

    fn knobs(&self) -> Json {
        Json::obj()
            .set("orbit_ticks", Self::ORBIT_TICKS)
            .set("sunlit_ticks", Self::SUNLIT_TICKS)
            .set("sunlit_harvest_secs", Self::SUNLIT_HARVEST_SECS)
            .set("contact_slo", Self::CONTACT_SLO)
    }
}

// ---------------------------------------------------------------------
// 5. IoT fleet churn
// ---------------------------------------------------------------------

/// A two-worker fleet whose active shard set shrinks and re-grows every
/// 8 ticks (device churn) while harvest duty-cycles between strong and
/// weak — the routed fleet under both membership and energy churn.
pub struct IotFleetChurn;

impl IotFleetChurn {
    const WORKERS: usize = 2;
    const CHURN_TICKS: u64 = 8;
    const DUTY_TICKS: u64 = 6;
    const STRONG_SECS: f64 = 24.0;
    const WEAK_SECS: f64 = 6.0;
}

impl Scenario for IotFleetChurn {
    fn name(&self) -> &'static str {
        "iot_fleet_churn"
    }

    fn description(&self) -> &'static str {
        "two-worker routed fleet: active shards shrink/regrow on a churn \
         cycle while per-device harvest duty-cycles strong/weak"
    }

    fn config(&self) -> ExperimentConfig {
        let mut cfg = base_cfg(0xe5);
        cfg.fleet_workers = Self::WORKERS;
        cfg
    }

    fn battery(&self) -> Option<Battery> {
        Some(edge_battery())
    }

    fn harvest_secs(&self, tick: u64) -> f64 {
        if (tick / Self::DUTY_TICKS) % 2 == 0 {
            Self::STRONG_SECS
        } else {
            Self::WEAK_SECS
        }
    }

    fn slo_ticks(&self) -> u64 {
        8
    }

    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest> {
        let users = factory.population().cfg.users;
        let user = live_user_from(factory, users, rng.range(0, users))?;
        request_for(factory, user, 1, 0.25, rng)
    }

    fn on_tick(&self, tick: u64, svc: &mut ServiceUnderTest) {
        // Churn: drop to one active shard for every other cycle; new
        // users re-home, existing users stay sticky (routing epoch).
        let shrunk = (tick / Self::CHURN_TICKS) % 2 == 1;
        let shards = if shrunk { 1 } else { Self::WORKERS };
        svc.set_active_shards(shards);
    }

    fn knobs(&self) -> Json {
        Json::obj()
            .set("fleet_workers", Self::WORKERS)
            .set("churn_ticks", Self::CHURN_TICKS)
            .set("duty_ticks", Self::DUTY_TICKS)
            .set("harvest_secs_strong", Self::STRONG_SECS)
            .set("harvest_secs_weak", Self::WEAK_SECS)
    }
}

// ---------------------------------------------------------------------
// 6. Adversarial oldest-segment targeting
// ---------------------------------------------------------------------

/// Worst-case replay amplification: every request deletes from the
/// owner of the *oldest* still-live block, hitting that user's oldest
/// blocks — each window invalidates the longest possible lineage suffix
/// and forces maximal retraining per sample deleted.
pub struct AdversarialOldest;

impl Scenario for AdversarialOldest {
    fn name(&self) -> &'static str {
        "adversarial_oldest"
    }

    fn description(&self) -> &'static str {
        "replay-maximizing adversary: always deletes from the oldest \
         live block's owner, oldest blocks first"
    }

    fn config(&self) -> ExperimentConfig {
        base_cfg(0xe6)
    }

    fn battery(&self) -> Option<Battery> {
        Some(edge_battery())
    }

    fn harvest_secs(&self, _tick: u64) -> f64 {
        HARVEST_SECS
    }

    fn slo_ticks(&self) -> u64 {
        8
    }

    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest> {
        let target = factory.oldest_live_block()?;
        let (user, round) = (target.user, factory.ingested_rounds());
        // Oldest-first: take the user's live blocks in round order, not
        // at random — the whole point is suffix invalidation depth.
        let live = factory.live_user_blocks(user);
        let k = live.len().min(3);
        let mut parts = Vec::with_capacity(k);
        for (id, _) in live.into_iter().take(k) {
            if let Some(part) = factory.take(id, 0.5) {
                parts.push(part);
            }
        }
        // rng keeps the per-request stream aligned with other scenarios'
        // draw discipline (one decision per request) without changing
        // the deterministic target choice.
        let _ = rng.next_u64();
        if parts.is_empty() {
            return None;
        }
        Some(UnlearnRequest { round, user, arrival_tick: 0, parts })
    }

    fn knobs(&self) -> Json {
        Json::obj()
            .set("target", "owner of the globally oldest live block")
            .set("blocks_per_request", 3u64)
            .set("frac_per_block", 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::load::{run_open_loop, OpenLoopCfg};

    #[test]
    fn corpus_names_are_stable_gate_keys() {
        let names: Vec<&str> = corpus().iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec![
                "gdpr_storm",
                "diurnal_burst",
                "heavy_tail",
                "satellite_windows",
                "iot_fleet_churn",
                "adversarial_oldest"
            ]
        );
        // Names are kebab-free identifiers usable as JSON gate keys.
        for n in names {
            assert!(n.chars().all(|c| c.is_ascii_lowercase() || c == '_'));
        }
    }

    #[test]
    fn every_scenario_serves_a_light_open_loop_run() {
        // Light smoke at a rate comfortably under every scenario's
        // harvest envelope: all requests must be served within the tail
        // and the trace digest must be non-trivial.
        let run = OpenLoopCfg {
            offered_per_tick: 0.5,
            ticks: 12,
            tail_ticks: 64,
            seed: 0x5afe,
            obs: false,
        };
        for sc in corpus() {
            let rep = run_open_loop(sc.as_ref(), &run).expect(sc.name());
            assert!(rep.submitted > 0, "{}: no arrivals", sc.name());
            assert_eq!(rep.unserved, 0, "{}: unserved at light load", sc.name());
            assert_eq!(rep.served, rep.hist.count(), "{}: hist/served", sc.name());
            assert_ne!(rep.trace_digest, 0, "{}", sc.name());
        }
    }
}
