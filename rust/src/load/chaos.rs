//! Chaos soak harness: any corpus [`Scenario`] driven open-loop over a
//! durable, log-shipping fleet while a seeded [`ChaosPlan`] injects the
//! faults the durability story claims to survive — worker kills with
//! failover, transport drop/dup/stale bursts, injected fsync failures,
//! battery collapse, and full crash-restart-recover cycles — and a
//! continuous invariant checker audits the run at every barrier:
//!
//! * **Ledger conservation** — every submitted obligation is eventually
//!   served; nothing acknowledged is lost across any fault.
//! * **Receipt-stream monotonicity** — each shard's journal sequence
//!   never regresses across kills, failovers, or restarts.
//! * **Watermark progress** — after every barrier, each shard's shipped
//!   watermark has caught its log head (nothing stuck in backoff).
//! * **Replica byte-convergence** — after every barrier, the peer's
//!   [`Replica`] equals the source journal's durable state byte for
//!   byte, and stays bounded by the source's live (post-compaction)
//!   WAL: `replica.bytes() <= 2 * live_bytes` (replica-side compaction
//!   via `ShipReset` deltas is what makes this hold).
//! * **Recovery receipt-identity** — every kill+failover and every
//!   crash-restart lands back on the exact pre-fault logical receipt
//!   (the `shards` digest; physical counters are allowed to reset).
//!
//! Faults are applied at *barrier points*: before a kill or restart the
//! harness seals, converges shipping, and snapshots the logical receipt,
//! so the loss window is provably empty and any divergence is a real
//! durability bug rather than harness bookkeeping. Everything is
//! deterministic — seeded [`Rng`], logical ticks, a [`FaultDial`] that
//! scales transport fault rates without perturbing the RNG draw
//! schedule — so a failing `(scenario, seed)` pair replays exactly.

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::system::SystemVariant;
use crate::data::dataset::EdgePopulation;
use crate::fleet::FleetService;
use crate::persist::{
    Durability, DurabilityMode, FileSpool, FsyncPolicy, MemFs, Replica, ReplicaSource,
};
use crate::prng::Rng;
use crate::sim::Battery;
use crate::testkit::{FailpointFs, FailpointTransport, FaultDial};
use crate::util::Json;

use super::{fnv_fold, ArrivalSchedule, Scenario, ServiceUnderTest, FNV_OFFSET};

/// Transport fault rates during a burst (the [`FaultDial`] scales them
/// to zero outside bursts and during barriers).
const DROP_P: f64 = 0.45;
const DUP_P: f64 = 0.3;
const STALE_P: f64 = 0.25;

/// Flush opportunities a barrier grants shipping before declaring it
/// stuck (backoff skips make one flush ≠ one attempt).
const BARRIER_SPINS: u32 = 10_000;

/// A negligible harvest used to journal the battery's post-state after a
/// swap ([`with_battery`](FleetService::with_battery) itself is not an
/// event, so without this anchor a crash-restart would replay the
/// pre-swap charge).
const ANCHOR_SECS: f64 = 1e-9;

// ---------------------------------------------------------------------
// Fault model
// ---------------------------------------------------------------------

/// The five fault classes the soak mixes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// Kill one worker at a converged barrier, fail over onto its peer
    /// replica, and require receipt-identity.
    KillFailover,
    /// Open the transport fault dial (drops, duplicates, stale
    /// re-deliveries) for `duration` ticks, then require shipping to
    /// re-converge.
    TransportBurst,
    /// Inject one fsync failure into a shard's journal filesystem and
    /// require the poisoning to surface through the fleet barrier, then
    /// recover the shard by failover.
    FsyncFailure,
    /// Swap in a fully drained battery for `duration` ticks (windows
    /// park in carryover), then restore the scenario's template.
    BatteryCollapse,
    /// Drop the whole fleet, lose every unsynced byte on every shard
    /// disk, rebuild from the surviving images, and require
    /// receipt-identity.
    CrashRestart,
}

impl FaultClass {
    pub const ALL: [FaultClass; 5] = [
        FaultClass::KillFailover,
        FaultClass::TransportBurst,
        FaultClass::FsyncFailure,
        FaultClass::BatteryCollapse,
        FaultClass::CrashRestart,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FaultClass::KillFailover => "kill_failover",
            FaultClass::TransportBurst => "transport_burst",
            FaultClass::FsyncFailure => "fsync_failure",
            FaultClass::BatteryCollapse => "battery_collapse",
            FaultClass::CrashRestart => "crash_restart",
        }
    }
}

/// One scheduled fault. `shard` is a raw slot index, reduced modulo the
/// fleet's worker count at apply time so one plan fits any fleet shape.
#[derive(Clone, Copy, Debug)]
pub struct Fault {
    pub tick: u64,
    pub class: FaultClass,
    pub shard: usize,
    /// Ticks a burst or collapse stays open (unused by point faults).
    pub duration: u64,
}

/// A seeded fault schedule over one run's arrival ticks.
#[derive(Clone, Debug)]
pub struct ChaosPlan {
    pub seed: u64,
    /// Faults in tick order, at most one per tick.
    pub faults: Vec<Fault>,
}

impl ChaosPlan {
    /// Schedule `max(1, ticks/32)` occurrences of every class in
    /// `classes` on distinct ticks of `[max(2, ticks/6), ticks)` —
    /// faults land only after some traffic exists. Deterministic in
    /// `(seed, ticks, classes)`.
    pub fn seeded(seed: u64, ticks: u64, classes: &[FaultClass]) -> ChaosPlan {
        let mut rng = Rng::new(seed ^ 0xc4a0_5eed);
        let start = (ticks / 6).max(2).min(ticks.saturating_sub(1));
        let span = ticks.saturating_sub(start).max(1);
        let per = (ticks / 32).max(1);
        let mut used = BTreeSet::new();
        let mut faults = Vec::new();
        for class in classes {
            for _ in 0..per {
                let mut tick = start + rng.below(span);
                let mut tries = 0;
                while used.contains(&tick) && tries < 64 {
                    tick = start + rng.below(span);
                    tries += 1;
                }
                if used.contains(&tick) {
                    continue; // schedule saturated; keep what fits
                }
                used.insert(tick);
                faults.push(Fault {
                    tick,
                    class: *class,
                    shard: rng.below(64) as usize,
                    duration: 2 + rng.below(3),
                });
            }
        }
        faults.sort_by_key(|f| f.tick);
        ChaosPlan { seed, faults }
    }
}

// ---------------------------------------------------------------------
// Run shape and report
// ---------------------------------------------------------------------

/// Shape of one chaos soak run (everything but the scenario and plan).
#[derive(Clone, Copy, Debug)]
pub struct ChaosCfg {
    /// Offered arrival rate, requests per tick (kept comfortably under
    /// every scenario's harvest envelope so "everything drains" stays an
    /// invariant rather than a saturation question).
    pub offered_per_tick: f64,
    /// Ticks of open-loop arrivals (fault schedule spans these).
    pub ticks: u64,
    /// Max extra ticks to finish queued and battery-parked work.
    pub tail_ticks: u64,
    /// Seed for request selection (the plan carries its own).
    pub seed: u64,
    /// Barrier + invariant-check cadence, in ticks.
    pub check_every: u64,
    /// Journal auto-compaction cadence (events), kept small so
    /// replica-side compaction is exercised mid-run.
    pub compact_every: u64,
    /// Ship over the file-backed [`FileSpool`] (frames survive process
    /// death on the peer's disk) instead of the in-process store.
    pub spool: bool,
    /// Trace the run: fault markers, spans, and a Chrome-trace export in
    /// the report. A crash-restart drops the dead fleet's in-memory
    /// spans — the trace covers the surviving processes, the receipts
    /// cover everything.
    pub obs: bool,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        ChaosCfg {
            offered_per_tick: 0.5,
            ticks: 48,
            tail_ticks: 256,
            seed: 0xc4a05,
            check_every: 8,
            compact_every: 12,
            spool: false,
            obs: false,
        }
    }
}

/// One applied fault, for the report.
#[derive(Clone, Debug)]
pub struct FaultRecord {
    pub tick: u64,
    pub class: &'static str,
    pub shard: usize,
    pub duration: u64,
}

/// Everything one chaos run produced; `ok()` is the soak verdict.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub scenario: String,
    pub seed: u64,
    pub spool: bool,
    pub ticks: u64,
    pub tail_used: u64,
    pub submitted: u64,
    pub served: u64,
    pub exhausted: bool,
    /// Barriers run (each one = a full invariant sweep).
    pub barriers: u64,
    pub failovers: u64,
    pub restarts: u64,
    pub faults: Vec<FaultRecord>,
    /// Invariant violations, in discovery order. Empty = clean soak.
    pub violations: Vec<String>,
    /// Final per-shard peer-replica payload bytes (post-compaction).
    pub replica_bytes: Vec<u64>,
    /// Final per-shard source live WAL + snapshot bytes.
    pub live_bytes: Vec<u64>,
    /// Fleet-merged durability/ship/latency counters (registry excerpt).
    pub telemetry: Json,
    /// Chrome-trace export when [`ChaosCfg::obs`] is set. Kept out of
    /// `to_json` — callers write it as its own artifact rather than
    /// embedding thousands of span events in the verdict report.
    pub trace: Option<Json>,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("seed", format!("{:#x}", self.seed))
            .set("spool", self.spool)
            .set("ticks", self.ticks)
            .set("tail_used", self.tail_used)
            .set("submitted", self.submitted)
            .set("served", self.served)
            .set("exhausted", self.exhausted)
            .set("barriers", self.barriers)
            .set("failovers", self.failovers)
            .set("restarts", self.restarts)
            .set(
                "faults",
                Json::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            Json::obj()
                                .set("tick", f.tick)
                                .set("class", f.class)
                                .set("shard", f.shard)
                                .set("duration", f.duration)
                        })
                        .collect(),
                ),
            )
            .set(
                "violations",
                Json::Arr(self.violations.iter().map(|v| Json::Str(v.clone())).collect()),
            )
            .set("replica_bytes", self.replica_bytes.clone())
            .set("live_bytes", self.live_bytes.clone())
            .set("telemetry", self.telemetry.clone())
            .set("ok", self.ok())
    }
}

// ---------------------------------------------------------------------
// Spool-backed replica source
// ---------------------------------------------------------------------

/// Failover source that **reopens** the spool from its backing store on
/// every read — recovery sees exactly what a fresh process would find on
/// the peer's disk, never an in-memory copy.
struct SpoolReopen {
    fs: MemFs,
}

impl ReplicaSource for SpoolReopen {
    fn replica(&self, source: usize) -> Option<Replica> {
        FileSpool::open(Box::new(self.fs.clone())).replica(source)
    }
}

// ---------------------------------------------------------------------
// The harness
// ---------------------------------------------------------------------

struct ChaosRun {
    cfg: ChaosCfg,
    ecfg: crate::config::ExperimentConfig,
    battery: Option<Battery>,
    fleet: Option<FleetService>,
    /// Per-shard surviving disks (what a crash cannot take).
    disks: Vec<MemFs>,
    /// Per-shard failpoint wrappers over `disks` (fsync faults, crash
    /// truncation); rebuilt at failover/restart so injection keeps
    /// reaching replacement shards.
    fps: Vec<FailpointFs>,
    dial: FaultDial,
    /// Backing store of the file spool (spool mode only).
    spool_fs: Option<MemFs>,
    /// Where the invariant checker reads peer replicas from.
    rsource: Option<Arc<dyn ReplicaSource>>,
    /// Per-shard journal-sequence high-water marks (monotonicity).
    last_log_seq: Vec<u64>,
    burst_left: u64,
    collapse_left: u64,
}

impl ChaosRun {
    fn new(scenario: &dyn Scenario, cfg: ChaosCfg) -> ChaosRun {
        let mut ecfg = scenario.config();
        // Kills and failovers need peers; chaos always runs a real fleet.
        ecfg.fleet_workers = ecfg.fleet_workers.max(2);
        if cfg.obs {
            ecfg.obs = true;
        }
        // The harness owns durability (failpoint-wrapped journals).
        ecfg.durability = DurabilityMode::Off;
        ChaosRun {
            cfg,
            ecfg,
            battery: scenario.battery(),
            fleet: None,
            disks: Vec::new(),
            fps: Vec::new(),
            dial: FaultDial::new(0.0),
            spool_fs: None,
            rsource: None,
            last_log_seq: Vec::new(),
            burst_left: 0,
            collapse_left: 0,
        }
    }

    fn fleet(&mut self) -> &mut FleetService {
        self.fleet.as_mut().expect("fleet alive")
    }

    fn workers(&self) -> usize {
        self.ecfg.fleet_workers
    }

    /// Current transport fault scale (barriers force 0.0 temporarily).
    fn scale(&self) -> f64 {
        if self.burst_left > 0 {
            1.0
        } else {
            0.0
        }
    }

    /// Build (or rebuild, after a crash) the fleet: battery template,
    /// failpoint-wrapped volatile journals over `disks`, shipping with
    /// dial-scaled fault transports.
    fn build(&mut self, fresh_disks: bool) -> Result<()> {
        let mut fleet = SystemVariant::Cause.build_fleet(&self.ecfg)?;
        if let Some(b) = &self.battery {
            fleet = fleet.with_battery(b.clone());
        }
        let n = fleet.workers();
        if fresh_disks {
            self.disks = (0..n).map(|_| MemFs::new()).collect();
        }
        self.fps = self
            .disks
            .iter()
            .map(|d| {
                let fp = FailpointFs::new(d.clone());
                fp.enable_volatile();
                fp
            })
            .collect();
        let ds = self
            .fps
            .iter()
            .map(|fp| Durability {
                mode: DurabilityMode::Log,
                fs: Box::new(fp.clone()),
                compact_every: self.cfg.compact_every,
                fsync: FsyncPolicy::GroupCommit,
            })
            .collect();
        fleet.attach_durability(ds).context("chaos: attach durability")?;
        self.enable_shipping(&mut fleet)?;
        self.fleet = Some(fleet);
        Ok(())
    }

    fn enable_shipping(&mut self, fleet: &mut FleetService) -> Result<()> {
        let seed = self.cfg.seed;
        let dial = self.dial.clone();
        if self.cfg.spool {
            let fs = self.spool_fs.get_or_insert_with(MemFs::new).clone();
            let spool = FileSpool::open(Box::new(fs.clone()));
            fleet.enable_log_shipping_custom(
                Arc::new(SpoolReopen { fs: fs.clone() }),
                move |k| {
                    Box::new(
                        FailpointTransport::new(
                            Box::new(spool.clone()),
                            seed ^ 0xf11e ^ k as u64,
                            DROP_P,
                            DUP_P,
                            STALE_P,
                        )
                        .with_dial(dial.clone()),
                    )
                },
            )?;
            self.rsource = Some(Arc::new(SpoolReopen { fs }));
        } else {
            let store = fleet.enable_log_shipping_with(move |k, store| {
                Box::new(
                    FailpointTransport::new(
                        Box::new(store),
                        seed ^ 0xf11e ^ k as u64,
                        DROP_P,
                        DUP_P,
                        STALE_P,
                    )
                    .with_dial(dial.clone()),
                )
            })?;
            self.rsource = Some(Arc::new(store));
        }
        Ok(())
    }

    /// The logical fleet digest: the `shards` sub-document only, so
    /// physical counters (shipping attempts, fsync totals, routing
    /// epoch) may reset across recovery without tripping identity.
    fn shards_digest(&mut self) -> Result<String> {
        let receipt = self.fleet().state_receipt()?;
        Ok(receipt
            .at(&["shards"])
            .map(ToString::to_string)
            .unwrap_or_else(|| receipt.to_string()))
    }

    /// Seal + converge shipping with faults dialed off, then sweep every
    /// invariant: watermark progress, sequence monotonicity, replica
    /// byte-convergence, and the bounded-replica property.
    fn barrier(&mut self, report: &mut ChaosReport, whence: &str) -> Result<()> {
        report.barriers += 1;
        self.dial.set(0.0);
        let mut spins = 0u32;
        loop {
            self.fleet().sync_journals().with_context(|| format!("barrier at {whence}"))?;
            let states = self.fleet().shipping_states()?;
            let mut done = true;
            for (k, (r, log_seq)) in states.iter().enumerate() {
                let r = r
                    .as_ref()
                    .ok_or_else(|| anyhow!("chaos: shipping off on shard {k}"))?;
                if let Some(f) = &r.failed {
                    report.violations.push(format!(
                        "{whence}: shard {k} shipping failed terminally: {f}"
                    ));
                    self.dial.set(self.scale());
                    return Ok(());
                }
                if r.pending != 0 || r.shipped_seq != *log_seq {
                    done = false;
                }
            }
            if done {
                break;
            }
            spins += 1;
            if spins > BARRIER_SPINS {
                report.violations.push(format!(
                    "{whence}: shipping failed to converge within {BARRIER_SPINS} flushes"
                ));
                self.dial.set(self.scale());
                return Ok(());
            }
        }

        let stats = self.fleet().journal_stats()?;
        let images = self.fleet().journal_images()?;
        let source = self.rsource.clone().expect("shipping enabled");
        report.replica_bytes.clear();
        report.live_bytes.clear();
        for k in 0..self.workers() {
            let Some(st) = stats[k] else {
                report.violations.push(format!("{whence}: shard {k} lost its journal"));
                continue;
            };
            if st.next_seq < self.last_log_seq[k] {
                report.violations.push(format!(
                    "{whence}: shard {k} journal regressed: seq {} < {}",
                    st.next_seq, self.last_log_seq[k]
                ));
            }
            self.last_log_seq[k] = self.last_log_seq[k].max(st.next_seq);
            let img = images[k].clone().unwrap_or_default();
            let replica = source.replica(k).unwrap_or_default();
            if replica != img {
                report.violations.push(format!(
                    "{whence}: shard {k} replica diverged from source durable state \
                     (replica base {} / {} frames vs source base {} / {} frames)",
                    replica.base_seq,
                    replica.frames.len(),
                    img.base_seq,
                    img.frames.len()
                ));
            }
            let live = st.live_bytes();
            if replica.bytes() > 2 * live.max(1) {
                report.violations.push(format!(
                    "{whence}: shard {k} replica unbounded: {} bytes vs live {}",
                    replica.bytes(),
                    live
                ));
            }
            report.replica_bytes.push(replica.bytes());
            report.live_bytes.push(live);
        }
        self.dial.set(self.scale());
        Ok(())
    }

    /// Fail shard `k` over onto its replica, re-wrapping the replacement
    /// disk in a fresh tracked failpoint filesystem.
    fn failover_fresh(&mut self, k: usize) -> Result<()> {
        let mut newfp = None;
        self.fleet
            .as_mut()
            .expect("fleet alive")
            .failover_wrapped(k, |fs| {
                let fp = FailpointFs::new(fs);
                fp.enable_volatile();
                newfp = Some(fp.clone());
                Box::new(fp)
            })?;
        let fp = newfp.expect("failover ran the wrap");
        self.disks[k] = fp.inner().clone();
        self.fps[k] = fp;
        Ok(())
    }

    fn swap_battery(&mut self, b: Battery) {
        let fleet = self.fleet.take().expect("fleet alive");
        self.fleet = Some(fleet.with_battery(b));
        // Journal the post-swap state so a later crash-restart replays
        // the swapped battery, not the pre-swap charge.
        self.fleet().harvest(ANCHOR_SECS);
    }

    fn apply(
        &mut self,
        fault: &Fault,
        report: &mut ChaosReport,
        pop: &EdgePopulation,
    ) -> Result<()> {
        let k = fault.shard % self.workers();
        let whence = format!("tick {} {}", fault.tick, fault.class.name());
        // Stamp the fault class into the front-end trace lane so the
        // Chrome view lines faults up against the spans they perturb.
        self.fleet().obs_marker(fault.class.name());
        report.faults.push(FaultRecord {
            tick: fault.tick,
            class: fault.class.name(),
            shard: k,
            duration: fault.duration,
        });
        match fault.class {
            FaultClass::KillFailover => {
                self.barrier(report, &whence)?;
                let pre = self.shards_digest()?;
                self.fleet().kill_worker(k)?;
                self.failover_fresh(k)?;
                report.failovers += 1;
                let post = self.shards_digest()?;
                if pre != post {
                    report.violations.push(format!(
                        "{whence}: failover changed the fleet's logical state"
                    ));
                }
            }
            FaultClass::TransportBurst => {
                self.burst_left = self.burst_left.max(fault.duration);
                self.dial.set(1.0);
            }
            FaultClass::FsyncFailure => {
                self.barrier(report, &whence)?;
                self.fps[k].fail_next_syncs(1);
                // Dirty every journal (a zero-tick Advance event, no
                // logical state change) so the next barrier definitely
                // issues the sync that fails.
                self.fleet().advance(0);
                if self.fleet().sync_journals().is_ok() {
                    report.violations.push(format!(
                        "{whence}: injected fsync failure did not poison shard {k}"
                    ));
                } else {
                    // The shard is poisoned; the only rolled-back event
                    // is the unacknowledged harvest anchor. Recover it.
                    self.fleet().kill_worker(k)?;
                    self.failover_fresh(k)?;
                    report.failovers += 1;
                }
            }
            FaultClass::BatteryCollapse => {
                let Some(template) = self.battery.clone() else {
                    return Ok(()); // mains-powered scenario: nothing to collapse
                };
                let mut dead = template;
                dead.charge_j = 0.0;
                self.swap_battery(dead);
                self.collapse_left = self.collapse_left.max(fault.duration);
            }
            FaultClass::CrashRestart => {
                self.barrier(report, &whence)?;
                let pre = self.shards_digest()?;
                drop(self.fleet.take()); // joins every worker
                for fp in &self.fps {
                    fp.crash_lose_unsynced();
                }
                self.build(false)?;
                // The front-end router is in-memory only; replay the
                // preload routing touches so recovered users keep their
                // sticky shard assignments.
                self.fleet().warm_routes(pop, pop.rounds());
                report.restarts += 1;
                let post = self.shards_digest()?;
                if pre != post {
                    report.violations.push(format!(
                        "{whence}: crash-restart recovery diverged from the pre-crash receipt"
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Run one scenario open-loop under a chaos plan. See the module docs
/// for the invariant set; the returned report's `ok()` is the verdict.
pub fn run_chaos(
    scenario: &dyn Scenario,
    plan: &ChaosPlan,
    cfg: &ChaosCfg,
) -> Result<ChaosReport> {
    let mut run = ChaosRun::new(scenario, *cfg);
    let mut report = ChaosReport {
        scenario: scenario.name().to_string(),
        seed: plan.seed,
        spool: cfg.spool,
        ticks: cfg.ticks,
        tail_used: 0,
        submitted: 0,
        served: 0,
        exhausted: false,
        barriers: 0,
        failovers: 0,
        restarts: 0,
        faults: Vec::new(),
        violations: Vec::new(),
        replica_bytes: Vec::new(),
        live_bytes: Vec::new(),
        telemetry: Json::obj(),
        trace: None,
    };
    run.build(true)?;
    run.last_log_seq = vec![0; run.workers()];
    let pop = scenario.population(&run.ecfg);

    // Preload every training round (journaled; recovery replays them).
    let mut factory = super::RequestFactory::new(&pop);
    for _ in 0..pop.rounds() {
        run.fleet().ingest_round(&pop)?;
        factory.ingest_round();
    }

    let mut rng = Rng::new(fnv_fold(cfg.seed ^ FNV_OFFSET, scenario.name().as_bytes()));
    let mut schedule = ArrivalSchedule::new();
    let mut next_fault = 0usize;

    for t in 0..cfg.ticks {
        // Expire open fault windows first...
        if run.burst_left > 0 {
            run.burst_left -= 1;
            if run.burst_left == 0 {
                run.dial.set(0.0);
                run.barrier(&mut report, &format!("tick {t} burst_end"))?;
            }
        }
        if run.collapse_left > 0 {
            run.collapse_left -= 1;
            if run.collapse_left == 0 {
                if let Some(b) = run.battery.clone() {
                    run.swap_battery(b);
                }
            }
        }
        // ...then land this tick's scheduled faults.
        while next_fault < plan.faults.len() && plan.faults[next_fault].tick == t {
            let fault = plan.faults[next_fault];
            run.apply(&fault, &mut report, &pop)?;
            next_fault += 1;
        }

        // One open-loop tick, exactly as `run_open_loop` shapes it.
        for _ in 0..schedule.due(cfg.offered_per_tick, scenario.intensity(t)) {
            match scenario.make_request(&mut factory, &mut rng) {
                Some(req) => {
                    run.fleet().submit(req);
                    report.submitted += 1;
                }
                None => report.exhausted = true,
            }
        }
        run.fleet().advance(1);
        let h = scenario.harvest_secs(t);
        if h > 0.0 {
            run.fleet().harvest(h);
        }
        {
            let fleet = run.fleet.take().expect("fleet alive");
            let mut sut = ServiceUnderTest::Fleet(fleet);
            scenario.on_tick(t, &mut sut);
            match sut {
                ServiceUnderTest::Fleet(f) => run.fleet = Some(f),
                ServiceUnderTest::Single(_) => unreachable!("chaos drives a fleet"),
            }
        }
        report.served +=
            run.fleet().drain_batched().with_context(|| format!("drain at tick {t}"))? as u64;

        if cfg.check_every > 0 && (t + 1) % cfg.check_every == 0 {
            run.barrier(&mut report, &format!("tick {t} checkpoint"))?;
        }
    }

    // Close any window still open, then drain the tail.
    if run.burst_left > 0 {
        run.burst_left = 0;
        run.dial.set(0.0);
        run.barrier(&mut report, "post-run burst_end")?;
    }
    if run.collapse_left > 0 {
        run.collapse_left = 0;
        if let Some(b) = run.battery.clone() {
            run.swap_battery(b);
        }
    }
    while report.tail_used < cfg.tail_ticks {
        if run.fleet().pending()? == 0
            && run.fleet().carryover_requests()? == 0
            && run.fleet().carryover_lineages()? == 0
        {
            break;
        }
        run.fleet().advance(1);
        let h = scenario.harvest_secs(cfg.ticks + report.tail_used);
        if h > 0.0 {
            run.fleet().harvest(h);
        }
        report.served += run.fleet().flush_batched()? as u64;
        report.tail_used += 1;
    }

    // Ledger conservation: everything submitted was served.
    if run.fleet().pending()? != 0
        || run.fleet().carryover_requests()? != 0
        || run.fleet().carryover_lineages()? != 0
    {
        report.violations.push(format!(
            "tail: {} queued / {} carried requests survived the drain tail",
            run.fleet().pending()?,
            run.fleet().carryover_requests()?
        ));
    }
    if report.served != report.submitted {
        report.violations.push(format!(
            "ledger: submitted {} but served {}",
            report.submitted, report.served
        ));
    }
    // Final bound check from a compacted source: the peer replica must
    // track the post-compaction WAL, not the run's full history.
    run.fleet().compact_now()?;
    run.barrier(&mut report, "final")?;

    // Surface the durability/ship/latency counters the soak binaries
    // print, and the trace when this run recorded one.
    let reg = run.fleet().registry()?;
    report.telemetry = Json::obj()
        .set("ship_attempts", reg.counter("ship.attempts"))
        .set("ship_faults", reg.counter("ship.faults"))
        .set("ship_failed", reg.counter("ship.failed"))
        .set("journal_appended", reg.counter("journal.appended"))
        .set("journal_fsyncs", reg.counter("journal.fsyncs"))
        .set("latency_dropped", reg.counter("latency.dropped"))
        .set("latency_slo_miss", reg.counter("latency.slo_miss"));
    if run.ecfg.obs {
        report.trace =
            Some(crate::obs::export::chrome_trace(&run.fleet().trace_records()?));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_plans_are_seeded_and_distinct_ticked() {
        let a = ChaosPlan::seeded(7, 64, &FaultClass::ALL);
        let b = ChaosPlan::seeded(7, 64, &FaultClass::ALL);
        assert_eq!(a.faults.len(), b.faults.len());
        for (x, y) in a.faults.iter().zip(&b.faults) {
            assert_eq!(
                (x.tick, x.class, x.shard, x.duration),
                (y.tick, y.class, y.shard, y.duration)
            );
        }
        // Every class present, on distinct ticks, inside the run.
        let mut ticks = BTreeSet::new();
        for f in &a.faults {
            assert!(f.tick >= 2 && f.tick < 64, "fault at {}", f.tick);
            assert!(ticks.insert(f.tick), "duplicate fault tick {}", f.tick);
        }
        for class in FaultClass::ALL {
            assert!(
                a.faults.iter().any(|f| f.class == class),
                "plan missing {}",
                class.name()
            );
        }
        // Different seeds move the schedule.
        let c = ChaosPlan::seeded(8, 64, &FaultClass::ALL);
        assert!(
            a.faults.iter().zip(&c.faults).any(|(x, y)| x.tick != y.tick),
            "seed must move the schedule"
        );
    }
}
